"""Fused transpose-free 2-D FFT Pallas kernel.

The paper's §5 2-D FFT is dominated by the global transpose between the two
1-D passes — on the Wormhole that transpose crosses the NoC; in our
row-column :func:`repro.core.fft2d.fft2` it round-trips through HBM twice.
This kernel is the TPU analogue of keeping the whole problem resident in
on-chip memory: each grid step loads a (block_batch, H, W) tile into VMEM
and performs

    row FFT -> in-VMEM tile transpose -> column FFT -> transpose back

so the global transpose never touches HBM.  Per image the kernel moves
exactly one HBM read + one HBM write (2 plane traversals); the
transpose-based path pays 8 — rows r/w, transpose r/w, columns r/w, output
transpose r/w (the model in
:func:`repro.analysis.roofline.fft2d_traffic_bytes`).  Both 1-D passes are the
mixed radix-4/radix-2 Stockham of :func:`repro.core.fft1d.stockham_stages` —
the same arithmetic as the 1-D kernel, just run on a 3-D VMEM tile.

Twiddles arrive as the packed (s4, 3, N/4) tables for W (rows) and H
(columns); for square tiles the two tables are byte-identical but kept as
separate operands so rectangular tiles work unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from repro.core import twiddle as tw
from repro.core.fft1d import stockham_stages


def _fft2d_kernel(wrw_ref, wiw_ref, wrh_ref, wih_ref,
                  xre_ref, xim_ref, ore_ref, oim_ref,
                  *, h: int, w: int, inverse: bool, radices_h, radices_w):
    """One batch tile: both 1-D passes and the tile transpose in VMEM."""
    re = xre_ref[...]                            # (bb, h, w)
    im = xim_ref[...]
    # row pass: FFT every length-w row, batched over (bb, h)
    re, im = stockham_stages(re, im, wrw_ref[...], wiw_ref[...], w,
                             radices_w, inverse=inverse)
    # in-VMEM tile transpose — the HBM round-trip this kernel eliminates
    re = jnp.swapaxes(re, -1, -2)                # (bb, w, h)
    im = jnp.swapaxes(im, -1, -2)
    # column pass: now contiguous length-h rows
    re, im = stockham_stages(re, im, wrh_ref[...], wih_ref[...], h,
                             radices_h, inverse=inverse)
    re = jnp.swapaxes(re, -1, -2)                # back to (bb, h, w)
    im = jnp.swapaxes(im, -1, -2)
    if inverse:
        scale = jnp.asarray(1.0 / (h * w), re.dtype)
        re = re * scale
        im = im * scale
    ore_ref[...] = re
    oim_ref[...] = im


def fft2d_fused_pallas(x: SplitComplex, *, inverse: bool = False,
                       block_batch: int = 1,
                       interpret: bool = True) -> SplitComplex:
    """Batched 2-D FFT over the last two axes: x.re/x.im of (batch, h, w)."""
    batch, h, w = x.re.shape
    for d in (h, w):
        assert d & (d - 1) == 0 and d >= 2, \
            f"power-of-two tile dims required, got {(h, w)}"
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)

    wrw_np, wiw_np = tw.packed_radix4_twiddles_np(w, inverse)
    wrh_np, wih_np = tw.packed_radix4_twiddles_np(h, inverse)
    wrw = jnp.asarray(wrw_np, x.dtype)
    wiw = jnp.asarray(wiw_np, x.dtype)
    wrh = jnp.asarray(wrh_np, x.dtype)
    wih = jnp.asarray(wih_np, x.dtype)

    kernel = functools.partial(_fft2d_kernel, h=h, w=w, inverse=inverse,
                               radices_h=tw.stockham_radices(h),
                               radices_w=tw.stockham_radices(w))
    grid = (batch // bb,)
    data_spec = pl.BlockSpec((bb, h, w), lambda i: (i, 0, 0))
    tww_spec = pl.BlockSpec(wrw.shape, lambda i: (0,) * wrw.ndim)
    twh_spec = pl.BlockSpec(wrh.shape, lambda i: (0,) * wrh.ndim)

    out_shape = [jax.ShapeDtypeStruct((batch, h, w), x.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tww_spec, tww_spec, twh_spec, twh_spec,
                  data_spec, data_spec],
        out_specs=[data_spec, data_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(wrw, wiw, wrh, wih, x.re, x.im)
    return SplitComplex(ore, oim)
