"""Fused spectral-convolution Pallas kernel: rfft -> pointwise multiply ->
irfft in ONE VMEM-resident pass.

The unfused ``fftconv`` path is three registry calls — ``rfft(x)``,
``cm.mul``, ``irfft`` — which ships the full half spectrum to HBM twice
per convolution (once out of the forward transform, once back into the
inverse).  On decoupled-data-movement hardware that round-trip *is* the
cost: the pointwise multiply is a rounding error next to the plane
traffic.  This kernel keeps the spectrum in VMEM, runs BOTH transforms
at the packed half length m/2 (four-step FLOPs are superlinear —
``F(m) ~ m * 2*sqrt(m)`` — so half-length passes are also the cheapest
real-input schedule, the same reason ``rfft`` beats a zero-imaginary
full FFT), and folds the whole spectral section into one multiply-add:

- **Even/odd packing per row** — each real row's even/odd samples become
  the re/im planes of one length-m/2 complex row (the classic rfft pack,
  all within a row: no cross-row coupling, no row-count constraint), and
  one forward four-step pass of length m/2 runs per row, producing the
  packed spectrum ``Z``.
- **Packed-domain filter operands** — the Hermitian untangle
  (``X[k] = A[k] Z[k] + B[k] conj(Z[(m/2-k) % (m/2)])`` with
  ``A = (1 - i w^k)/2``, ``B = (1 + i w^k)/2``, ``w = exp(-2*pi*i/m)``),
  the pointwise multiply ``Y = X * K`` against the filter half spectrum,
  and the packed-irfft pre-tangle
  (``Z'[k] = C[k] Y[k] + D[k] conj(Y[m/2-k])`` with
  ``C = (1 + i w^{-k})/2``, ``D = (1 - i w^{-k})/2``) compose — all
  three are elementwise in ``Z`` and its conjugate-reverse — into

      ``Z'[k] = E[k] Z[k] + F[k] conj(Z[(m/2-k) % (m/2)])``

  where ``E = C P + D conj(rev Q)``, ``F = C Q + D conj(rev P)``,
  ``P = K A``, ``Q = K B``.  E and F depend only on the FILTER and the
  twiddles, so :func:`pack_filter` builds them outside the kernel — in
  float64 numpy for concrete filters (cached per filter identity: the
  SSM/Hyena serving pattern pays the pack once), in-graph for traced
  training parameters — and the kernel's entire spectral section is the
  one complex multiply-add above.  E/F are the filter spectrum, linearly
  re-packaged into the packed domain; no information is added or lost.
- **Packed half-length inverse** — one m/2-point inverse four-step pass
  turns ``Z'`` back into the packed time sequence, and the even/odd
  interleave of its re/im planes writes the real row out.

Both FFT passes are one level of Bailey four-step — dense DFT-matrix
matmuls fed by host-built tables passed as operands (12 arrays: forward
+ inverse tables at length m/2).  Per call the kernel moves one real
plane in, the packed filter pair in, and one real plane out — versus the
unfused path's six planes (real in, spectrum out, spectrum + filter in,
product out, product in, real out).

Layout contract: ``x`` is (batch, R, m) real; the packed filter pair is
either (R, m/2) — one filter per row, shared across the batch grid (the
SSM/Hyena channel-bank pattern, staged once per grid step) — or
(batch, R, m/2) for per-batch filter banks.  ``m`` is the pre-padded
power-of-two FFT length; causal padding/truncation happens upstream in
:func:`repro.core.fftconv.fft_conv`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from repro.kernels.rfft2d_fused import (fft_last_fourstep, fourstep_factors,
                                        fourstep_tables_np)


def conv_tables(m: int, dtype):
    """The 12 table operands for one fused conv call: forward + inverse
    four-step tables for the packed length m/2 (the untangle / filter /
    pre-tangle twiddles are all folded into the packed filter operands —
    see :func:`pack_filter`), cast to the working dtype."""
    hm = m // 2
    tabs = fourstep_tables_np(hm, False) + fourstep_tables_np(hm, True)
    return [jnp.asarray(t, dtype) for t in tabs]


# -- packed-domain filter operands ------------------------------------------

_PACK_CACHE = {}   # (lead shape, m, dtype) -> (kf.re, kf.im, packed pair)


def clear_pack_cache() -> None:
    """Drop every cached packed filter pair (called alongside the plan
    registry's spectrum cache — packed operands derive from spectra)."""
    _PACK_CACHE.clear()


def _pack_coeffs(m: int):
    """The four twiddle coefficient vectors of the packed-domain collapse
    (float64): untangle A/B at k = 0..m/2, pre-tangle C/D at
    k = 0..m/2-1."""
    hm = m // 2
    w = np.exp(-2j * np.pi * np.arange(hm + 1) / m)
    a = (1.0 - 1j * w) / 2.0
    b = (1.0 + 1j * w) / 2.0
    c = (1.0 + 1j * np.conj(w[:hm])) / 2.0
    d = (1.0 - 1j * np.conj(w[:hm])) / 2.0
    return a, b, c, d


def _pack_filter_np(kre, kri, m: int, dtype):
    """Concrete filters: build E/F in float64 and cast once."""
    hm = m // 2
    kc = np.asarray(kre, np.float64) + 1j * np.asarray(kri, np.float64)
    # the C2R convention ignores the DC/Nyquist imaginary parts; zero them
    # here so residue in the fp32 spectrum cannot alias across the edges
    kc[..., 0] = kc[..., 0].real
    kc[..., hm] = kc[..., hm].real
    a, b, c, d = _pack_coeffs(m)
    p, q = kc * a, kc * b
    e = c * p[..., :hm] + d * np.conj(q[..., :0:-1])
    f = c * q[..., :hm] + d * np.conj(p[..., :0:-1])
    return (SplitComplex(jnp.asarray(e.real, dtype),
                         jnp.asarray(e.imag, dtype)),
            SplitComplex(jnp.asarray(f.real, dtype),
                         jnp.asarray(f.imag, dtype)))


def _pack_filter_traced(kf: SplitComplex, m: int, dtype):
    """Traced filters (jit-time training parameters): the same E/F build
    as jnp ops — part of the traced graph, recomputed per step because
    the filter itself changes per step."""
    hm = m // 2
    a, b, c, d = _pack_coeffs(m)
    ar, ai, br, bi, cr, ci, dr, di = [
        jnp.asarray(v, dtype) for co in (a, b, c, d)
        for v in (co.real, co.imag)]
    # zero the DC/Nyquist imaginary parts (C2R convention)
    mask = np.ones(hm + 1, np.float64)
    mask[0] = mask[hm] = 0.0
    kr = kf.re.astype(dtype)
    ki = kf.im.astype(dtype) * jnp.asarray(mask, dtype)
    pr, pi = kr * ar - ki * ai, kr * ai + ki * ar
    qr, qi = kr * br - ki * bi, kr * bi + ki * br
    rev = lambda t: jnp.flip(t[..., 1:], -1)          # indices m/2 .. 1
    prr, pri, qrr, qri = rev(pr), rev(pi), rev(qr), rev(qi)
    er = cr * pr[..., :hm] - ci * pi[..., :hm] + dr * qrr + di * qri
    ei = cr * pi[..., :hm] + ci * pr[..., :hm] + di * qrr - dr * qri
    fr = cr * qr[..., :hm] - ci * qi[..., :hm] + dr * prr + di * pri
    fi = cr * qi[..., :hm] + ci * qr[..., :hm] + di * prr - dr * pri
    return SplitComplex(er, ei), SplitComplex(fr, fi)


def pack_filter(kf: SplitComplex, m: int, dtype):
    """Fold the Hermitian untangle, the pointwise filter multiply and the
    packed-irfft pre-tangle into the packed-domain filter pair (E, F)
    with ``Z'[k] = E[k] Z[k] + F[k] conj(Z[(m/2-k) % (m/2)])``.

    kf is the filter half spectrum (..., m/2+1); returns two
    SplitComplex of (..., m/2).  Concrete filters build in float64 and
    are cached per filter identity (one entry per lead-shape/length key,
    the same policy as the plan registry's spectrum cache); traced
    filters build in-graph."""
    if isinstance(kf.re, jax.core.Tracer) or isinstance(kf.im,
                                                        jax.core.Tracer):
        return _pack_filter_traced(kf, m, dtype)
    key = (kf.re.shape[:-1], m, jnp.dtype(dtype).name)
    ent = _PACK_CACHE.get(key)
    if ent is not None and ent[0] is kf.re and ent[1] is kf.im:
        return ent[2]
    ef = _pack_filter_np(kf.re, kf.im, m, dtype)
    _PACK_CACHE[key] = (kf.re, kf.im, ef)
    return ef


# -- the kernel --------------------------------------------------------------

def _check_len(m: int):
    if m & (m - 1) or m < 4:
        raise ValueError("the fused conv kernel needs a power-of-two FFT "
                         f"length >= 4, got {m}")


def _fftconv_kernel(w1rf, w1if, w2rf, w2if, twrf, twif,
                    w1rb, w1ib, w2rb, w2ib, twrb, twib,
                    er_ref, ei_ref, fr_ref, fi_ref, x_ref, o_ref, *,
                    m: int, n1: int, n2: int, shared: bool):
    """One batch tile: packed forward FFT, the packed-domain filter
    multiply-add, packed inverse FFT — the spectrum never leaves VMEM."""
    x = x_ref[...]                               # (bb, r, m) real
    bb, r = x.shape[0], x.shape[1]
    re = x[..., 0::2]                            # even/odd samples -> one
    im = x[..., 1::2]                            # complex row: (bb, r, m/2)
    tf = (w1rf[...], w1if[...], w2rf[...], w2if[...], twrf[...], twif[...])
    zr, zi = fft_last_fourstep(re, im, tf, n1, n2)
    # the whole spectral section: Z' = E Z + F conj(Z[(m/2-k) % (m/2)]).
    # The conjugate-reverse index is a flip with DC fixed — one concat.
    zcr = jnp.concatenate([zr[..., :1], jnp.flip(zr[..., 1:], -1)], -1)
    zci = jnp.concatenate([zi[..., :1], jnp.flip(zi[..., 1:], -1)], -1)
    er, ei = er_ref[...], ei_ref[...]
    fr, fi = fr_ref[...], fi_ref[...]
    if shared:                                   # (r, m/2) -> broadcast bb
        er, ei, fr, fi = er[None], ei[None], fr[None], fi[None]
    z2r = er * zr - ei * zi + fr * zcr + fi * zci
    z2i = er * zi + ei * zr + fi * zcr - fr * zci
    tb = (w1rb[...], w1ib[...], w2rb[...], w2ib[...], twrb[...], twib[...])
    z2r, z2i = fft_last_fourstep(z2r, z2i, tb, n1, n2)
    out = jnp.stack([z2r, z2i], 3).reshape(bb, r, m)  # even/odd interleave
    o_ref[...] = out * jnp.asarray(2.0 / m, out.dtype)


def fftconv_fused_pallas(x: jnp.ndarray, ef, *,
                         block_batch: int = 1,
                         interpret: bool = True) -> jnp.ndarray:
    """Batched fused FFT convolution: x of (batch, r, m) real circularly
    convolved with the packed filter pair ef = (E, F) from
    :func:`pack_filter` — each (r, m/2) (shared bank) or (batch, r, m/2)
    (per-batch banks) -> (batch, r, m) real."""
    batch, r, m = x.shape
    _check_len(m)
    hm = m // 2
    e, f = ef
    shared = e.re.ndim == 2
    want = (r, hm) if shared else (batch, r, hm)
    assert e.re.shape == want and f.re.shape == want, (e.re.shape, want)
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)
    ops = conv_tables(m, x.dtype)
    n1, n2 = fourstep_factors(hm)
    kernel = functools.partial(_fftconv_kernel, m=m, n1=n1, n2=n2,
                               shared=shared)
    grid = (batch // bb,)
    tspecs = [pl.BlockSpec(t.shape, lambda i, nd=t.ndim: (0,) * nd)
              for t in ops]
    if shared:
        ef_spec = pl.BlockSpec((r, hm), lambda i: (0, 0))
    else:
        ef_spec = pl.BlockSpec((bb, r, hm), lambda i: (i, 0, 0))
    io_spec = pl.BlockSpec((bb, r, m), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=tspecs + [ef_spec] * 4 + [io_spec],
        out_specs=io_spec,
        out_shape=jax.ShapeDtypeStruct((batch, r, m), x.dtype),
        interpret=interpret)(*ops, e.re, e.im, f.re, f.im, x)
