"""Flash-decode Pallas kernel: one-token GQA attention against a KV cache.

The §Perf decode iterations (EXPERIMENTS.md D1/D2) identified the XLA-lowered
decode attention as copy-bound: the cache is re-materialised (and on CPU,
upcast) around the dot ops.  On TPU the fix is exactly this kernel: the
cache streams HBM -> VMEM once per token in (chunk) tiles, the online
softmax state (m, l, acc) lives in VMEM across the sequential chunk grid,
and nothing is ever written back but the (B, H, D) output.

Grid: (batch_tiles, kv_chunks) — the chunk dim is the minor (sequential)
axis, so accumulator blocks are revisited in order (the standard TPU
accumulation pattern).  Masking is positional (padding slots carry -1;
sliding windows are a position predicate), identical semantics to
repro.models.layers._attend_chunked / repro.models.flash.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                   acc_ref, m_ref, l_ref, *, window, kv_heads, q_heads):
    ci = pl.program_id(1)
    group = q_heads // kv_heads

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)                  # (bb, H, D)
    k = k_ref[...].astype(jnp.float32)                  # (bb, C, KV, D)
    v = v_ref[...].astype(jnp.float32)
    pos = pos_ref[...]                                  # (bb, C)
    qpos = qpos_ref[...]                                # (bb,)

    bb, h, d = q.shape
    c = k.shape[1]
    qg = q.reshape(bb, kv_heads, group, d) / np.sqrt(d)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k,
                   preferred_element_type=jnp.float32)  # (bb, KV, G, C)
    mask = (pos >= 0) & (pos <= qpos[:, None])
    if window is not None:
        mask &= pos > (qpos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)

    m_prev = m_ref[...]                                 # (bb, KV, G)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_prev * alpha + p.sum(axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jnp.einsum("bkgc,bckd->bkgd", p, v,
                                 preferred_element_type=jnp.float32))


def decode_attention_pallas(q, k_cache, v_cache, kv_pos, q_pos, *,
                            window=None, chunk: int = 512,
                            block_batch: int = 8,
                            interpret: bool = True):
    """q: (B, H, D); caches: (B, S, KV, D); kv_pos: (B, S) int32 (-1 = empty);
    q_pos: (B,).  Returns (B, H, D) in q.dtype."""
    b, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c
    bb = min(block_batch, b)
    assert b % bb == 0
    group = h // kvh

    grid = (b // bb, nc)
    q_spec = pl.BlockSpec((bb, h, d), lambda i, j: (i, 0, 0))
    kv_spec = pl.BlockSpec((bb, c, kvh, d), lambda i, j: (i, j, 0, 0))
    pos_spec = pl.BlockSpec((bb, c), lambda i, j: (i, j))
    qpos_spec = pl.BlockSpec((bb,), lambda i, j: (i,))
    acc_spec = pl.BlockSpec((bb, kvh, group, d), lambda i, j: (i, 0, 0, 0))
    ml_spec = pl.BlockSpec((bb, kvh, group), lambda i, j: (i, 0, 0))

    kernel = functools.partial(_decode_kernel, window=window,
                               kv_heads=kvh, q_heads=h)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qpos_spec, q_spec, kv_spec, kv_spec, pos_spec],
        out_specs=[acc_spec, ml_spec, ml_spec],
        out_shape=[jax.ShapeDtypeStruct((b, kvh, group, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, kvh, group), jnp.float32),
                   jax.ShapeDtypeStruct((b, kvh, group), jnp.float32)],
        interpret=interpret,
    )(q_pos, q, k_cache, v_cache, kv_pos)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(q.dtype)
