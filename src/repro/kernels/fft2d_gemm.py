"""GEMM-formulated fused complex 2-D FFT Pallas kernel.

The Tensix compute engine — like the TPU MXU — is matmul-native, and PR 5
already proved the formulation for the real-input kernel: one level of
Bailey four-step turns each 1-D pass into dense DFT-matrix *matmuls*
(``n = n1 * n2``, a single dense DFT below the leaf size) plus a pointwise
inter-factor twiddle, all fed by host-built float64 operand tables.  This
module folds that GEMM shape back into the flagship complex fused kernel:

- **Row pass** — :func:`repro.kernels.rfft2d_fused.fft_last_fourstep`
  on the length-W last axis.
- **Column pass** — :func:`~repro.kernels.rfft2d_fused.fft_col_fourstep`:
  the length-H FFT runs as *left-side* DFT contractions along axis -2, so
  the in-VMEM tile transpose the Stockham fused kernel pays
  (:mod:`repro.kernels.fft2d_fused`, now the explicit-algo oracle
  ``algo="fused_stockham"``) is absorbed into the matmul operand order and
  never materialises at all.

Per image the kernel still moves exactly one HBM read + one HBM write of
each split-complex plane — the §5 transpose stays off HBM — but the inner
loops are now MXU-shaped GEMMs instead of elementwise Stockham stages.

**Precision-compensated bf16 variant** (``variant="compensated"``): the
1024x1024 fp32 working set busts the 16 MiB v5e VMEM budget, and a bf16
tile halves it — but a straight bf16 cast of the DFT/twiddle tables costs
~1e-2 relative error.  The compensated variant stores every table as a
**split pair** ``w = hi + lo`` (``hi`` = the bf16 rounding of the float64
table, ``lo`` = the bf16 rounding of the residual ``w - hi``), reconstructs
the ~fp32-accurate value inside the kernel, and runs both four-step passes
with **fp32 accumulation**; only the resident tile — kernel I/O and the
inter-pass working set — stays bf16, which is exactly the footprint
:func:`repro.tt.trace.trace_plan` prices.  Error lands at the bf16
*quantisation* floor (~3e-3 relative) instead of the bf16 *arithmetic*
floor, inside the 5e-3 acceptance bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from .rfft2d_fused import (fourstep_factors, fourstep_tables_np,
                           fft_last_fourstep, fft_col_fourstep, _check_dims)

VARIANTS = ("plain", "compensated")


def split_table_np(t: np.ndarray, dtype) -> np.ndarray:
    """Stack the ``(hi, lo)`` split of a float64 table in storage dtype:
    ``hi`` is the direct rounding, ``lo`` the rounding of the residual, so
    ``hi + lo`` (accumulated in fp32) recovers the table to ~storage-eps^2
    accuracy from two narrow operands."""
    nd = np.dtype(jnp.dtype(dtype))       # ml_dtypes-backed for bfloat16
    hi = np.asarray(t, np.float64).astype(nd)
    lo = (t - hi.astype(np.float64)).astype(nd)
    return jnp.asarray(np.stack([hi, lo]))


def gemm_tables(h: int, w: int, inverse: bool, dtype, variant: str):
    """The 12 kernel table operands (6 per axis, W then H), plain-cast or
    split-stacked per ``variant``."""
    tabs = fourstep_tables_np(w, inverse) + fourstep_tables_np(h, inverse)
    if variant == "compensated":
        return [split_table_np(t, dtype) for t in tabs]
    return [jnp.asarray(t, dtype) for t in tabs]


def _unsplit(tabs, compensated: bool):
    if compensated:
        return tuple(t[0].astype(jnp.float32) + t[1].astype(jnp.float32)
                     for t in tabs)
    return tuple(tabs)


def _fft2d_gemm_kernel(*refs, h: int, w: int, n1w: int, n2w: int,
                       n1h: int, n2h: int, inverse: bool, compensated: bool):
    """One batch tile: four-step GEMM row pass, four-step GEMM column pass
    (transpose absorbed), everything VMEM-resident."""
    tw_w = _unsplit([r[...] for r in refs[:6]], compensated)
    tw_h = _unsplit([r[...] for r in refs[6:12]], compensated)
    xre_ref, xim_ref, ore_ref, oim_ref = refs[12:]
    re = xre_ref[...]                            # (bb, h, w)
    im = xim_ref[...]
    dt = re.dtype
    if compensated:
        re, im = re.astype(jnp.float32), im.astype(jnp.float32)
    re, im = fft_last_fourstep(re, im, tw_w, n1w, n2w)
    if compensated:
        # round the inter-pass tile back to the storage dtype: the resident
        # working set stays bf16-sized (the footprint the trace model
        # prices) while each pass accumulates in fp32
        re = re.astype(dt).astype(jnp.float32)
        im = im.astype(dt).astype(jnp.float32)
    re, im = fft_col_fourstep(re, im, tw_h, n1h, n2h)
    if inverse:
        scale = jnp.asarray(1.0 / (h * w), re.dtype)
        re, im = re * scale, im * scale
    ore_ref[...] = re.astype(dt)
    oim_ref[...] = im.astype(dt)


def fft2d_gemm_pallas(x: SplitComplex, *, inverse: bool = False,
                      block_batch: int = 1, variant: str = "plain",
                      interpret: bool = True) -> SplitComplex:
    """Batched 2-D FFT over the last two axes: x.re/x.im of (batch, h, w)."""
    assert variant in VARIANTS, variant
    batch, h, w = x.re.shape
    _check_dims(h, w)
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)
    ops = gemm_tables(h, w, inverse, x.dtype, variant)
    n1w, n2w = fourstep_factors(w)
    n1h, n2h = fourstep_factors(h)
    kernel = functools.partial(_fft2d_gemm_kernel, h=h, w=w, n1w=n1w,
                               n2w=n2w, n1h=n1h, n2h=n2h, inverse=inverse,
                               compensated=variant == "compensated")
    grid = (batch // bb,)
    data_spec = pl.BlockSpec((bb, h, w), lambda i: (i, 0, 0))
    tspecs = [pl.BlockSpec(t.shape, lambda i, nd=t.ndim: (0,) * nd)
              for t in ops]
    out_shape = [jax.ShapeDtypeStruct((batch, h, w), x.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel, grid=grid,
        in_specs=tspecs + [data_spec, data_spec],
        out_specs=[data_spec, data_spec], out_shape=out_shape,
        interpret=interpret)(*ops, x.re, x.im)
    return SplitComplex(ore, oim)
