"""Pure-jnp oracles for every kernel in this package.

Ground truth is ``jnp.fft`` (an implementation wholly independent of
``repro.core``), exposed in split-complex form so tests can
``assert_allclose`` kernel outputs directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.complexmath import SplitComplex


def fft_ref(x: SplitComplex, *, inverse: bool = False) -> SplitComplex:
    z = x.re.astype(jnp.complex64) + 1j * x.im.astype(jnp.complex64)
    out = jnp.fft.ifft(z, axis=-1) if inverse else jnp.fft.fft(z, axis=-1)
    return SplitComplex(jnp.real(out).astype(x.dtype),
                        jnp.imag(out).astype(x.dtype))


def fft2_ref(x: SplitComplex, *, inverse: bool = False) -> SplitComplex:
    z = x.re.astype(jnp.complex64) + 1j * x.im.astype(jnp.complex64)
    out = jnp.fft.ifft2(z) if inverse else jnp.fft.fft2(z)
    return SplitComplex(jnp.real(out).astype(x.dtype),
                        jnp.imag(out).astype(x.dtype))


def rfft_ref(x: jnp.ndarray) -> SplitComplex:
    out = jnp.fft.rfft(x, axis=-1)
    return SplitComplex(jnp.real(out).astype(x.dtype),
                        jnp.imag(out).astype(x.dtype))


def decode_attention_ref(q, k_cache, v_cache, kv_pos, q_pos, *, window=None):
    """Dense one-token GQA attention oracle.  q: (B,H,D); caches
    (B,S,KV,D); positions as in kernels.decode_attention."""
    import numpy as np
    b, h, d = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    qg = q.astype(jnp.float32).reshape(b, kvh, g, d) / np.sqrt(d)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache.astype(jnp.float32))
    mask = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window is not None:
        mask &= kv_pos > (q_pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
