"""Fused 3-D FFT Pallas kernel: pencil-in-VMEM four-step GEMM passes.

The 3-D transform is where the row-column schedule's transposes hurt most:
three 1-D passes separated by two global relayouts, each a full-volume HBM
round-trip (the distributed version in :mod:`repro.dist.pencil` pays them
as all_to_alls).  This kernel keeps an entire (block_batch, D, H, W)
sub-volume resident in VMEM and runs all three passes on it back to back —
per volume exactly one HBM read and one HBM write of each split-complex
plane, zero relayouts:

- **W pass** — :func:`repro.kernels.rfft2d_fused.fft_last_fourstep` over
  the contiguous last axis (every (d, h) pencil at once).
- **H pass** — :func:`~repro.kernels.rfft2d_fused.fft_col_fourstep` along
  axis -2: a *left-side* DFT contraction, so the W-H transpose is absorbed
  into the matmul operand order.
- **D pass** — the same left-side contraction with the (H, W) plane
  flattened into the pencil axis: reshaping (bb, D, H, W) to
  (bb, D, H*W) makes D the contracted axis of ``fft_col_fourstep`` and the
  D-H-W relayout disappears the same way.

Each pass is one level of Bailey four-step — dense DFT-matrix matmuls with
a pointwise inter-factor twiddle, single dense DFT below the leaf — fed by
host-built tables, exactly the GEMM formulation of the 2-D kernel
(:mod:`repro.kernels.fft2d_gemm`), whose precision-compensated bf16
variant (split tables + fp32 accumulation, bf16 resident tile) is also
available here: a 128^3 fp32 brick busts 16 MiB VMEM, the bf16 one fits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from repro.core.fft1d import _best_split
from .rfft2d_fused import (fourstep_tables_np, fft_last_fourstep,
                           fft_col_fourstep)
from .fft2d_gemm import VARIANTS, split_table_np, _unsplit

# The fused brick runs three memory-bound passes back to back, so its
# dense-leaf crossover sits one octave below the 2-D kernel's: at 256 a
# (16, 16) split does 16x fewer MACs per axis and the brick stays
# cache-resident between passes, which measures ~1.5x over the dense
# leaf on small-depth bricks; at <= 128 the dense matmul still wins
# (skinny-factor GEMMs run far below peak).
FOURSTEP_LEAF3 = 128


def fourstep_factors3(n: int):
    """(n1, n2) for one axis of the fused 3-D kernel (n1 == 1 means a
    single dense DFT matmul) — mirrored by repro.tt.trace's
    ``_gemm3d_stage`` so model and kernel count the same tables/flops."""
    n1 = 1 if n <= FOURSTEP_LEAF3 else _best_split(n)
    return n1, n // n1


def _check_dims3(d: int, h: int, w: int):
    for n in (d, h, w):
        if n & (n - 1) or n < 2:
            raise ValueError("the fused 3-D kernel needs power-of-two "
                             f"dims >= 2, got {(d, h, w)}")


def gemm_tables3(d: int, h: int, w: int, inverse: bool, dtype, variant: str):
    """The 18 kernel table operands (6 per axis: W, H, then D)."""
    tabs = (fourstep_tables_np(w, inverse, fourstep_factors3(w))
            + fourstep_tables_np(h, inverse, fourstep_factors3(h))
            + fourstep_tables_np(d, inverse, fourstep_factors3(d)))
    if variant == "compensated":
        return [split_table_np(t, dtype) for t in tabs]
    return [jnp.asarray(t, dtype) for t in tabs]


def _fft3d_kernel(*refs, d: int, h: int, w: int, facs, inverse: bool,
                  compensated: bool):
    """One batch tile: W, H and D four-step GEMM passes, all VMEM-resident
    (both transposes absorbed into left-side contractions)."""
    tw_w = _unsplit([r[...] for r in refs[:6]], compensated)
    tw_h = _unsplit([r[...] for r in refs[6:12]], compensated)
    tw_d = _unsplit([r[...] for r in refs[12:18]], compensated)
    (n1w, n2w), (n1h, n2h), (n1d, n2d) = facs
    xre_ref, xim_ref, ore_ref, oim_ref = refs[18:]
    re = xre_ref[...]                            # (bb, d, h, w)
    im = xim_ref[...]
    dt = re.dtype
    rnd = (lambda q: q.astype(dt).astype(jnp.float32)) if compensated \
        else (lambda q: q)
    if compensated:
        re, im = re.astype(jnp.float32), im.astype(jnp.float32)
    re, im = fft_last_fourstep(re, im, tw_w, n1w, n2w)       # W pass
    re, im = rnd(re), rnd(im)
    re, im = fft_col_fourstep(re, im, tw_h, n1h, n2h)        # H pass
    re, im = rnd(re), rnd(im)
    bb = re.shape[0]
    re = re.reshape(bb, d, h * w)                # D becomes the column axis
    im = im.reshape(bb, d, h * w)
    re, im = fft_col_fourstep(re, im, tw_d, n1d, n2d)        # D pass
    re = re.reshape(bb, d, h, w)
    im = im.reshape(bb, d, h, w)
    if inverse:
        scale = jnp.asarray(1.0 / (d * h * w), re.dtype)
        re, im = re * scale, im * scale
    ore_ref[...] = re.astype(dt)
    oim_ref[...] = im.astype(dt)


def fft3d_fused_pallas(x: SplitComplex, *, inverse: bool = False,
                       block_batch: int = 1, variant: str = "plain",
                       interpret: bool = True) -> SplitComplex:
    """Batched 3-D FFT over the last three axes: x.re/x.im of
    (batch, d, h, w)."""
    assert variant in VARIANTS, variant
    batch, d, h, w = x.re.shape
    _check_dims3(d, h, w)
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)
    ops = gemm_tables3(d, h, w, inverse, x.dtype, variant)
    facs = (fourstep_factors3(w), fourstep_factors3(h),
            fourstep_factors3(d))
    kernel = functools.partial(_fft3d_kernel, d=d, h=h, w=w, facs=facs,
                               inverse=inverse,
                               compensated=variant == "compensated")
    grid = (batch // bb,)
    data_spec = pl.BlockSpec((bb, d, h, w), lambda i: (i, 0, 0, 0))
    tspecs = [pl.BlockSpec(t.shape, lambda i, nd=t.ndim: (0,) * nd)
              for t in ops]
    out_shape = [jax.ShapeDtypeStruct((batch, d, h, w), x.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel, grid=grid,
        in_specs=tspecs + [data_spec, data_spec],
        out_specs=[data_spec, data_spec], out_shape=out_shape,
        interpret=interpret)(*ops, x.re, x.im)
    return SplitComplex(ore, oim)
