"""jit'd dispatch wrappers around the Pallas kernels.

Handle leading-batch flattening, batch padding to the block size, dtype
plumbing, and the interpret-mode switch (interpret=True on CPU — the kernels
target TPU; see EXAMPLE.md).  The public entry points mirror
:mod:`repro.core.fft1d` so :class:`repro.core.plan.FFTPlan` can swap backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.complexmath import SplitComplex
from . import fft_stockham as _stockham
from . import fft_fourstep as _fourstep
from . import fft_stage as _stage
from . import fft2d_fused as _fused2d
from . import fft2d_gemm as _gemm2d
from . import fft3d_fused as _fused3d
from . import rfft2d_fused as _rfused2d


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten(x: SplitComplex):
    n = x.shape[-1]
    lead = x.shape[:-1]
    batch = 1
    for d in lead:
        batch *= d
    return SplitComplex(x.re.reshape(batch, n), x.im.reshape(batch, n)), lead


def _unflatten(x: SplitComplex, lead) -> SplitComplex:
    n = x.shape[-1]
    return SplitComplex(x.re.reshape(*lead, n), x.im.reshape(*lead, n))


def _pad_batch(x: SplitComplex, bb: int):
    batch = x.shape[0]
    pad = (-batch) % bb
    if pad:
        x = SplitComplex(jnp.pad(x.re, ((0, pad), (0, 0))),
                         jnp.pad(x.im, ((0, pad), (0, 0))))
    return x, batch


@functools.partial(jax.jit, static_argnames=("inverse", "radix",
                                             "block_batch", "interpret"))
def fft_stockham(x: SplitComplex, *, inverse: bool = False, radix: int = 4,
                 block_batch: int = 8, interpret: bool = None) -> SplitComplex:
    if interpret is None:
        interpret = not _on_tpu()
    flat, lead = _flatten(x)
    padded, batch = _pad_batch(flat, block_batch)
    out = _stockham.fft_stockham_pallas(padded, inverse=inverse, radix=radix,
                                        block_batch=block_batch,
                                        interpret=interpret)
    out = SplitComplex(out.re[:batch], out.im[:batch])
    return _unflatten(out, lead)


def _flatten2d(x: SplitComplex):
    h, w = x.shape[-2:]
    lead = x.shape[:-2]
    batch = 1
    for d in lead:
        batch *= d
    return SplitComplex(x.re.reshape(batch, h, w),
                        x.im.reshape(batch, h, w)), lead


def _pad_batch2d(arrs, batch: int, block_batch: int):
    """Pad flattened (batch, h, w) component planes up to the block size.
    Callers guard ``batch > 0`` (an empty batch has nothing to kernel)."""
    bb = min(block_batch, batch)
    pad = (-batch) % bb
    if pad:
        arrs = [jnp.pad(a, ((0, pad), (0, 0), (0, 0))) for a in arrs]
    return arrs, bb


@functools.partial(jax.jit, static_argnames=("inverse", "block_batch",
                                             "interpret"))
def fft2d_fused(x: SplitComplex, *, inverse: bool = False,
                block_batch: int = 1, interpret: bool = None) -> SplitComplex:
    """Fused transpose-free 2-D FFT over the last two axes (any leading
    batch dims); see :mod:`repro.kernels.fft2d_fused`."""
    if interpret is None:
        interpret = not _on_tpu()
    flat, lead = _flatten2d(x)
    h, w = flat.shape[-2:]
    batch = flat.shape[0]
    if batch == 0:
        return x                       # empty batch: nothing to transform
    (re, im), bb = _pad_batch2d([flat.re, flat.im], batch, block_batch)
    out = _fused2d.fft2d_fused_pallas(SplitComplex(re, im), inverse=inverse,
                                      block_batch=bb, interpret=interpret)
    out = SplitComplex(out.re[:batch], out.im[:batch])
    return SplitComplex(out.re.reshape(*lead, h, w),
                        out.im.reshape(*lead, h, w))


@functools.partial(jax.jit, static_argnames=("inverse", "block_batch",
                                             "variant", "interpret"))
def fft2d_gemm(x: SplitComplex, *, inverse: bool = False,
               block_batch: int = 1, variant: str = "plain",
               interpret: bool = None) -> SplitComplex:
    """GEMM-formulated fused 2-D FFT over the last two axes (any leading
    batch dims): four-step DFT matmul passes, transpose absorbed; see
    :mod:`repro.kernels.fft2d_gemm`.  ``variant="compensated"`` runs the
    precision-compensated bf16 path (split tables + fp32 accumulation)."""
    if interpret is None:
        interpret = not _on_tpu()
    flat, lead = _flatten2d(x)
    h, w = flat.shape[-2:]
    batch = flat.shape[0]
    if batch == 0:
        return x                       # empty batch: nothing to transform
    (re, im), bb = _pad_batch2d([flat.re, flat.im], batch, block_batch)
    out = _gemm2d.fft2d_gemm_pallas(SplitComplex(re, im), inverse=inverse,
                                    block_batch=bb, variant=variant,
                                    interpret=interpret)
    return SplitComplex(out.re[:batch].reshape(*lead, h, w),
                        out.im[:batch].reshape(*lead, h, w))


def _flatten3d(x: SplitComplex):
    d, h, w = x.shape[-3:]
    lead = x.shape[:-3]
    batch = 1
    for n in lead:
        batch *= n
    return SplitComplex(x.re.reshape(batch, d, h, w),
                        x.im.reshape(batch, d, h, w)), lead


def _pad_batch3d(arrs, batch: int, block_batch: int):
    """Pad flattened (batch, d, h, w) component planes up to the block
    size.  Callers guard ``batch > 0``."""
    bb = min(block_batch, batch)
    pad = (-batch) % bb
    if pad:
        arrs = [jnp.pad(a, ((0, pad),) + ((0, 0),) * 3) for a in arrs]
    return arrs, bb


@functools.partial(jax.jit, static_argnames=("inverse", "block_batch",
                                             "variant", "interpret"))
def fft3d_fused(x: SplitComplex, *, inverse: bool = False,
                block_batch: int = 1, variant: str = "plain",
                interpret: bool = None) -> SplitComplex:
    """Fused 3-D FFT over the last three axes (any leading batch dims):
    pencil-in-VMEM four-step GEMM passes, both relayouts absorbed; see
    :mod:`repro.kernels.fft3d_fused`."""
    if interpret is None:
        interpret = not _on_tpu()
    flat, lead = _flatten3d(x)
    d, h, w = flat.shape[-3:]
    batch = flat.shape[0]
    if batch == 0:
        return x                       # empty batch: nothing to transform
    (re, im), bb = _pad_batch3d([flat.re, flat.im], batch, block_batch)
    out = _fused3d.fft3d_fused_pallas(SplitComplex(re, im), inverse=inverse,
                                      block_batch=bb, variant=variant,
                                      interpret=interpret)
    return SplitComplex(out.re[:batch].reshape(*lead, d, h, w),
                        out.im[:batch].reshape(*lead, d, h, w))


@functools.partial(jax.jit, static_argnames=("block_batch", "interpret"))
def rfft2d_fused(x: jnp.ndarray, *, block_batch: int = 1,
                 interpret: bool = None) -> SplitComplex:
    """Fused real-input 2-D FFT over the last two axes (any leading batch
    dims): real (..., h, w) -> (..., h, w//2+1) half spectra; see
    :mod:`repro.kernels.rfft2d_fused`."""
    if interpret is None:
        interpret = not _on_tpu()
    h, w = x.shape[-2:]
    lead = x.shape[:-2]
    batch = 1
    for d in lead:
        batch *= d
    if batch == 0:
        empty = jnp.zeros((*lead, h, w // 2 + 1), x.dtype)
        return SplitComplex(empty, empty)
    (flat,), bb = _pad_batch2d([x.reshape(batch, h, w)], batch, block_batch)
    out = _rfused2d.rfft2d_fused_pallas(flat, block_batch=bb,
                                        interpret=interpret)
    return SplitComplex(out.re[:batch].reshape(*lead, h, w // 2 + 1),
                        out.im[:batch].reshape(*lead, h, w // 2 + 1))


@functools.partial(jax.jit, static_argnames=("block_batch", "interpret"))
def irfft2d_fused(xf: SplitComplex, *, block_batch: int = 1,
                  interpret: bool = None) -> jnp.ndarray:
    """Inverse twin of :func:`rfft2d_fused`: (..., h, w/2+1) half spectra ->
    real (..., h, w)."""
    if interpret is None:
        interpret = not _on_tpu()
    h, bins = xf.shape[-2:]
    w = 2 * (bins - 1)
    lead = xf.shape[:-2]
    batch = 1
    for d in lead:
        batch *= d
    if batch == 0:
        return jnp.zeros((*lead, h, w), xf.dtype)
    (re, im), bb = _pad_batch2d([xf.re.reshape(batch, h, bins),
                                 xf.im.reshape(batch, h, bins)],
                                batch, block_batch)
    out = _rfused2d.irfft2d_fused_pallas(SplitComplex(re, im),
                                         block_batch=bb,
                                         interpret=interpret)
    return out[:batch].reshape(*lead, h, w)


def _fftconv_ref(x3: jnp.ndarray, kf: SplitComplex) -> jnp.ndarray:
    """Differentiable jnp twin of the fused conv core: the same
    rfft -> pointwise multiply -> irfft math at the padded length."""
    from repro.core import complexmath as cm
    from repro.core import fft1d
    m = x3.shape[-1]
    xf = fft1d.rfft(x3)                        # registry-resolved jnp algos:
    return fft1d.irfft(cm.mul(xf, kf), m)      # same VJP as the unfused plan


# pallas_call has no autodiff rules, but the conv core is bilinear in
# (x, kf), so the jnp twin's VJP is exact: forward stays on the fused
# kernel, backward runs the composed jnp transforms.  The packed filter
# pair ef derives linearly from kf (repro.kernels.fftconv_fused
# .pack_filter), so the bwd returns the TOTAL kf gradient through the
# kf slot and zeros for ef — anything nonzero there would double count.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _fftconv_core(x3, kf, ef, block_batch, interpret):
    from . import fftconv_fused as _fconv
    return _fconv.fftconv_fused_pallas(x3, ef, block_batch=block_batch,
                                       interpret=interpret)


def _fftconv_core_fwd(x3, kf, ef, block_batch, interpret):
    return _fftconv_core(x3, kf, ef, block_batch, interpret), (x3, kf, ef)


def _fftconv_core_bwd(block_batch, interpret, res, g):
    x3, kf, ef = res
    _, vjp = jax.vjp(_fftconv_ref, x3, kf)
    dx, dkf = vjp(g)
    return dx, dkf, jax.tree_util.tree_map(jnp.zeros_like, ef)


_fftconv_core.defvjp(_fftconv_core_fwd, _fftconv_core_bwd)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _fftconv_jit(xb, kfb, efb, block_batch, interpret):
    return _fftconv_core(xb, kfb, efb, block_batch, interpret)


def fftconv_fused(x: jnp.ndarray, kf: SplitComplex, *, block_batch: int = 1,
                  interpret: bool = None) -> jnp.ndarray:
    """Fused FFT convolution over the last axis: real x (..., m) circularly
    convolved per-row with the filter half spectra kf (..., m//2+1) ->
    real of the broadcast shape; see :mod:`repro.kernels.fftconv_fused`.

    The leading dims of x and kf broadcast; the last lead dim becomes the
    kernel's row axis.  A kf whose lead dims broadcast to
    just the row axis — e.g. the SSM channel bank (C, K) against
    (B, C, L) activations — stays a (rows, m//2) shared operand staged
    once per grid step instead of materialising per-batch copies.

    NOT jitted on purpose: the filter packs into its packed-domain
    operands (E, F) at the Python level, so a concrete filter — the
    eager-serving and closure-constant benchmark patterns — packs in
    float64 numpy, cached per filter identity, and enters the traced
    graph as a constant.  Traced filters pack in-graph (the training
    pattern: the filter changes every step, so per-step packing is
    semantically required)."""
    import numpy as np
    from . import fftconv_fused as _fconv
    if interpret is None:
        interpret = not _on_tpu()
    m = x.shape[-1]
    hm = m // 2
    lead = np.broadcast_shapes(x.shape[:-1], kf.re.shape[:-1])
    out_shape = lead + (m,)
    lead = lead if lead else (1,)
    r = lead[-1]
    batch = 1
    for d in lead[:-1]:
        batch *= d
    if batch == 0 or r == 0:
        return jnp.zeros(out_shape, x.dtype)
    xb = jnp.broadcast_to(x, lead + (m,)).reshape(batch, r, m)
    klead = kf.re.shape[:-1]
    # pack in the filter's OWN lead shape (identity-cache-friendly: the
    # broadcast copies below are fresh arrays every call, the caller's
    # filter object is not), then broadcast E/F exactly like kf
    e, f = _fconv.pack_filter(kf, m, x.dtype)

    def _bcast(sc, bins, to2, to3):
        if to2 is not None:
            return SplitComplex(
                jnp.broadcast_to(sc.re, to2 + (bins,)).reshape(r, bins),
                jnp.broadcast_to(sc.im, to2 + (bins,)).reshape(r, bins))
        return SplitComplex(
            jnp.broadcast_to(sc.re, to3 + (bins,)).reshape(batch, r, bins),
            jnp.broadcast_to(sc.im, to3 + (bins,)).reshape(batch, r, bins))

    # shared bank iff the filter's lead dims broadcast to one row axis
    shared = int(np.prod(np.broadcast_shapes(klead, (r,)), dtype=np.int64)) \
        == r
    to2 = np.broadcast_shapes(klead, (r,)) if shared else None
    to3 = None if shared else lead
    kfb = _bcast(kf, hm + 1, to2, to3)
    efb = (_bcast(e, hm, to2, to3), _bcast(f, hm, to2, to3))
    bb = min(block_batch, batch)
    pad = (-batch) % bb
    if pad:
        xb = jnp.pad(xb, ((0, pad), (0, 0), (0, 0)))
        if not shared:
            bpad = ((0, pad), (0, 0), (0, 0))
            padsc = lambda sc: SplitComplex(jnp.pad(sc.re, bpad),
                                            jnp.pad(sc.im, bpad))
            kfb = padsc(kfb)
            efb = (padsc(efb[0]), padsc(efb[1]))
    out = _fftconv_jit(xb, kfb, efb, bb, interpret)
    return out[:batch, :r].reshape(out_shape)


@functools.partial(jax.jit, static_argnames=("inverse", "block_batch", "n1",
                                             "interpret"))
def fft_fourstep(x: SplitComplex, *, inverse: bool = False,
                 block_batch: int = 4, n1: int = None,
                 interpret: bool = None) -> SplitComplex:
    if interpret is None:
        interpret = not _on_tpu()
    flat, lead = _flatten(x)
    padded, batch = _pad_batch(flat, block_batch)
    out = _fourstep.fft_fourstep_pallas(padded, inverse=inverse,
                                        block_batch=block_batch, n1=n1,
                                        interpret=interpret)
    out = SplitComplex(out.re[:batch], out.im[:batch])
    return _unflatten(out, lead)


@functools.partial(jax.jit, static_argnames=("window", "chunk",
                                             "block_batch", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_pos, q_pos, *, window=None,
                     chunk: int = 512, block_batch: int = 8,
                     interpret: bool = None):
    """Flash-decode kernel (see kernels.decode_attention): the TPU fix for
    the copy-bound XLA decode attention measured in EXPERIMENTS.md §Perf D2."""
    from . import decode_attention as _da
    if interpret is None:
        interpret = not _on_tpu()
    b = q.shape[0]
    bb = min(block_batch, b)
    pad = (-b) % bb
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        k_cache = jnp.pad(k_cache, ((0, pad), (0, 0), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, pad), (0, 0), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, pad), (0, 0)), constant_values=-1)
        q_pos = jnp.pad(q_pos, ((0, pad),))
    out = _da.decode_attention_pallas(q, k_cache, v_cache, kv_pos, q_pos,
                                      window=window, chunk=chunk,
                                      block_batch=bb, interpret=interpret)
    return out[:b]


@functools.partial(jax.jit, static_argnames=("inverse", "block_batch",
                                             "interpret"))
def fft_staged(x: SplitComplex, *, inverse: bool = False,
               block_batch: int = 8, interpret: bool = None) -> SplitComplex:
    """Paper-faithful per-stage kernel chain (the Table 1 baseline)."""
    if interpret is None:
        interpret = not _on_tpu()
    flat, lead = _flatten(x)
    padded, batch = _pad_batch(flat, block_batch)
    out = _stage.fft_staged_pallas(padded, inverse=inverse,
                                   block_batch=block_batch,
                                   interpret=interpret)
    out = SplitComplex(out.re[:batch], out.im[:batch])
    return _unflatten(out, lead)
