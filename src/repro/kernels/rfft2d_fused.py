"""Fused real-input 2-D FFT Pallas kernel (rfft2 / irfft2).

The complex fused kernel (:mod:`repro.kernels.fft2d_fused`) already keeps
the §5 global transpose off HBM; this kernel additionally exploits the
input being *real*, which halves both FLOPs and HBM plane traffic:

- **Row-pair packing** — rows ``2j`` and ``2j+1`` of the real (H, W) tile
  become the re/im planes of ONE complex row, so the row pass runs H/2
  complex FFTs of length W instead of H (the classic two-real-FFTs-in-one
  trick).
- **Hermitian untangle in-VMEM** — the packed spectra split back into the
  two rows' half spectra ``A = (Z[k] + conj(Z[-k]))/2`` and
  ``B = -i (Z[k] - conj(Z[-k]))/2`` for k = 0..W/2, never materialising
  the redundant half.
- **Half-width column pass** — the column FFT runs on the (H, W/2+1) half
  spectrum as a *left-side* DFT contraction along the H axis, so the tile
  transpose the row-column schedule pays for is absorbed into the matmul
  operand order and never round-trips anywhere (not even inside VMEM).

Both 1-D passes are one level of Bailey four-step — dense DFT-matrix
matmuls (MXU work on TPU, fast GEMMs under interpret mode on CPU) with a
pointwise inter-factor twiddle — fed by host-built tables passed as kernel
operands: ``n = n1 * n2`` with ``n1 = 1`` (a single dense DFT) below the
leaf size.  Per image the kernel moves one real plane in and one half
spectrum out: ~half the complex fused kernel's HBM traffic, and the VMEM
working set is the half-width tile (the 1024x1024 fp32 case fits the
16 MiB v5e budget that the complex kernel busts — see
:func:`repro.tt.trace.trace_plan`).

The inverse twin repacks the half spectra (Hermitian extension of each
row pair into one complex row), runs the inverse column and row passes,
and writes the real plane; ``s=`` truncate/pad fits happen upstream in
:func:`repro.core.fft2d.irfft2`, which hands this kernel an already
fitted spectrum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from repro.core.fft1d import _best_split

# below this length a single dense DFT matmul beats the four-step's extra
# twiddle/reshape traffic (mirrors resolve_algo's naive-leaf region)
FOURSTEP_LEAF = 256


def fourstep_factors(n: int):
    """(n1, n2) with n = n1 * n2: the one-level four-step split used by the
    kernel (and mirrored by the :mod:`repro.tt.trace` cost model).  n1 == 1
    means a single dense DFT matmul."""
    n1 = 1 if n <= FOURSTEP_LEAF else _best_split(n)
    return n1, n // n1


def fourstep_tables_np(n: int, inverse: bool, factors=None):
    """Host-built float64 tables for one four-step pass of length n, cast
    by the caller: DFT matrices for both factors plus the inter-factor
    twiddle ``T[k1, j2] = exp(sign * 2*pi*i * k1*j2 / n)`` — composed from
    the (lru-cached) builders in :mod:`repro.core.twiddle`.  No 1/n
    scaling — the inverse kernels fold one 1/(H*W) at the end.  An
    explicit ``factors`` pair overrides :func:`fourstep_factors` (the 3-D
    kernel's leaf crossover sits one octave lower)."""
    from repro.core.twiddle import _dft_matrix_np, _fourstep_twiddle_np
    n1, n2 = fourstep_factors(n) if factors is None else factors
    assert n1 * n2 == n, (n, n1, n2)
    sign = 1.0 if inverse else -1.0
    w1r, w1i = _dft_matrix_np(n1, sign)
    w2r, w2i = _dft_matrix_np(n2, sign)
    twr, twi = _fourstep_twiddle_np(n1, n2, sign)
    return (w1r, w1i, w2r, w2i, twr, twi)


def fft_last_fourstep(re, im, tabs, n1: int, n2: int):
    """Length-(n1*n2) FFT of the last axis via one four-step level.

    The n1-factor DFT contracts along axis -2 as a *left* multiply
    (einsum), so no transpose is materialised for it; only the four-step
    output reordering transposes the two small factor axes.
    """
    w1r, w1i, w2r, w2i, twr, twi = tabs
    b = re.shape[:-1]
    re = re.reshape(*b, n1, n2)
    im = im.reshape(*b, n1, n2)
    if n1 > 1:
        yr = jnp.einsum("ka,...an->...kn", w1r, re) \
            - jnp.einsum("ka,...an->...kn", w1i, im)
        yi = jnp.einsum("ka,...an->...kn", w1i, re) \
            + jnp.einsum("ka,...an->...kn", w1r, im)
        re, im = yr * twr - yi * twi, yr * twi + yi * twr
    zr = re @ w2r - im @ w2i
    zi = re @ w2i + im @ w2r
    # output ordering X[k2*n1 + k1] = Z[k1, k2]
    zr = jnp.swapaxes(zr, -1, -2).reshape(*b, n1 * n2)
    zi = jnp.swapaxes(zi, -1, -2).reshape(*b, n1 * n2)
    return zr, zi


def fft_col_fourstep(re, im, tabs, n1: int, n2: int):
    """Length-(n1*n2) FFT along axis -2 of an (..., H, C) tile — the column
    pass — as left-side DFT contractions, absorbing the tile transpose."""
    w1r, w1i, w2r, w2i, twr, twi = tabs
    b = re.shape[:-2]
    c = re.shape[-1]
    re = re.reshape(*b, n1, n2, c)
    im = im.reshape(*b, n1, n2, c)
    if n1 > 1:
        yr = jnp.einsum("ka,...anc->...knc", w1r, re) \
            - jnp.einsum("ka,...anc->...knc", w1i, im)
        yi = jnp.einsum("ka,...anc->...knc", w1i, re) \
            + jnp.einsum("ka,...anc->...knc", w1r, im)
        twr = twr[..., None]
        twi = twi[..., None]
        re, im = yr * twr - yi * twi, yr * twi + yi * twr
    zr = jnp.einsum("kb,...nbc->...nkc", w2r, re) \
        - jnp.einsum("kb,...nbc->...nkc", w2i, im)
    zi = jnp.einsum("kb,...nbc->...nkc", w2i, re) \
        + jnp.einsum("kb,...nbc->...nkc", w2r, im)
    zr = jnp.swapaxes(zr, -3, -2).reshape(*b, n1 * n2, c)
    zi = jnp.swapaxes(zi, -3, -2).reshape(*b, n1 * n2, c)
    return zr, zi


def _conj_rev(x):
    """x[(W-k) % W] for k = 0..W/2 on a length-W last axis (the conj(Z[-k])
    gather of the Hermitian untangle, built from a flip — no gather op)."""
    h = x.shape[-1] // 2
    return jnp.concatenate([x[..., :1], jnp.flip(x[..., h:], -1)], -1)


def _rfft2d_kernel(w1rw, w1iw, w2rw, w2iw, twrw, twiw,
                   w1rh, w1ih, w2rh, w2ih, twrh, twih,
                   x_ref, ore_ref, oim_ref, *,
                   h: int, w: int, n1w: int, n2w: int, n1h: int, n2h: int):
    """One batch tile: packed row FFT, Hermitian untangle, half-width
    column FFT — all VMEM-resident."""
    x = x_ref[...]                               # (bb, h, w) real
    re = x[:, 0::2, :]                           # row pairs -> one complex
    im = x[:, 1::2, :]                           # row: (bb, h/2, w)
    tw_w = (w1rw[...], w1iw[...], w2rw[...], w2iw[...], twrw[...], twiw[...])
    re, im = fft_last_fourstep(re, im, tw_w, n1w, n2w)
    # untangle Z -> A (even rows), B (odd rows), bins k = 0..w/2
    hw = w // 2
    cr, ci = _conj_rev(re), _conj_rev(im)
    rk, ik = re[..., :hw + 1], im[..., :hw + 1]
    ar, ai = (rk + cr) * 0.5, (ik - ci) * 0.5
    br, bi = (ik + ci) * 0.5, (cr - rk) * 0.5
    bb = x.shape[0]
    re2 = jnp.stack([ar, br], 2).reshape(bb, h, hw + 1)
    im2 = jnp.stack([ai, bi], 2).reshape(bb, h, hw + 1)
    # column FFT on the half-width tile (transpose absorbed into the
    # left-side contraction)
    tw_h = (w1rh[...], w1ih[...], w2rh[...], w2ih[...], twrh[...], twih[...])
    re2, im2 = fft_col_fourstep(re2, im2, tw_h, n1h, n2h)
    ore_ref[...] = re2
    oim_ref[...] = im2


def _irfft2d_kernel(w1rw, w1iw, w2rw, w2iw, twrw, twiw,
                    w1rh, w1ih, w2rh, w2ih, twrh, twih,
                    xre_ref, xim_ref, o_ref, *,
                    h: int, w: int, n1w: int, n2w: int, n1h: int, n2h: int):
    """Inverse twin: inverse column FFT, row-pair repack (Hermitian
    extension), inverse row FFT, write the real plane."""
    re = xre_ref[...]                            # (bb, h, w/2+1)
    im = xim_ref[...]
    tw_h = (w1rh[...], w1ih[...], w2rh[...], w2ih[...], twrh[...], twih[...])
    re, im = fft_col_fourstep(re, im, tw_h, n1h, n2h)
    # repack: rows 2j/2j+1's half spectra A/B -> Z = A_ext + i * B_ext.
    # The C2R convention (numpy, and the jnp path's trailing .re) ignores
    # the imaginary parts of the DC and Nyquist bins; here they MUST be
    # zeroed explicitly — a complex Nyquist (e.g. after an s= width
    # truncation) would otherwise leak row 2j+1's residue into row 2j.
    hw = w // 2
    ar, ai = re[:, 0::2, :], im[:, 0::2, :]      # (bb, h/2, w/2+1)
    br, bi = re[:, 1::2, :], im[:, 1::2, :]
    z0 = jnp.zeros_like(ai[..., :1])
    drop_ends = lambda q: jnp.concatenate([z0, q[..., 1:hw], z0], -1)
    ai, bi = drop_ends(ai), drop_ends(bi)
    ext = lambda q, s: jnp.concatenate(
        [q, s * jnp.flip(q[..., 1:hw], -1)], -1)  # Hermitian-extend to w
    zr = ext(ar, 1.0) - ext(bi, -1.0)
    zi = ext(ai, -1.0) + ext(br, 1.0)
    tw_w = (w1rw[...], w1iw[...], w2rw[...], w2iw[...], twrw[...], twiw[...])
    zr, zi = fft_last_fourstep(zr, zi, tw_w, n1w, n2w)
    bb = re.shape[0]
    out = jnp.stack([zr, zi], 2).reshape(bb, h, w)   # re -> 2j, im -> 2j+1
    o_ref[...] = out * jnp.asarray(1.0 / (h * w), out.dtype)


def _tables(h: int, w: int, inverse: bool, dtype):
    tabs_w = fourstep_tables_np(w, inverse)
    tabs_h = fourstep_tables_np(h, inverse)
    return [jnp.asarray(t, dtype) for t in tabs_w + tabs_h]


def _check_dims(h: int, w: int):
    for d in (h, w):
        if d & (d - 1) or d < 2:
            raise ValueError("the fused rfft kernel needs power-of-two "
                             f"tile dims >= 2, got {(h, w)}")


def rfft2d_fused_pallas(x: jnp.ndarray, *, block_batch: int = 1,
                        interpret: bool = True) -> SplitComplex:
    """Batched real 2-D FFT: x of (batch, h, w) real -> (batch, h, w/2+1)
    half spectra."""
    batch, h, w = x.shape
    _check_dims(h, w)
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)
    ops = _tables(h, w, False, x.dtype)
    n1w, n2w = fourstep_factors(w)
    n1h, n2h = fourstep_factors(h)
    kernel = functools.partial(_rfft2d_kernel, h=h, w=w, n1w=n1w, n2w=n2w,
                               n1h=n1h, n2h=n2h)
    grid = (batch // bb,)
    in_spec = pl.BlockSpec((bb, h, w), lambda i: (i, 0, 0))
    out_spec = pl.BlockSpec((bb, h, w // 2 + 1), lambda i: (i, 0, 0))
    tspecs = [pl.BlockSpec(t.shape, lambda i, nd=t.ndim: (0,) * nd)
              for t in ops]
    out_shape = [jax.ShapeDtypeStruct((batch, h, w // 2 + 1), x.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel, grid=grid, in_specs=tspecs + [in_spec],
        out_specs=[out_spec, out_spec], out_shape=out_shape,
        interpret=interpret)(*ops, x)
    return SplitComplex(ore, oim)


def irfft2d_fused_pallas(xf: SplitComplex, *, block_batch: int = 1,
                         interpret: bool = True) -> jnp.ndarray:
    """Batched inverse real 2-D FFT: (batch, h, w/2+1) half spectra ->
    (batch, h, w) real, w = 2 * (bins - 1)."""
    batch, h, bins = xf.re.shape
    w = 2 * (bins - 1)
    _check_dims(h, w)
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)
    ops = _tables(h, w, True, xf.dtype)
    n1w, n2w = fourstep_factors(w)
    n1h, n2h = fourstep_factors(h)
    kernel = functools.partial(_irfft2d_kernel, h=h, w=w, n1w=n1w, n2w=n2w,
                               n1h=n1h, n2h=n2h)
    grid = (batch // bb,)
    in_spec = pl.BlockSpec((bb, h, bins), lambda i: (i, 0, 0))
    out_spec = pl.BlockSpec((bb, h, w), lambda i: (i, 0, 0))
    tspecs = [pl.BlockSpec(t.shape, lambda i, nd=t.ndim: (0,) * nd)
              for t in ops]
    out = pl.pallas_call(
        kernel, grid=grid, in_specs=tspecs + [in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((batch, h, w), xf.dtype),
        interpret=interpret)(*ops, xf.re, xf.im)
    return out
