"""Four-step (Bailey) FFT Pallas kernel — the MXU formulation.

N = n1*n2: column DFTs as a (n1,n1) complex matmul, pointwise twiddle, row
DFTs as a (n2,n2) complex matmul, output transpose.  Every FLOP except the
twiddle multiply lands on the MXU; matmul operand dims are chosen MXU-aligned
(n1, n2 multiples of 128 whenever N allows).

This is the beyond-paper headline (DESIGN.md §2): the paper found the Tensix
matrix and vector units interchangeable for FFT; on TPU the MXU is ~50x the
VPU for f32 MACs, so reformulating the butterflies as dense DFT matmuls
converts a movement-bound kernel into a compute-dense one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from repro.core import twiddle as tw


def _split_n(n: int) -> tuple:
    """Factor n = n1*n2 with n1 <= n2, both as close to sqrt(n) and as
    MXU-friendly (multiples of 128, else powers of two) as possible."""
    best = None
    for n1 in range(1, int(np.sqrt(n)) + 1):
        if n % n1 == 0:
            best = n1
    n1 = best
    return n1, n // n1


def _cmatmul(ar, ai, br, bi, *, left: bool):
    """Complex matmul via 4 real matmuls; left: W@A else A@W."""
    dot = lambda p, q: jnp.dot(p, q, preferred_element_type=jnp.float32)
    if left:
        return (dot(br, ar) - dot(bi, ai), dot(br, ai) + dot(bi, ar))
    return (dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br))


def _fourstep_kernel(w1r_ref, w1i_ref, w2r_ref, w2i_ref, tr_ref, ti_ref,
                     xre_ref, xim_ref, ore_ref, oim_ref,
                     *, n1: int, n2: int, inverse: bool):
    b = xre_ref.shape[0]
    n = n1 * n2
    # (1) column DFTs: fold batch into the contraction's RHS free dim so the
    # whole tile is ONE (n1 x n1) @ (n1 x b*n2) MXU matmul per plane.
    ar = xre_ref[...].reshape(b, n1, n2).transpose(1, 0, 2).reshape(n1, b * n2)
    ai = xim_ref[...].reshape(b, n1, n2).transpose(1, 0, 2).reshape(n1, b * n2)
    br_, bi_ = _cmatmul(ar, ai, w1r_ref[...], w1i_ref[...], left=True)
    br_ = br_.reshape(n1, b, n2).transpose(1, 0, 2)      # (b, n1, n2)
    bi_ = bi_.reshape(n1, b, n2).transpose(1, 0, 2)
    # (2) pointwise twiddle T[k1, n2]
    tr_v = tr_ref[...]
    ti_v = ti_ref[...]
    cr = br_ * tr_v - bi_ * ti_v
    ci = br_ * ti_v + bi_ * tr_v
    # (3) row DFTs: (b*n1, n2) @ (n2, n2)
    cr2 = cr.reshape(b * n1, n2)
    ci2 = ci.reshape(b * n1, n2)
    dr, di = _cmatmul(cr2, ci2, w2r_ref[...], w2i_ref[...], left=False)
    dr = dr.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b, n)
    di = di.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b, n)
    if inverse:
        s = jnp.asarray(1.0 / n, dr.dtype)
        dr, di = dr * s, di * s
    ore_ref[...] = dr.astype(ore_ref.dtype)
    oim_ref[...] = di.astype(oim_ref.dtype)


def fft_fourstep_pallas(x: SplitComplex, *, inverse: bool = False,
                        block_batch: int = 4, n1: int = None,
                        interpret: bool = True) -> SplitComplex:
    """Batched four-step FFT along the last axis: (batch, n) planes."""
    batch, n = x.re.shape
    if n1 is None:
        n1, n2 = _split_n(n)
    else:
        n2 = n // n1
    assert n1 * n2 == n and n1 > 1, (n, n1)
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)

    w1 = tw.dft_matrix(n1, inverse=inverse, dtype=x.dtype)
    w2 = tw.dft_matrix(n2, inverse=inverse, dtype=x.dtype)
    t = tw.fourstep_twiddle(n1, n2, inverse=inverse, dtype=x.dtype)

    grid = (batch // bb,)
    data_spec = pl.BlockSpec((bb, n), lambda i: (i, 0))
    w1_spec = pl.BlockSpec((n1, n1), lambda i: (0, 0))
    w2_spec = pl.BlockSpec((n2, n2), lambda i: (0, 0))
    t_spec = pl.BlockSpec((n1, n2), lambda i: (0, 0))

    kernel = functools.partial(_fourstep_kernel, n1=n1, n2=n2, inverse=inverse)
    out_shape = [jax.ShapeDtypeStruct((batch, n), x.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[w1_spec, w1_spec, w2_spec, w2_spec, t_spec, t_spec,
                  data_spec, data_spec],
        out_specs=[data_spec, data_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(w1.re, w1.im, w2.re, w2.im, t.re, t.im, x.re, x.im)
    return SplitComplex(ore, oim)
