"""VMEM-resident Stockham FFT Pallas kernel.

The TPU analogue of the paper's SRAM-resident single-core FFT (Section 4),
with the full reorder-elimination ladder applied:

- The whole (batch-tile x N) problem lives in VMEM for all log2(N) stages —
  zero HBM round-trips between stages (the paper pays an SRAM round-trip per
  stage through its circular buffers).
- The Pallas grid pipelines HBM->VMEM tile loads against compute — the
  paper's *chunked* optimisation, done by the Mosaic pipeline emitter.
- Stockham's autosort write pattern removes the explicit reorders entirely;
  every slice below is a contiguous block, so Mosaic emits full-width vector
  ld/st (the paper's *128-bit copies*, without the fused-reorder contiguity
  regression it reports for *Single data copy*).

Twiddles arrive as one packed (stages, N/2) table: row s holds the
per-butterfly factors for stage s, pre-broadcast over the stride axis, so the
kernel's twiddle access is also a contiguous row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex


def _log2(n: int) -> int:
    return int(n).bit_length() - 1


@functools.lru_cache(maxsize=64)
def packed_twiddles_np(n: int, inverse: bool) -> tuple:
    """(stages, n//2) per-stage, stride-broadcast twiddle planes (float64)."""
    stages = _log2(n)
    sign = 1.0 if inverse else -1.0
    wr = np.empty((stages, n // 2), dtype=np.float64)
    wi = np.empty((stages, n // 2), dtype=np.float64)
    for s in range(stages):
        n_cur = n >> s
        stride = 1 << s
        m = n_cur // 2
        p = np.arange(m, dtype=np.float64)
        ang = sign * 2.0 * np.pi * p / n_cur
        wr[s] = np.repeat(np.cos(ang), stride)
        wi[s] = np.repeat(np.sin(ang), stride)
    return wr, wi


def _stockham_kernel(wre_ref, wim_ref, xre_ref, xim_ref, ore_ref, oim_ref,
                     *, n: int, inverse: bool):
    """One batch tile, all stages in VMEM."""
    stages = _log2(n)
    h = n // 2
    re = xre_ref[...]
    im = xim_ref[...]
    b = re.shape[0]
    for s in range(stages):                      # static unroll: log2(N) steps
        stride = 1 << s
        m = n >> (s + 1)
        ar, ai = re[:, :h], im[:, :h]            # contiguous halves
        br, bi = re[:, h:], im[:, h:]
        wr = wre_ref[s, :]
        wi = wim_ref[s, :]
        ur, ui = ar + br, ai + bi                # a + b
        sr, si = ar - br, ai - bi                # a - b
        vr = sr * wr - si * wi                   # (a - b) * w
        vi = sr * wi + si * wr
        # autosort interleave: (b, m, stride) pairs -> (b, n)
        re = jnp.stack([ur.reshape(b, m, stride),
                        vr.reshape(b, m, stride)], axis=2).reshape(b, n)
        im = jnp.stack([ui.reshape(b, m, stride),
                        vi.reshape(b, m, stride)], axis=2).reshape(b, n)
    if inverse:
        scale = jnp.asarray(1.0 / n, re.dtype)
        re = re * scale
        im = im * scale
    ore_ref[...] = re
    oim_ref[...] = im


def fft_stockham_pallas(x: SplitComplex, *, inverse: bool = False,
                        block_batch: int = 8,
                        interpret: bool = True) -> SplitComplex:
    """Batched FFT along the last axis: x.re/x.im of shape (batch, n)."""
    batch, n = x.re.shape
    assert n & (n - 1) == 0 and n >= 2, f"power-of-two n required, got {n}"
    stages = _log2(n)
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)
    wr_np, wi_np = packed_twiddles_np(n, inverse)
    wr = jnp.asarray(wr_np, x.dtype)
    wi = jnp.asarray(wi_np, x.dtype)

    grid = (batch // bb,)
    data_spec = pl.BlockSpec((bb, n), lambda i: (i, 0))
    tw_spec = pl.BlockSpec((stages, n // 2), lambda i: (0, 0))

    kernel = functools.partial(_stockham_kernel, n=n, inverse=inverse)
    out_shape = [jax.ShapeDtypeStruct((batch, n), x.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tw_spec, tw_spec, data_spec, data_spec],
        out_specs=[data_spec, data_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(wr, wi, x.re, x.im)
    return SplitComplex(ore, oim)
