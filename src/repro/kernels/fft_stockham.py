"""VMEM-resident Stockham FFT Pallas kernel (mixed radix-4 / radix-2).

The TPU analogue of the paper's SRAM-resident single-core FFT (Section 4),
with the full reorder-elimination ladder applied:

- The whole (batch-tile x N) problem lives in VMEM for all stages — zero HBM
  round-trips between stages (the paper pays an SRAM round-trip per stage
  through its circular buffers).
- The Pallas grid pipelines HBM->VMEM tile loads against compute — the
  paper's *chunked* optimisation, done by the Mosaic pipeline emitter.
- Stockham's autosort write pattern removes the explicit reorders entirely;
  every slice below is a contiguous block, so Mosaic emits full-width vector
  ld/st (the paper's *128-bit copies*, without the fused-reorder contiguity
  regression it reports for *Single data copy*).
- Radix-4 stages (radix-2 tail for odd log2 N) halve the stage count — and
  with it the inter-stage VMEM traffic — versus the radix-2 kernel, which is
  kept as ``radix=2`` (the autotune candidate and numerical oracle).

Twiddles arrive packed: radix-4 stages read a (s4, 3, N/4) table (row s =
stage s's (w, w^2, w^3), pre-broadcast over the stride axis), radix-2 reads
the (stages, N/2) table — either way every access is a contiguous row.  The
stage arithmetic itself is :func:`repro.core.fft1d.stockham_stages`, shared
with the jnp path and the fused 2-D kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from repro.core import twiddle as tw
from repro.core.fft1d import stockham_stages, stockham_radix2_stages

# Back-compat export: the packed radix-2 table historically lived here.
packed_twiddles_np = tw.packed_radix2_twiddles_np


def _log2(n: int) -> int:
    return int(n).bit_length() - 1


def _stockham_kernel(wre_ref, wim_ref, xre_ref, xim_ref, ore_ref, oim_ref,
                     *, n: int, inverse: bool, radices):
    """One batch tile, all mixed-radix stages in VMEM."""
    re, im = stockham_stages(xre_ref[...], xim_ref[...],
                             wre_ref[...], wim_ref[...], n, radices,
                             inverse=inverse)
    if inverse:
        scale = jnp.asarray(1.0 / n, re.dtype)
        re = re * scale
        im = im * scale
    ore_ref[...] = re
    oim_ref[...] = im


def _stockham_kernel_r2(wre_ref, wim_ref, xre_ref, xim_ref, ore_ref, oim_ref,
                        *, n: int, inverse: bool):
    """Radix-2 variant: one butterfly per stage, log2(N) stages."""
    re, im = stockham_radix2_stages(xre_ref[...], xim_ref[...],
                                    wre_ref[...], wim_ref[...], n)
    if inverse:
        scale = jnp.asarray(1.0 / n, re.dtype)
        re = re * scale
        im = im * scale
    ore_ref[...] = re
    oim_ref[...] = im


def fft_stockham_pallas(x: SplitComplex, *, inverse: bool = False,
                        radix: int = 4, block_batch: int = 8,
                        interpret: bool = True) -> SplitComplex:
    """Batched FFT along the last axis: x.re/x.im of shape (batch, n)."""
    batch, n = x.re.shape
    assert n & (n - 1) == 0 and n >= 2, f"power-of-two n required, got {n}"
    assert radix in (2, 4), radix
    bb = min(block_batch, batch)
    assert batch % bb == 0, (batch, bb)

    if radix == 4:
        wr_np, wi_np = tw.packed_radix4_twiddles_np(n, inverse)
        kernel = functools.partial(_stockham_kernel, n=n, inverse=inverse,
                                   radices=tw.stockham_radices(n))
    else:
        wr_np, wi_np = tw.packed_radix2_twiddles_np(n, inverse)
        kernel = functools.partial(_stockham_kernel_r2, n=n, inverse=inverse)
    wr = jnp.asarray(wr_np, x.dtype)
    wi = jnp.asarray(wi_np, x.dtype)

    grid = (batch // bb,)
    data_spec = pl.BlockSpec((bb, n), lambda i: (i, 0))
    tw_spec = pl.BlockSpec(wr.shape, lambda i: (0,) * wr.ndim)

    out_shape = [jax.ShapeDtypeStruct((batch, n), x.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tw_spec, tw_spec, data_spec, data_spec],
        out_specs=[data_spec, data_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(wr, wi, x.re, x.im)
    return SplitComplex(ore, oim)
