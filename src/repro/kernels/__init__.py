"""Pallas TPU kernels for the FFT hot path (validated with interpret=True).

fft_stockham — VMEM-resident autosort FFT (all stages, zero reorders)
fft_fourstep — MXU DFT-matmul four-step FFT
fft_stage    — paper-faithful per-stage butterfly chain (baseline)
fft2d_fused  — fused transpose-free 2-D FFT (row/transpose/column in VMEM)
rfft2d_fused — fused real-input 2-D FFT (row-pair packing, half spectrum)
ops          — jit'd wrappers; ref — jnp.fft oracles
"""
