"""Single radix-2 butterfly stage Pallas kernel — the paper's per-step design.

The paper's *Initial* implementation runs one stage at a time: gather the
stage's LHS/RHS pairs into contiguous tiles (read reorder), butterfly, then
scatter back to natural order (write reorder), with an SRAM round-trip per
stage.  This kernel reproduces that structure on TPU — one ``pallas_call``
per stage, gather/scatter permutations done in-kernel — and exists as the
measured *baseline* of the reorder-elimination ladder (benchmarks table 1).
:mod:`repro.kernels.fft_stockham` is the end state the ladder reaches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.complexmath import SplitComplex
from repro.core import twiddle as tw
from repro.core.fft1d import _ct_stage_indices, _log2


def _stage_kernel(idx0_ref, idx1_ref, inv_ref, wr_ref, wi_ref,
                  zre_ref, zim_ref, ore_ref, oim_ref, *, n: int):
    h = n // 2
    re = zre_ref[...]
    im = zim_ref[...]
    idx0 = idx0_ref[...]
    idx1 = idx1_ref[...]
    # read reorder: gather pairs into contiguous LHS/RHS tiles
    lr = jnp.take(re, idx0, axis=1)
    li = jnp.take(im, idx0, axis=1)
    rr = jnp.take(re, idx1, axis=1)
    ri = jnp.take(im, idx1, axis=1)
    wr = wr_ref[...]
    wi = wi_ref[...]
    fr = rr * wr - ri * wi                       # f0 (Listing 1.1)
    fi = rr * wi + ri * wr                       # f1
    o0r, o0i = lr + fr, li + fi
    o1r, o1i = lr - fr, li - fi
    cat_r = jnp.concatenate([o0r, o1r], axis=1)
    cat_i = jnp.concatenate([o0i, o1i], axis=1)
    inv = inv_ref[...]
    # write reorder: scatter back to natural order
    ore_ref[...] = jnp.take(cat_r, inv, axis=1)
    oim_ref[...] = jnp.take(cat_i, inv, axis=1)


def fft_stage_pallas(z: SplitComplex, stage: int, *, inverse: bool = False,
                     block_batch: int = 8,
                     interpret: bool = True) -> SplitComplex:
    """Apply butterfly stage ``stage`` to bit-reversed-order data (batch, n)."""
    batch, n = z.re.shape
    h = n // 2
    bb = min(block_batch, batch)
    assert batch % bb == 0
    _, stages = _ct_stage_indices(n)
    idx0, idx1, tw_idx, inv_perm = stages[stage]
    c, s = tw._twiddle_np(n, 1.0 if inverse else -1.0)   # host-side table
    wr = jnp.asarray(c[tw_idx], z.dtype)
    wi = jnp.asarray(s[tw_idx], z.dtype)

    grid = (batch // bb,)
    data_spec = pl.BlockSpec((bb, n), lambda i: (i, 0))
    half_spec = pl.BlockSpec((h,), lambda i: (0,))
    full_spec = pl.BlockSpec((n,), lambda i: (0,))
    kernel = functools.partial(_stage_kernel, n=n)
    out_shape = [jax.ShapeDtypeStruct((batch, n), z.dtype)] * 2
    ore, oim = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[half_spec, half_spec, full_spec, half_spec, half_spec,
                  data_spec, data_spec],
        out_specs=[data_spec, data_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray(idx0, jnp.int32), jnp.asarray(idx1, jnp.int32),
      jnp.asarray(inv_perm, jnp.int32), wr, wi, z.re, z.im)
    return SplitComplex(ore, oim)


def fft_staged_pallas(x: SplitComplex, *, inverse: bool = False,
                      block_batch: int = 8,
                      interpret: bool = True) -> SplitComplex:
    """Full FFT as log2(N) chained single-stage kernels (paper's Initial)."""
    batch, n = x.re.shape
    rev = jnp.asarray(tw.bit_reverse_indices(n))
    z = SplitComplex(jnp.take(x.re, rev, axis=1), jnp.take(x.im, rev, axis=1))
    for s in range(_log2(n)):
        z = fft_stage_pallas(z, s, inverse=inverse, block_batch=block_batch,
                             interpret=interpret)
    if inverse:
        z = SplitComplex(z.re / n, z.im / n)
    return z
