"""Checkpointing: async, atomic, latest-k retention, **elastic** restore.

Design (multi-host posture, tested single-host):
- Every host writes its *local shards* of each jax.Array (`.addressable_shards`)
  into its own subdirectory; a JSON manifest records the pytree structure,
  global shapes/dtypes, and the step.  No host ever materialises a global
  array — required at 340B scale.
- Writes go to ``step_XXXX.tmp`` and are atomically renamed after fsync:
  a crash mid-write can never corrupt the latest checkpoint (fault
  tolerance requirement).
- ``save_async`` snapshots device arrays to host memory synchronously (cheap)
  and does the disk I/O on a worker thread — the train loop overlaps
  checkpoint I/O with compute.
- **Elastic restore**: ``restore`` takes the *target* sharding tree; shards
  on disk are concatenated to the global array and re-laid-out for the new
  mesh, so a job can restart on a different device count (scale up/down
  after node failure).
- The data-pipeline step and RNG state ride along in the manifest, so a
  restart is bitwise-deterministic.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Synchronous atomic save."""
        self._write(step, self._snapshot(tree), extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot now, write on a worker thread (overlaps with compute)."""
        self.wait()
        snap = self._snapshot(tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, snap, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, tree):
        paths, leaves, treedef = _flatten_with_paths(tree)
        host = []
        for leaf in leaves:
            arr = jnp.asarray(leaf)
            shards = []
            for s in arr.addressable_shards:
                shards.append((s.index, np.asarray(s.data)))
            host.append({"global_shape": tuple(arr.shape),
                         "dtype": str(arr.dtype), "shards": shards})
        return paths, host, treedef

    def _write(self, step: int, snap, extra: dict):
        paths, host, _ = snap
        pid = jax.process_index()
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + f".tmp{pid}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for path, rec in zip(paths, host):
            safe = path.replace("/", "__")
            manifest["leaves"][path] = {
                "global_shape": list(rec["global_shape"]),
                "dtype": rec["dtype"],
                "file": f"{safe}.host{pid}.npz",
            }
            arrs = {}
            for i, (index, data) in enumerate(rec["shards"]):
                arrs[f"shard_{i}"] = data
                arrs[f"index_{i}"] = np.array(
                    [[sl.start or 0,
                      sl.stop if sl.stop is not None else rec["global_shape"][d]]
                     for d, sl in enumerate(index)], np.int64)
            np.savez(os.path.join(tmp, manifest["leaves"][path]["file"]),
                     **arrs)
        with open(os.path.join(tmp, f"manifest.host{pid}.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final) if not os.path.exists(final) else \
            self._merge_into(tmp, final)
        self._gc()

    def _merge_into(self, tmp, final):
        for name in os.listdir(tmp):
            os.replace(os.path.join(tmp, name), os.path.join(final, name))
        shutil.rmtree(tmp, ignore_errors=True)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Any = None):
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional pytree of NamedSharding for **elastic**
        restore — global arrays are rebuilt from shards then re-laid-out
        for the (possibly different) current mesh.
        Returns (tree, extra).
        """
        d = os.path.join(self.directory, f"step_{step:08d}")
        manifests = [json.load(open(os.path.join(d, m)))
                     for m in sorted(os.listdir(d))
                     if m.startswith("manifest.")]
        assert manifests, f"no manifest in {d}"
        leaves_meta = {}
        for m in manifests:
            leaves_meta.update(m["leaves"])
        extra = manifests[0]["extra"]

        paths, leaves, treedef = _flatten_with_paths(target_tree)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, shd in zip(paths, leaves, shard_flat):
            meta = leaves_meta[path]
            gshape = tuple(meta["global_shape"])
            full = np.zeros(gshape, dtype=np.dtype(meta["dtype"]))
            # gather every host's shard files for this leaf
            safe = path.replace("/", "__")
            for fname in os.listdir(d):
                if fname.startswith(safe + ".host"):
                    z = np.load(os.path.join(d, fname))
                    n = len([k for k in z.files if k.startswith("shard_")])
                    for i in range(n):
                        idx = z[f"index_{i}"]
                        sl = tuple(slice(int(a), int(b)) for a, b in idx)
                        full[sl] = z[f"shard_{i}"]
            arr = jnp.asarray(full)
            if shd is not None:
                arr = jax.device_put(arr, shd)
            out.append(arr.astype(leaf.dtype))
        return jax.tree.unflatten(treedef, out), extra
