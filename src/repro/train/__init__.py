"""repro.train — optimizer, train step, checkpointing (pure JAX, no optax)."""
