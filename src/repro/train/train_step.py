"""Train step: loss/grad/update with microbatch accumulation and optional
compressed gradient reduction.

``make_train_step`` builds a pure (params, opt_state, batch) -> (params,
opt_state, metrics) function suitable for jit/pjit.  Microbatching runs a
``lax.scan`` over grad accumulation slices (peak activation memory divides by
``microbatches``).  With ``compress="bf16"`` the accumulated gradients are
cast to bf16 *before* the (pjit-inserted) data-parallel all-reduce and
error-feedback residuals are carried in the optimizer state — halving
gradient collective bytes (DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from . import optimizer as opt_lib


def make_train_step(cfg: ModelConfig, ocfg: opt_lib.AdamWConfig, *,
                    microbatches: int = 1,
                    compress: Optional[str] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        return loss, metrics, grads

    def accumulate(params, batch):
        if microbatches == 1:
            return grads_of(params, batch)
        def slice_mb(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mbs = jax.tree.map(slice_mb, batch)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            loss, metrics, grads = grads_of(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, grads_acc, grads)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss_sum, grads_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mbs)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads_sum)
        return loss_sum * inv, {}, grads

    def train_step(params, opt_state, batch):
        loss, _, grads = accumulate(params, batch)
        if compress == "bf16":
            # cast before the DP all-reduce; keep the quantisation error as
            # a residual added back next step (error feedback)
            resid = opt_state.get("ef_residual")
            if resid is not None:
                grads = jax.tree.map(
                    lambda g, r: g + r.astype(jnp.float32), grads, resid)
            q = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            new_resid = jax.tree.map(
                lambda g, qq: (g - qq.astype(jnp.float32)).astype(jnp.bfloat16),
                grads, q)
            grads = jax.tree.map(lambda qq: qq.astype(jnp.float32), q)
        inner = {k: v for k, v in opt_state.items() if k != "ef_residual"}
        new_params, new_inner, metrics = opt_lib.adamw_update(
            ocfg, grads, inner, params)
        new_state = dict(new_inner)
        if compress == "bf16":
            new_state["ef_residual"] = new_resid
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step


def init_opt_state(cfg: ModelConfig, ocfg: opt_lib.AdamWConfig, params, *,
                   compress: Optional[str] = None):
    state = opt_lib.adamw_init(ocfg, params)
    if compress == "bf16":
        state["ef_residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    return state


def abstract_opt_state(cfg: ModelConfig, ocfg: opt_lib.AdamWConfig,
                       abstract_params, *, compress: Optional[str] = None):
    return jax.eval_shape(
        functools.partial(init_opt_state, cfg, ocfg, compress=compress),
        abstract_params)
