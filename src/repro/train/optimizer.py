"""Optimizers and schedules from scratch (no optax in this environment).

AdamW with decoupled weight decay, global-norm clipping, warmup-cosine
schedule, optional gradient accumulation, and optional bf16 moment storage
(a distributed-memory trick: halves optimizer HBM for the 340B config; the
update math still runs in f32).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"   # "bfloat16" halves optimizer memory


def warmup_cosine(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(cfg: AdamWConfig, params):
    mdt = jnp.dtype(cfg.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    lr = warmup_cosine(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_val = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.
        newp = p.astype(jnp.float32) - lr * (step_val + decay)
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
