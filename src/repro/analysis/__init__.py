"""repro.analysis — loop-aware HLO cost extraction + roofline model."""
