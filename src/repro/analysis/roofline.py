"""Roofline model: three terms per (arch x shape x mesh) from the dry-run.

    compute term    = HLO_FLOPs / (chips x peak FLOP/s)
    memory term     = HLO_bytes / (chips x HBM bw)
    collective term = collective_bytes / (chips x link bw)

All numerators come from the loop-aware HLO analysis (repro.analysis.hloparse)
of the per-device compiled module, so terms are already per-chip.  Hardware
numbers come from the multi-arch tables in :mod:`repro.tt.arch` (Wormhole
n300, Grayskull e150, TPU v5e, Xeon 8160); the module-level ``HW`` dict is
the TPU v5e entry, kept for the historical callers — pass ``arch=`` to
:func:`fft2d_roofline` / :func:`roofline_terms` for any other machine.

MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference);
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch waste.  The
``fraction`` column is ideal_time / max(term)s — the share of roofline the
compiled program could reach if perfectly overlapped.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional

from repro.tt.arch import hw_table

HW = hw_table("tpu_v5e")


def fft2d_traffic_bytes(h: int, w: int, *, elem_bytes: int = 8,
                        fused: bool = False) -> float:
    """Modelled HBM traffic of one (h, w) split-complex 2-D FFT.

    One "plane" is the full split-complex image (re+im), h*w*elem_bytes with
    elem_bytes=8 for float32 re+im.  The row-column path streams the plane
    through HBM three times — row pass (read+write), global transpose
    (read+write, the paper's §5 bottleneck), column pass (read+write) — plus
    the second output transpose: 8 plane-traversals.  The fused kernel keeps
    each tile VMEM-resident through both passes and the tile transpose, so
    HBM sees exactly one read and one write: 2 traversals, a 4x traffic
    reduction.  (Per-stage butterfly traffic is VMEM-side in both cases and
    excluded here; this term is the memory-roofline numerator for
    :mod:`benchmarks.table3_fft2d`.)
    """
    plane = float(h) * float(w) * float(elem_bytes)
    if fused:
        return 2.0 * plane                       # one HBM read + one write
    return 8.0 * plane                           # rows r/w, T r/w, cols r/w, T r/w


def fft2d_roofline(h: int, w: int, *, elem_bytes: int = 8,
                   fused: bool = False, flops: Optional[float] = None,
                   arch: str = "tpu_v5e") -> dict:
    """Roofline terms for the 2-D FFT under the traffic model above, on any
    :mod:`repro.tt.arch` entry (default keeps the historical v5e)."""
    import math
    hw = hw_table(arch)
    n = h * w
    if flops is None:
        flops = 5.0 * n * math.log2(n)           # canonical 5 N log2 N
    traffic = fft2d_traffic_bytes(h, w, elem_bytes=elem_bytes, fused=fused)
    compute_s = flops / hw["peak_flops_f32"]
    memory_s = traffic / hw["hbm_bw"]
    step_s = max(compute_s, memory_s)
    return {
        "arch": arch,
        "flops": flops,
        "traffic_bytes": traffic,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "step_s": step_s,
        "dominant": "memory_s" if memory_s >= compute_s else "compute_s",
        "energy_j": step_s * hw["chip_power_w"],
    }


def roofline_terms(rec: dict, *, arch: str = "tpu_v5e") -> Optional[dict]:
    la = rec.get("loop_aware") or {}
    if "flops" not in la:
        return None
    hw = hw_table(arch)
    chips = rec["devices"] if rec["mesh"] == "2x16x16" else 256
    # per-device numbers from the per-device module
    peak = (hw["peak_flops_bf16"] if rec.get("dtype") == "bfloat16"
            else hw["peak_flops_f32"])
    compute_s = la["flops"] / peak
    memory_s = la["traffic_bytes"] / hw["hbm_bw"]
    collective_s = la["collective_total"] / hw["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    tokens = (rec["global_batch"] * rec["seq_len"]
              if rec["kind"] in ("train", "prefill") else rec["global_batch"])
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * rec["n_active"] * tokens
    hlo_total = la["flops"] * chips
    ideal_s = model_flops / (chips * peak)
    if rec["kind"] == "decode":
        # decode is bandwidth-bound by construction: every active param must
        # be read once per token — the memory roofline is the honest ideal
        pbytes = 2 if rec.get("dtype") == "bfloat16" else 4
        ideal_mem = rec["n_active"] * pbytes / (chips * hw["hbm_bw"])
        ideal_s = max(ideal_s, ideal_mem)
    step_s = max(terms.values())
    return dict(
        terms,
        dominant=dominant,
        model_flops=model_flops,
        hlo_flops_total=hlo_total,
        useful_ratio=model_flops / hlo_total if hlo_total else 0.0,
        ideal_s=ideal_s,
        step_s=step_s,
        fraction=ideal_s / step_s if step_s else 0.0,
        chips=chips,
        energy_j=step_s * chips * hw["chip_power_w"],
    )


def load_records(save_dir: str = "runs/dryrun", mesh: str = "16x16",
                 include_variants: bool = False) -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(save_dir, mesh, "*.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("tag") and not include_variants:
            continue                      # hillclimb variants live in §Perf
        out.append(rec)
    return out


def markdown_table(save_dir: str = "runs/dryrun", mesh: str = "16x16",
                   arch: str = "tpu_v5e") -> str:
    rows = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
            "dominant | useful ratio | roofline frac | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in load_records(save_dir, mesh):
        t = roofline_terms(rec, arch=arch)
        if t is None:
            rows.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | "
                        f"parse-error | - | - | |")
            continue
        note = _note(rec, t)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant'].replace('_s','')} | {t['useful_ratio']:.2f} | "
            f"{t['fraction']:.3f} | {note} |")
    return "\n".join(rows)


def _note(rec: dict, t: dict) -> str:
    if t["dominant"] == "collective_s":
        return "shrink/overlap collectives"
    if t["dominant"] == "memory_s":
        if rec["kind"] == "decode":
            return "decode is HBM-bound by nature (weights+cache read/token)"
        return "fuse/cast to cut HBM traffic"
    if t["useful_ratio"] < 0.5:
        return "recompute/dispatch overhead dominates HLO flops"
    return "near compute roofline"


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--save-dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--arch", default="tpu_v5e",
                    help="any repro.tt.arch entry (wormhole_n300, xeon_8160, ...)")
    args = ap.parse_args()
    print(markdown_table(args.save_dir, args.mesh, args.arch))


if __name__ == "__main__":
    main()
