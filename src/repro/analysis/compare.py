"""Compare dry-run artifacts (baseline vs hillclimb variants).

    python -m repro.analysis.compare runs/dryrun/16x16/A.json runs/dryrun/16x16/B.json
"""
from __future__ import annotations

import json
import sys

from .roofline import HW


def row(path: str) -> dict:
    with open(path) as f:
        rec = json.load(f)
    la = rec["loop_aware"]
    peak = (HW["peak_flops_bf16"] if rec.get("dtype") == "bfloat16"
            else HW["peak_flops_f32"])
    return {
        "name": path.split("/")[-1].replace(".json", ""),
        "compute_s": la["flops"] / peak,
        "memory_s": la["traffic_bytes"] / HW["hbm_bw"],
        "collective_s": la["collective_total"] / HW["ici_bw"],
        "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
    }


def main():
    rows = [row(p) for p in sys.argv[1:]]
    base = rows[0]
    print(f"{'variant':44s} {'compute':>9s} {'memory':>9s} {'coll':>9s} "
          f"{'step*':>9s} {'temp GB':>8s}")
    for r in rows:
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        base_step = max(base["compute_s"], base["memory_s"],
                        base["collective_s"])
        print(f"{r['name']:44s} {r['compute_s']:9.2f} {r['memory_s']:9.2f} "
              f"{r['collective_s']:9.2f} {step:9.2f} {r['temp_gb']:8.1f}"
              + (f"  ({base_step/step:.2f}x)" if r is not rows[0] else ""))


if __name__ == "__main__":
    main()
