"""Loop-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — under
scan-over-layers (mandatory at this scale: compile time must not grow with
depth) that undercounts FLOPs/bytes by the trip count (96x for nemotron).
Verified empirically: scan(length=2/4/8) of a matmul all report identical
flops.

This module parses the optimized HLO text (which carries
``backend_config={"known_trip_count":{"n":...}}`` on while ops) and
recursively accumulates, with loop multiplication:

- ``flops``      dot/convolution MACs x2 (exact from shapes + contracting
                 dims) plus 1 FLOP per output element of elementwise ops
                 inside fusions (the same convention HloCostAnalysis uses);
- ``traffic``    an HBM-traffic model: operand + result bytes at every
                 fusion/op boundary in non-fused computations (intra-fusion
                 values never touch HBM);
- ``collectives``  payload bytes per collective kind (all-reduce, all-gather,
                 reduce-scatter, all-to-all, collective-permute).

It is a *model*, not a simulator: good to ~2x, loop-exact, and consistent
across the optimization iterations in EXPERIMENTS.md §Perf (the deltas are
what drive decisions).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.+\{\s*$")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")


def _parse_op_line(line: str):
    """Parse '%name = TYPE opcode(operands), attrs' with balanced-paren
    tuple types (nested tuples broke a single-regex approach and silently
    dropped while ops)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):                   # tuple type: balance parens
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        typ = rest[:i + 1]
        tail = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        typ = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    m = _OPCODE_RE.match(tail)
    if not m:
        return None
    opcode = m.group(1)
    body = tail[m.end():]
    return name, typ, opcode, body
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute", "all-reduce-start",
                   "all-gather-start", "collective-permute-start",
                   "ragged-all-to-all"}

_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
                "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                "negate", "abs", "and", "or", "compare", "select", "cosine",
                "sine", "floor", "ceil", "sign", "atan2", "logistic",
                "exponential-minus-one", "log-plus-one", "clamp"}

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "opt-barrier"}


def shape_numel(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    type: str
    opcode: str
    rest: str           # everything after the '(' — operands + attributes


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, str]           # value name -> type string


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.strip().endswith("{"):
                cur = Computation(m.group(2), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            op = Op(*parsed)
            cur.ops.append(op)
            cur.symtab[op.name] = op.type
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        for k in self.collectives:
            self.collectives[k] += other.collectives[k] * mult

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_numel = shape_numel(op.type)
    mm = _OPERAND_RE.search(op.rest)
    k = 1
    if mm:
        lhs_type = symtab.get(mm.group(1), "")
        dims = _shape_dims(lhs_type)
        cm = _LHS_C_RE.search(op.rest)
        if cm and cm.group(1):
            for d in cm.group(1).split(","):
                di = int(d)
                if di < len(dims):
                    k *= dims[di]
    return 2.0 * out_numel * k


def _conv_flops(op: Op, symtab: Dict[str, str]) -> float:
    # flops ~= 2 * out_numel * (kernel elements per output / out features)
    ops = _OPERAND_RE.findall(op.rest)
    out_numel = shape_numel(op.type)
    if len(ops) >= 2:
        k_dims = _shape_dims(symtab.get(ops[1], ""))
        if k_dims:
            import numpy as np
            k_per_out = max(1, int(np.prod(k_dims)) // max(1, k_dims[-1]))
            return 2.0 * out_numel * k_per_out
    return 2.0 * out_numel


def _collective_kind(opcode: str) -> Optional[str]:
    base = opcode[:-6] if opcode.endswith("-start") else opcode
    if base == "ragged-all-to-all":
        base = "all-to-all"
    return base if base in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute") else None


def analyze(text: str, entry: Optional[str] = None) -> Cost:
    comps = parse_module(text)
    if entry is None:
        # the last computation in the module is ENTRY by convention; find by
        # name match from the module header instead
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = m.group(1) if m else list(comps)[-1]
    memo: Dict[Tuple[str, bool], Cost] = {}

    def comp_cost(name: str, fused: bool) -> Cost:
        key = (name, fused)
        if key in memo:
            return memo[key]
        memo[key] = Cost()                    # cycle guard
        c = comps.get(name)
        if c is None:
            return memo[key]
        total = Cost()
        for op in c.ops:
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            kind = _collective_kind(oc)
            if kind:
                b = shape_bytes(op.type)
                total.collectives[kind] += b
                total.traffic += b
                continue
            if oc == "while":
                bm = _BODY_RE.search(op.rest)
                tm = _TRIP_RE.search(op.rest)
                trips = int(tm.group(1)) if tm else 1
                if bm:
                    total.add(comp_cost(bm.group(1), False), trips)
                cm = _COND_RE.search(op.rest)
                if cm:
                    total.add(comp_cost(cm.group(1), False), trips)
                continue
            if oc == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    branches = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",")]
                    costs = [comp_cost(b, False) for b in branches]
                    if costs:
                        worst = max(costs, key=lambda x: x.flops + x.traffic)
                        total.add(worst)
                continue
            if oc in ("fusion", "call", "async-start", "custom-call"):
                cm = _CALLS_RE.search(op.rest) or (
                    _OPERAND_RE.search(op.rest) if oc == "call" else None)
                callee_name = cm.group(1) if cm else None
                if oc == "fusion" and callee_name:
                    inner = comp_cost(callee_name, True)
                    total.flops += inner.flops
                    # only fusion-boundary bytes touch HBM
                elif callee_name and oc == "call":
                    total.add(comp_cost(callee_name, fused))
                if not fused:
                    kind_f = (_fusion_kind(callee_name)
                              if oc == "fusion" else "general")
                    if kind_f == "convert":
                        # pure dtype-convert fusion: an XLA:CPU artifact
                        # around bf16 dots (TPU MXUs consume bf16 natively
                        # and fold the convert) — no HBM traffic on target
                        continue
                    total.traffic += shape_bytes(op.type)
                    if kind_f == "layout":
                        # transpose/copy-only fusion: one pass, not
                        # result+operands
                        continue
                    names = _operand_names(op)
                    sliced = (_fusion_sliced_reads(callee_name)
                              if oc == "fusion" else {})
                    for i, nm in enumerate(names):
                        if i in sliced:
                            # operand is only dynamic-sliced/gathered inside
                            # the fusion: HBM reads the windows, not the
                            # buffer (scan xs / stacked params would
                            # otherwise be counted in full on every trip)
                            total.traffic += sliced[i]
                        else:
                            total.traffic += shape_bytes(
                                c.symtab.get(nm, ""))
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, c.symtab)
                if not fused:
                    total.traffic += shape_bytes(op.type)
                    for nm in _operand_names(op):
                        total.traffic += shape_bytes(c.symtab.get(nm, ""))
                continue
            if oc == "convolution":
                total.flops += _conv_flops(op, c.symtab)
                if not fused:
                    total.traffic += shape_bytes(op.type)
                    for nm in _operand_names(op):
                        total.traffic += shape_bytes(c.symtab.get(nm, ""))
                continue
            if oc in _ELEMENTWISE or oc in ("reduce", "scatter", "gather",
                                            "select-and-scatter"):
                total.flops += shape_numel(op.type)
            if not fused:
                if oc in ("dynamic-slice", "gather", "slice"):
                    # reads only the sliced window, not the operand buffer
                    # (layer-scan param stacks would otherwise be counted
                    # in full on every trip: 96x overcount for nemotron)
                    total.traffic += 2 * shape_bytes(op.type)
                elif oc in ("dynamic-update-slice", "scatter"):
                    # in-place window write: count the update read + write
                    names = _operand_names(op)
                    upd = (shape_bytes(c.symtab.get(names[1], ""))
                           if len(names) > 1 else shape_bytes(op.type))
                    total.traffic += 2 * upd
                else:
                    total.traffic += shape_bytes(op.type)
                    for nm in _operand_names(op):
                        total.traffic += shape_bytes(c.symtab.get(nm, ""))
        memo[key] = total
        return total

    def _operand_names(op: Op) -> List[str]:
        # operands appear before the first '),' — attributes come after
        paren = op.rest.split(")")[0]
        return _OPERAND_RE.findall(paren)

    slice_memo: Dict[str, Dict[int, float]] = {}
    kind_memo: Dict[str, str] = {}

    def _fusion_kind(callee: Optional[str]) -> str:
        """'convert' (dtype cast only), 'layout' (transpose/copy/reshape
        only), or 'general'."""
        if callee is None or callee not in comps:
            return "general"
        if callee in kind_memo:
            return kind_memo[callee]
        ops_set = {op.opcode for op in comps[callee].ops} - _FREE_OPS
        if ops_set <= {"convert"}:
            kind = "convert"
        elif ops_set <= {"convert", "transpose", "copy", "reshape",
                         "broadcast", "slice"}:
            kind = "layout"
        else:
            kind = "general"
        kind_memo[callee] = kind
        return kind

    def _fusion_sliced_reads(callee: Optional[str]) -> Dict[int, float]:
        """For each parameter index of a fused computation that is ONLY
        consumed by windowing ops (dynamic-slice/gather/slice), the bytes
        those windows actually read."""
        if callee is None or callee not in comps:
            return {}
        if callee in slice_memo:
            return slice_memo[callee]
        c = comps[callee]
        param_of = {}                    # value name -> param index
        for op in c.ops:
            if op.opcode == "parameter":
                mm = re.match(r"\s*(\d+)\)", op.rest)
                if mm:
                    param_of[op.name] = int(mm.group(1))
        uses: Dict[int, List] = {i: [] for i in param_of.values()}
        ok: Dict[int, bool] = {i: True for i in param_of.values()}
        for op in c.ops:
            if op.opcode == "parameter":
                continue
            paren = op.rest.split(")")[0]
            names = _OPERAND_RE.findall(paren)
            for pos, nm in enumerate(names):
                if nm in param_of:
                    i = param_of[nm]
                    if op.opcode in ("dynamic-slice", "gather", "slice"):
                        uses[i].append(shape_bytes(op.type))
                    elif op.opcode == "dynamic-update-slice" and pos == 0:
                        # window write into the buffer (aliased in place):
                        # HBM cost = the update window, not the buffer
                        upd = (c.symtab.get(names[1], "")
                               if len(names) > 1 else op.type)
                        uses[i].append(shape_bytes(upd))
                    else:
                        ok[i] = False
        out = {i: float(sum(us)) for i, us in uses.items()
               if ok.get(i) and us}
        slice_memo[callee] = out
        return out

    return comp_cost(entry, False)
