"""Re-run the loop-aware HLO analysis over stored .hlo.zst artifacts and
refresh the loop_aware block of each dry-run JSON — analyzer improvements
don't require recompiling the sweep.

    python -m repro.analysis.reanalyze [--save-dir runs/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import zstandard

from .hloparse import analyze


def reanalyze(save_dir: str = "runs/dryrun") -> int:
    n = 0
    for jf in sorted(glob.glob(os.path.join(save_dir, "*", "*.json"))):
        hf = jf.replace(".json", ".hlo.zst")
        if not os.path.exists(hf):
            continue
        with open(hf, "rb") as f:
            text = zstandard.ZstdDecompressor().decompress(f.read()).decode()
        cost = analyze(text)
        with open(jf) as f:
            rec = json.load(f)
        rec["loop_aware"] = {
            "flops": cost.flops,
            "traffic_bytes": cost.traffic,
            "collective_bytes": cost.collectives,
            "collective_total": cost.collective_total,
        }
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    return n


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--save-dir", default="runs/dryrun")
    args = ap.parse_args()
    print(f"reanalyzed {reanalyze(args.save_dir)} records")
