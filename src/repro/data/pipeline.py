"""Deterministic synthetic data pipeline.

Produces token (or stub-embedding) batches that are:
- *deterministic in (seed, step)* — restart-safe: the iterator's checkpoint
  is just the integer step (fault tolerance requirement; the checkpoint
  manager stores it alongside the params);
- *host-shardable* — each host materialises only its slice of the global
  batch (``host_slice``), matching multi-host jax.Array construction;
- *structured* — a Zipf-ish unigram mix plus shifted-copy structure so a
  model can actually reduce loss (the overfit test and the end-to-end
  example both rely on that signal).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class Prefetcher:
    """Bounded background prefetch over any iterator.

    A daemon thread pulls items from ``src`` into a bounded queue of
    ``depth`` slots (2 = double buffering), so consumers overlap their own
    work with the producer's assembly cost — the serving executor's H2D
    staging stage (:mod:`repro.serve.spectral.executor`) and the training
    batch iterator both sit on this.  Order is preserved; a producer
    exception is re-raised at the consumer's ``next()`` (not swallowed on
    the thread); ``close()`` stops the producer promptly even when the
    queue is full.

    ``threaded=False`` is the injectable test mode: a plain synchronous
    passthrough with the identical interface, so pipeline tests can assert
    behaviour deterministically without thread scheduling in the loop.
    """

    _DONE = object()

    def __init__(self, src: Iterable, *, depth: int = 2,
                 threaded: bool = True):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._src = iter(src)
        self._threaded = threaded
        self._closed = False
        if not threaded:
            return
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._thread = threading.Thread(target=self._produce, daemon=True,
                                        name="repro-prefetch")
        self._thread.start()

    def _produce(self) -> None:
        try:
            for item in self._src:
                while not self._closed:
                    try:
                        self._q.put(("item", item), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._closed:
                    return
            self._q.put((None, self._DONE))
        except BaseException as e:  # noqa: BLE001 — re-raised at next()
            self._q.put(("error", e))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if not self._threaded:
            if self._closed:
                raise StopIteration
            return next(self._src)
        kind, item = self._q.get()
        if item is self._DONE:
            raise StopIteration
        if kind == "error":
            raise item
        return item

    def close(self) -> None:
        """Stop prefetching; the producer thread exits at its next put."""
        self._closed = True
        if self._threaded:
            while True:             # unblock a full-queue producer
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_offset: int = 3             # learnable structure: x[t] ~ x[t-offset]
    copy_prob: float = 0.7


class SyntheticLM:
    """Deterministic (seed, step) -> batch generator."""

    def __init__(self, dcfg: DataConfig, mcfg: ModelConfig):
        self.dcfg = dcfg
        self.mcfg = mcfg

    def batch_at(self, step: int, host_id: int = 0, num_hosts: int = 1):
        d, m = self.dcfg, self.mcfg
        per_host = d.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, host_id]))
        v = m.vocab_size
        # Zipf-ish unigram draw
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(per_host, d.seq_len + 1), p=probs)
        # inject copy structure
        copy_mask = rng.random((per_host, d.seq_len + 1)) < d.copy_prob
        idx = np.arange(d.seq_len + 1)
        src = np.clip(idx - d.copy_offset, 0, None)
        toks = np.where(copy_mask, toks[:, src], toks)
        tokens = jnp.asarray(toks[:, :-1], jnp.int32)
        labels = jnp.asarray(toks[:, 1:], jnp.int32)
        if m.input_mode == "embeddings":
            # stub modality frontend: deterministic random projections of
            # the token stream stand in for patch/frame embeddings
            emb_rng = np.random.default_rng(
                np.random.SeedSequence([d.seed, step, host_id, 7]))
            embeds = emb_rng.standard_normal(
                (per_host, d.seq_len, m.d_model)).astype(np.float32)
            return {"embeds": jnp.asarray(embeds, jnp.dtype(m.dtype)),
                    "labels": labels}
        return {"tokens": tokens, "labels": labels}

    def iter_batches(self, start_step: int = 0, *, num_steps: int = None,
                     host_id: int = 0, num_hosts: int = 1,
                     prefetch_depth: int = 2,
                     threaded: bool = True) -> Prefetcher:
        """Streaming batch iterator with bounded background prefetch.

        Yields ``(step, batch)`` pairs from ``start_step`` (restart-safe:
        resume by passing the checkpointed step).  Batch assembly — the
        numpy Zipf draw plus copy-structure injection in :meth:`batch_at` —
        runs on the prefetch thread, overlapped with the consumer's device
        step, instead of synchronously on the training loop's critical
        path.  ``threaded=False`` degrades to a synchronous passthrough
        (deterministic tests)."""
        def gen():
            step = start_step
            while num_steps is None or step < start_step + num_steps:
                yield step, self.batch_at(step, host_id=host_id,
                                          num_hosts=num_hosts)
                step += 1
        return Prefetcher(gen(), depth=prefetch_depth, threaded=threaded)

    def checkpoint_state(self, step: int) -> dict:
        return {"step": step, "seed": self.dcfg.seed}

    @staticmethod
    def restore_step(state: dict) -> int:
        return int(state["step"])


def make_batch_specs(mcfg: ModelConfig, seq_len: int, global_batch: int,
                     dtype=None):
    """ShapeDtypeStruct stand-ins for one training batch (dry-run path)."""
    dtype = dtype or jnp.dtype(mcfg.dtype)
    labels = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    if mcfg.input_mode == "embeddings":
        return {"embeds": jax.ShapeDtypeStruct(
                    (global_batch, seq_len, mcfg.d_model), dtype),
                "labels": labels}
    return {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len),
                                           jnp.int32),
            "labels": labels}
