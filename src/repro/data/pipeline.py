"""Deterministic synthetic data pipeline.

Produces token (or stub-embedding) batches that are:
- *deterministic in (seed, step)* — restart-safe: the iterator's checkpoint
  is just the integer step (fault tolerance requirement; the checkpoint
  manager stores it alongside the params);
- *host-shardable* — each host materialises only its slice of the global
  batch (``host_slice``), matching multi-host jax.Array construction;
- *structured* — a Zipf-ish unigram mix plus shifted-copy structure so a
  model can actually reduce loss (the overfit test and the end-to-end
  example both rely on that signal).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_offset: int = 3             # learnable structure: x[t] ~ x[t-offset]
    copy_prob: float = 0.7


class SyntheticLM:
    """Deterministic (seed, step) -> batch generator."""

    def __init__(self, dcfg: DataConfig, mcfg: ModelConfig):
        self.dcfg = dcfg
        self.mcfg = mcfg

    def batch_at(self, step: int, host_id: int = 0, num_hosts: int = 1):
        d, m = self.dcfg, self.mcfg
        per_host = d.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, host_id]))
        v = m.vocab_size
        # Zipf-ish unigram draw
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(per_host, d.seq_len + 1), p=probs)
        # inject copy structure
        copy_mask = rng.random((per_host, d.seq_len + 1)) < d.copy_prob
        idx = np.arange(d.seq_len + 1)
        src = np.clip(idx - d.copy_offset, 0, None)
        toks = np.where(copy_mask, toks[:, src], toks)
        tokens = jnp.asarray(toks[:, :-1], jnp.int32)
        labels = jnp.asarray(toks[:, 1:], jnp.int32)
        if m.input_mode == "embeddings":
            # stub modality frontend: deterministic random projections of
            # the token stream stand in for patch/frame embeddings
            emb_rng = np.random.default_rng(
                np.random.SeedSequence([d.seed, step, host_id, 7]))
            embeds = emb_rng.standard_normal(
                (per_host, d.seq_len, m.d_model)).astype(np.float32)
            return {"embeds": jnp.asarray(embeds, jnp.dtype(m.dtype)),
                    "labels": labels}
        return {"tokens": tokens, "labels": labels}

    def checkpoint_state(self, step: int) -> dict:
        return {"step": step, "seed": self.dcfg.seed}

    @staticmethod
    def restore_step(state: dict) -> int:
        return int(state["step"])


def make_batch_specs(mcfg: ModelConfig, seq_len: int, global_batch: int,
                     dtype=None):
    """ShapeDtypeStruct stand-ins for one training batch (dry-run path)."""
    dtype = dtype or jnp.dtype(mcfg.dtype)
    labels = jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)
    if mcfg.input_mode == "embeddings":
        return {"embeds": jax.ShapeDtypeStruct(
                    (global_batch, seq_len, mcfg.d_model), dtype),
                "labels": labels}
    return {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len),
                                           jnp.int32),
            "labels": labels}
