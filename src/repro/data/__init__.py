"""repro.data — deterministic, checkpointable, host-sharded data pipeline."""
from .pipeline import DataConfig, Prefetcher, SyntheticLM, make_batch_specs
