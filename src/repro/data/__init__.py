"""repro.data — deterministic, checkpointable, host-sharded data pipeline."""
from .pipeline import DataConfig, SyntheticLM, make_batch_specs
