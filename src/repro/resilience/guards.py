"""Cheap numerical integrity checks for FFT executions.

Three guards, ordered by cost:

- :func:`finite_check` — NaN/Inf scan over every output plane.  O(size)
  elementwise + reduce; this is the whole of ``guard_level="basic"`` and
  what the ≤5% overhead pin in BENCH_resilience.json measures.
- :func:`parseval_ratio` — Parseval's theorem as a checksum: the output
  spectrum's energy must equal ``N ×`` the input energy (direction- and
  kind-aware).  Catches corruption that stays finite (a scaled block, a
  zeroed payload) for two extra reductions.
- :func:`hermitian_residual` — rfft outputs only: the DC/Nyquist bins of a
  real transform are exactly real (1-D), and the DC/Nyquist *columns* of a
  2-D half spectrum are Hermitian along the column axis.  A structural
  check no energy checksum can see (e.g. conjugation errors).

All guards are **eager-only** — they read concrete values — which is why
the guarded executor only engages outside of traced code.  Tolerances come
from :mod:`repro.resilience.config` (fp32 vs low-precision dtypes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.complexmath import SplitComplex
from . import config

_EPS = 1e-30


class GuardViolation(RuntimeError):
    """An execution produced output that failed an integrity check."""

    def __init__(self, report: "GuardReport"):
        self.report = report
        super().__init__(f"guard violation: {report.reason} "
                         f"(checks: {report.checks})")


@dataclasses.dataclass
class GuardReport:
    ok: bool
    checks: dict                    # name -> measured value
    reason: Optional[str] = None    # first failing check, None when ok


def _planes(y):
    if isinstance(y, SplitComplex):
        return (y.re, y.im)
    return (y,)


def _energy(y) -> jnp.ndarray:
    """Sum of squared magnitudes over every plane, accumulated in fp32."""
    return sum(jnp.sum(jnp.square(p.astype(jnp.float32)))
               for p in _planes(y))


def _half_axis_energy(y: SplitComplex) -> jnp.ndarray:
    """Full-spectrum energy recovered from a half spectrum whose *last*
    axis holds bins 0..n/2: interior bins count twice (their Hermitian
    mirrors), DC and Nyquist once."""
    full = 2.0 * _energy(y)
    ends = (_energy(SplitComplex(y.re[..., 0], y.im[..., 0]))
            + _energy(SplitComplex(y.re[..., -1], y.im[..., -1])))
    return full - ends


@jax.jit
def _all_finite(planes):
    acc = None
    for p in planes:
        ok = jnp.isfinite(p).all()
        acc = ok if acc is None else acc & ok
    return acc


def finite_check(y) -> bool:
    # one fused jit dispatch: the basic guard sits on the eager hot path
    # of every pallas execution, so per-op dispatch overhead (not the
    # O(size) scan itself) is what the <=5% overhead budget is spent on
    return bool(_all_finite(tuple(_planes(y))))


def parseval_ratio(plan, x, y) -> float:
    """Energy ratio (expected 1.0) between output and input of one plan
    execution, with the transform's 1/N scalings folded in.  Returns 1.0
    when the input energy is ~0 (nothing to compare against).  conv-kind
    plans have no input→output energy identity (the filter reshapes the
    spectrum arbitrarily), so the check is vacuously 1.0 for them —
    conv executions are covered by the finite scan."""
    if plan.kind.startswith("conv"):
        return 1.0
    n = 1
    for d in plan.shape:
        n *= int(d)
    if plan.kind == "rfft":
        if plan.inverse:     # half spectrum in -> real out
            e_in, e_out = _half_axis_energy(x), _energy(y) * n
        else:                # real in -> half spectrum out
            e_in, e_out = _energy(x) * n, _half_axis_energy(y)
    elif plan.inverse:       # c2c inverse carries the 1/N scaling
        e_in, e_out = _energy(x), _energy(y) * n
    else:
        e_in, e_out = _energy(x) * n, _energy(y)
    e_in, e_out = float(e_in), float(e_out)
    if e_in < _EPS:
        return 1.0
    return e_out / e_in


def hermitian_residual(plan, y) -> float:
    """rfft *forward* outputs: relative residual of the real-transform
    symmetry constraints (0.0 = exactly symmetric).  Returns 0.0 for plans
    the check does not apply to."""
    if plan.kind != "rfft" or plan.inverse:
        return 0.0
    scale = float(max(float(jnp.max(jnp.abs(p))) for p in _planes(y)))
    if scale < _EPS:
        return 0.0
    if plan.ndim == 1:       # DC and Nyquist bins are exactly real
        res = jnp.maximum(jnp.max(jnp.abs(y.im[..., 0])),
                          jnp.max(jnp.abs(y.im[..., -1])))
        return float(res) / scale
    # 2-D (..., h, w/2+1): the DC (c=0) and Nyquist (c=-1) columns are the
    # rffts of real column signals -> Hermitian along the h axis
    h = y.shape[-2]
    idx = (-jnp.arange(h)) % h
    res = 0.0
    for c in (0, -1):
        cr, ci = y.re[..., :, c], y.im[..., :, c]
        res = max(res,
                  float(jnp.max(jnp.abs(cr - jnp.take(cr, idx, axis=-1)))),
                  float(jnp.max(jnp.abs(ci + jnp.take(ci, idx, axis=-1)))))
    return res / scale


def _is_lowp(dtype) -> bool:
    return jnp.dtype(dtype).itemsize < 4


def check_output(plan, x, y, level: Optional[str] = None) -> GuardReport:
    """Run the guard stack for one eager execution of ``plan`` on input
    ``x`` producing ``y``.  ``level`` defaults to the configured
    ``guard_level``."""
    level = level if level is not None else config.get("guard_level")
    if level == "off":
        return GuardReport(ok=True, checks={})
    checks: dict = {}
    finite = finite_check(y)
    checks["finite"] = finite
    if not finite:
        return GuardReport(ok=False, checks=checks,
                           reason="non-finite output (NaN/Inf scan)")
    if level == "basic":
        return GuardReport(ok=True, checks=checks)
    tol = config.get("parseval_tol_lowp") if _is_lowp(plan.dtype) \
        else config.get("parseval_tol")
    ratio = parseval_ratio(plan, x, y)
    checks["parseval_ratio"] = ratio
    if abs(ratio - 1.0) > tol:
        return GuardReport(ok=False, checks=checks,
                           reason=f"Parseval energy ratio {ratio:.6g} "
                                  f"outside 1±{tol:g}")
    herm = hermitian_residual(plan, y)
    checks["hermitian_residual"] = herm
    htol = config.get("hermitian_tol_lowp") if _is_lowp(plan.dtype) \
        else config.get("hermitian_tol")
    if herm > htol:
        return GuardReport(ok=False, checks=checks,
                           reason=f"Hermitian residual {herm:.6g} > {htol:g}")
    return GuardReport(ok=True, checks=checks)
