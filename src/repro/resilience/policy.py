"""Per-plan-key circuit breaker: demote a failing kernel path at runtime,
probe it back to health.

One :class:`CircuitBreaker` exists per pallas plan key that has ever failed
a guarded execution.  Lifecycle (all transitions counted in *calls*, never
wall time, so tests are deterministic):

- ``closed``     normal operation; ``failure_threshold`` *consecutive*
                 guarded failures open the circuit.
- ``open``       every execution short-circuits to the key's jnp schedule
                 (the registry entry itself is demoted with
                 ``demote_reason="runtime_circuit_open"`` so the state is
                 visible to anyone holding — or fetching — the plan).
                 After ``cooldown_calls`` short-circuited calls the
                 breaker goes half-open.
- ``half_open``  the next execution is a *probe* on the original pallas
                 plan: success closes the circuit and re-promotes the
                 registry entry; failure re-opens it (cooldown restarts).

The breaker registry here is pure state machine; the guarded executor
(:mod:`repro.resilience.executor`) drives it and performs the actual
registry demotion/restoration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from . import config

RUNTIME_DEMOTE_REASON = "runtime_circuit_open"

STATES = ("closed", "open", "half_open")


@dataclasses.dataclass
class CircuitBreaker:
    key: tuple                       # the pallas plan key this guards
    original_plan: object            # the healthy pallas FFTPlan to restore
    state: str = "closed"
    consecutive_failures: int = 0
    open_calls: int = 0              # short-circuited calls while open
    failures: int = 0                # lifetime counters (introspection)
    successes: int = 0
    probes: int = 0
    transitions: List[str] = dataclasses.field(default_factory=list)

    def _move(self, state: str) -> None:
        self.state = state
        self.transitions.append(state)

    def allow_attempt(self) -> bool:
        """May this call try the pallas path?  ``open`` counts the call
        toward the cooldown and answers False until the half-open probe
        is due."""
        if self.state == "closed":
            return True
        if self.state == "half_open":
            return True
        self.open_calls += 1
        if self.open_calls >= config.get("cooldown_calls"):
            self._move("half_open")
            return True
        return False

    def record_success(self) -> bool:
        """Returns True when this success *closed* a non-closed circuit
        (the executor must then re-promote the registry entry)."""
        self.successes += 1
        self.consecutive_failures = 0
        if self.state in ("half_open", "open"):
            self.probes += 1
            self._move("closed")
            self.open_calls = 0
            return True
        return False

    def record_failure(self) -> bool:
        """Returns True when this failure *opened* a closed/half-open
        circuit (the executor must then demote the registry entry)."""
        self.failures += 1
        self.consecutive_failures += 1
        if self.state == "half_open":
            self.probes += 1
            self._move("open")
            self.open_calls = 0
            return True
        if (self.state == "closed"
                and self.consecutive_failures
                >= config.get("failure_threshold")):
            self._move("open")
            self.open_calls = 0
            return True
        return False


_BREAKERS: Dict[tuple, CircuitBreaker] = {}


def breaker(key: tuple, *, create: bool = False,
            original_plan=None) -> Optional[CircuitBreaker]:
    br = _BREAKERS.get(key)
    if br is None and create:
        br = CircuitBreaker(key=key, original_plan=original_plan)
        _BREAKERS[key] = br
    return br


def breaker_state(key: tuple) -> Optional[str]:
    br = _BREAKERS.get(key)
    return None if br is None else br.state


def all_breakers() -> Dict[tuple, CircuitBreaker]:
    return dict(_BREAKERS)


def reset() -> None:
    _BREAKERS.clear()
