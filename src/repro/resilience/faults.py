"""Deterministic, seeded fault injection at named sites.

A :class:`FaultPlan` is a list of :class:`FaultSpec` triggers plus a seeded
RNG; installing it (``with plan:`` or :func:`inject`) arms the named sites
that production code consults through the cheap module-level hooks
(:func:`check` / :func:`corrupt` / :func:`scaled`).  With no plan installed
every hook is a near-free no-op (one global ``is None`` test), so the
instrumented hot paths cost nothing in normal operation.

Sites are plain strings; the ones instrumented across the repo:

=====================  =====================================================
site                   where it fires / kinds that make sense there
=====================  =====================================================
``plan.execute``       guarded executor, before a pallas kernel launch
                       (``kind="error"`` = kernel-launch failure)
``plan.output``        guarded executor, on a pallas kernel's output
                       (``kind="nan"/"inf"/"corrupt"`` = bad numerics)
``autotune.measure``   inside each autotune candidate measurement
                       (``kind="hang"`` = a candidate that never returns;
                       ``duration`` = seconds it stalls)
``dist.exchange``      :mod:`repro.dist.pencil` after each all_to_all
                       (``kind="drop"/"corrupt"/"nan"`` = a lost or
                       mangled payload on one device)
``wisdom.save``        mid-write inside :func:`repro.core.plan.save_wisdom`
                       (``kind="error"`` = crash leaving a torn temp file)
``serve.prewarm``      :class:`repro.serve.engine.Engine` plan pre-warm
                       (``kind="error"``)
``serve.step``         every engine decode tick (``kind="hang"`` — drives
                       the per-request deadline path)
``straggler.times``    test harnesses perturbing gossip timings
                       (``kind="slow"``, ``scale`` = slowdown factor)
=====================  =====================================================

Determinism: every spec fires on an explicit visit schedule — skip the
first ``after`` matching visits, then fire up to ``times`` times (``None``
= unlimited), each firing additionally gated by ``prob`` drawn from the
plan's seeded ``numpy`` generator.  Two runs with the same plan, seed and
call sequence inject the identical faults, which is what lets the
fault-sweep benchmark assert "detected and recovered" instead of eyeballing
flakes.  Every firing is appended to ``plan.log`` for assertions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

KINDS = ("error", "nan", "inf", "drop", "corrupt", "hang", "slow")


class FaultInjected(RuntimeError):
    """Raised by ``kind="error"`` firings (a simulated hard failure)."""

    def __init__(self, site: str, tag: Optional[str] = None):
        self.site, self.tag = site, tag
        super().__init__(f"injected fault at site {site!r}"
                         + (f" (tag {tag!r})" if tag else ""))


@dataclasses.dataclass
class FaultSpec:
    site: str
    kind: str
    prob: float = 1.0          # firing probability per eligible visit
    times: Optional[int] = 1   # max firings (None = every eligible visit)
    after: int = 0             # skip this many matching visits first
    duration: float = 0.0      # kind="hang": seconds to stall
    scale: float = 8.0         # kind="slow"/"corrupt": perturbation factor
    tag: Optional[str] = None  # only visits whose tag contains this fire
    seen: int = 0              # matching visits so far (mutable counter)
    fired: int = 0             # firings so far (mutable counter)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, "
                             f"got {self.kind!r}")


class FaultPlan:
    """A seeded set of fault triggers, installable as a context manager."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.specs: List[FaultSpec] = []
        self.log: List[dict] = []

    def add(self, site: str, kind: str, **kw) -> "FaultPlan":
        self.specs.append(FaultSpec(site=site, kind=kind, **kw))
        return self

    # -- site consultation ---------------------------------------------------

    def _fire(self, site: str, tag: Optional[str]) -> Optional[FaultSpec]:
        for spec in self.specs:
            if spec.site != site:
                continue
            if spec.tag is not None and spec.tag not in (tag or ""):
                continue
            spec.seen += 1
            if spec.seen <= spec.after:
                continue
            if spec.times is not None and spec.fired >= spec.times:
                continue
            if spec.prob < 1.0 and self.rng.random() >= spec.prob:
                continue
            spec.fired += 1
            self.log.append({"site": site, "tag": tag, "kind": spec.kind,
                             "firing": spec.fired})
            return spec
        return None

    def fired(self, site: Optional[str] = None) -> int:
        """Total firings so far (optionally of one site)."""
        return sum(1 for e in self.log if site is None or e["site"] == site)

    # -- installation --------------------------------------------------------

    def __enter__(self) -> "FaultPlan":
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("a FaultPlan is already installed "
                               "(nesting is not supported)")
        _ACTIVE = self
        return self

    def __exit__(self, *exc) -> None:
        global _ACTIVE
        _ACTIVE = None


_ACTIVE: Optional[FaultPlan] = None


def inject(site: str, kind: str, *, seed: int = 0, **kw) -> FaultPlan:
    """One-liner for the single-fault case::

        with faults.inject("plan.execute", "error"):
            ...
    """
    return FaultPlan(seed=seed).add(site, kind, **kw)


def active() -> Optional[FaultPlan]:
    return _ACTIVE


def fire(site: str, tag: Optional[str] = None) -> Optional[FaultSpec]:
    """Consult a site: returns the firing spec, or None (the fast path)."""
    if _ACTIVE is None:
        return None
    return _ACTIVE._fire(site, tag)


def check(site: str, tag: Optional[str] = None) -> None:
    """Raise/stall sites: ``error`` raises :class:`FaultInjected`,
    ``hang`` sleeps ``duration`` seconds; other kinds are ignored here."""
    spec = fire(site, tag)
    if spec is None:
        return
    if spec.kind == "error":
        raise FaultInjected(site, tag)
    if spec.kind == "hang":
        time.sleep(spec.duration)


def scaled(site: str, value: float, tag: Optional[str] = None) -> float:
    """``slow`` sites: returns ``value * scale`` when the fault fires."""
    spec = fire(site, tag)
    if spec is not None and spec.kind == "slow":
        return value * spec.scale
    return value


def corrupt(site: str, value, tag: Optional[str] = None):
    """Array-corruption sites: perturb ``value`` (an ndarray or a
    SplitComplex) when a ``nan``/``inf``/``corrupt``/``drop`` spec fires."""
    spec = fire(site, tag)
    if spec is None:
        return value
    return apply_corruption(value, spec)


def apply_corruption(value, spec: FaultSpec):
    """Deterministically mangle ``value`` per ``spec.kind``:

    - ``nan``/``inf``: poison the first element of every component plane;
    - ``corrupt``: scale-and-shift every element (energy-visible);
    - ``drop``: replace the payload with zeros (a lost message).
    """
    import jax.numpy as jnp
    from repro.core.complexmath import SplitComplex

    def one(a):
        if spec.kind == "nan":
            return a.ravel().at[0].set(jnp.nan).reshape(a.shape)
        if spec.kind == "inf":
            return a.ravel().at[0].set(jnp.inf).reshape(a.shape)
        if spec.kind == "corrupt":
            return a * spec.scale + 1.0
        if spec.kind == "drop":
            return jnp.zeros_like(a)
        raise ValueError(f"kind {spec.kind!r} is not an array corruption")

    if isinstance(value, SplitComplex):
        return SplitComplex(one(value.re), one(value.im))
    return one(value)
