"""The guarded executor: every :class:`repro.core.plan.FFTPlan` call routes
through :func:`execute`.

Behaviour matrix:

- **Traced input or resilience disabled** — raw execution, byte-identical
  to the pre-resilience path.  Guards read concrete values, so code running
  under ``jit``/``shard_map`` (the autotuner's measured candidates, the
  pencil bodies, the serve decode step) is never taxed or altered; the
  distributed layer has its own in-graph checksum story
  (:mod:`repro.dist.pencil`).
- **Eager pallas execution** — consult the key's circuit breaker, then
  attempt the kernel inside a try/guard: a raised kernel failure
  (including the injected ``plan.execute`` site) or a guard violation on
  the output (``plan.output`` corruption, NaN/Inf, energy mismatch)
  records a breaker failure and falls back to the key's **jnp schedule**
  for this call — the caller still gets a correct result.  After
  ``failure_threshold`` consecutive failures the breaker opens and the
  registry entry itself is demoted
  (``demote_reason="runtime_circuit_open"``); cooldown and half-open
  probing re-promote it once the kernel path behaves again.
- **Eager jnp execution** — raw (plus the basic guard when ``guard_jnp``
  is configured); a runtime-demoted entry still drives its breaker so the
  half-open probe happens even for callers that fetched the plan *after*
  demotion.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.core.complexmath import SplitComplex
from . import config, faults, guards, policy
from .guards import GuardViolation
from .policy import RUNTIME_DEMOTE_REASON

_STATS: Dict[tuple, dict] = {}


def _stat(key: tuple) -> dict:
    st = _STATS.get(key)
    if st is None:
        st = _STATS[key] = {"attempts": 0, "failures": 0, "fallbacks": 0,
                            "short_circuits": 0, "last_reason": None}
    return st


def stats(key: Optional[tuple] = None):
    """Per-pallas-key executor counters (all keys when ``key`` is None)."""
    return dict(_STATS) if key is None else dict(_stat(key))


def reset() -> None:
    """Clear executor stats AND breaker state, and restore any
    runtime-demoted registry entries (test isolation)."""
    from repro.core import plan as plan_mod
    for key, br in policy.all_breakers().items():
        if br.state != "closed":
            plan_mod._runtime_restore(key, br.original_plan)
    policy.reset()
    _STATS.clear()


def _has_tracer(*operands) -> bool:
    leaves = []
    for x in operands:
        leaves.extend((x.re, x.im) if isinstance(x, SplitComplex) else (x,))
    return any(isinstance(l, jax.core.Tracer) for l in leaves)


def _label(plan) -> str:
    shp = "x".join(map(str, plan.shape))
    return f"{plan.backend}/{plan.algo}/{shp}"


def _pallas_key(plan_mod, plan) -> tuple:
    return plan_mod._plan_key(plan.shape, plan.dtype, plan.inverse,
                              "pallas", plan.kind)


def execute(plan, x, *args):
    """Entry point: ``FFTPlan.__call__`` delegates here.  conv-kind plans
    carry the filter half spectrum as an extra operand (``*args``), which
    rides through attempt and fallback unchanged."""
    if not config.get("enabled") or _has_tracer(x, *args):
        return plan._execute(x, *args)
    from repro.core import plan as plan_mod
    if plan.backend == "pallas":
        key = _pallas_key(plan_mod, plan)
        br = policy.breaker(key)
        if br is None or br.allow_attempt():
            return _guarded_attempt(plan_mod, plan, x, key, args)
        st = _stat(key)
        st["short_circuits"] += 1
        return _fallback(plan_mod, plan, x, args)
    if plan.demote_reason == RUNTIME_DEMOTE_REASON:
        # a runtime-demoted registry entry: the breaker still owns this
        # key, so cooldown ticks and half-open probes run from here too
        key = _pallas_key(plan_mod, plan)
        br = policy.breaker(key)
        if br is not None and br.state != "closed":
            if br.allow_attempt():
                return _guarded_attempt(plan_mod, br.original_plan, x, key,
                                        args)
            _stat(key)["short_circuits"] += 1
    y = plan._execute(x, *args)
    if config.get("guard_jnp"):
        rep = guards.check_output(plan, x, y, level="basic")
        if not rep.ok:
            raise GuardViolation(rep)
    return y


def _guarded_attempt(plan_mod, plan, x, key: tuple, args=()):
    """Try the pallas plan under guards; fall back to jnp on any failure."""
    st = _stat(key)
    st["attempts"] += 1
    try:
        faults.check("plan.execute", tag=_label(plan))
        y = plan._execute(x, *args)
        y = faults.corrupt("plan.output", y, tag=_label(plan))
        rep = guards.check_output(plan, x, y)
        if not rep.ok:
            raise GuardViolation(rep)
    except Exception as e:          # noqa: BLE001 — resilience boundary
        st["failures"] += 1
        st["last_reason"] = f"{type(e).__name__}: {e}"
        br = policy.breaker(key, create=True,
                            original_plan=plan_mod._PLAN_CACHE.get(key, plan))
        if br.record_failure():
            plan_mod._runtime_demote(key)
        st["fallbacks"] += 1
        return _fallback(plan_mod, plan, x, args)
    br = policy.breaker(key)
    if br is not None and br.record_success():
        plan_mod._runtime_restore(key, br.original_plan)
    return y


def _fallback(plan_mod, plan, x, args=()):
    """Execute the key's jnp schedule (guarded basic) for this call."""
    fb = plan_mod.get_plan(plan.shape, dtype=plan.dtype,
                           inverse=plan.inverse, kind=plan.kind,
                           backend="jnp")
    y = fb._execute(x, *args)
    rep = guards.check_output(fb, x, y, level="basic")
    if not rep.ok:
        # the fallback failed too: nothing left to recover with — report
        raise GuardViolation(rep)
    return y
