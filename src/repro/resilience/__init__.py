"""repro.resilience — fault injection, numerical guards, runtime fallback.

The production-hardening layer over the plan registry, serving engine and
distributed FFTs:

- :mod:`~repro.resilience.faults`    deterministic seeded fault injection
  at named sites (kernel launch/output, autotune measurement, pencil
  exchanges, wisdom writes, serve pre-warm/step).
- :mod:`~repro.resilience.guards`    cheap integrity checks: NaN/Inf scan,
  Parseval energy ratio, Hermitian symmetry of rfft outputs.
- :mod:`~repro.resilience.policy`    per-plan-key circuit breaker
  (closed -> open -> half-open), call-counted and deterministic.
- :mod:`~repro.resilience.executor`  the guarded executor every
  ``FFTPlan.__call__`` routes through: guard, retry on the jnp schedule,
  demote the registry key after repeated failures
  (``demote_reason="runtime_circuit_open"``), re-promote on probe success.
- :mod:`~repro.resilience.config`    the knobs (guard level, breaker
  thresholds, autotune watchdog timeout).
"""
from . import config, executor, faults, guards, policy  # noqa: F401
from .config import configure, overrides  # noqa: F401
from .faults import FaultInjected, FaultPlan, inject  # noqa: F401
from .guards import GuardReport, GuardViolation, check_output  # noqa: F401
from .policy import RUNTIME_DEMOTE_REASON, breaker_state  # noqa: F401


def reset() -> None:
    """Restore default config, clear breakers/stats, re-promote any
    runtime-demoted registry entries.  Tests call this for isolation."""
    executor.reset()
    config.reset()
