"""Resilience knobs: one process-wide settings dict, overridable per test.

Everything in :mod:`repro.resilience` reads its tunables from here so a
single ``configure(...)`` call (or the :func:`overrides` context manager in
tests) changes the behaviour of the guarded executor, the circuit breaker
and the autotune watchdog coherently.

Knobs
-----
enabled              master switch for the guarded executor; ``False``
                     restores the pre-resilience raw execution path.
guard_level          "off" | "basic" | "full".  "basic" is the default and
                     runs only the NaN/Inf scan on kernel-backed
                     executions (cheap — the ≤5% overhead pin in
                     BENCH_resilience.json is measured against it);
                     "full" adds the Parseval energy-ratio and
                     Hermitian-symmetry checks.
guard_jnp            also guard ``backend="jnp"`` executions (default off:
                     the pure-XLA path has no launch failure mode and the
                     scan would tax every eager call in the suite).
failure_threshold    consecutive guarded failures of a pallas key before
                     its circuit opens (K in the ISSUE's "after K guarded
                     failures ... demotes").
cooldown_calls       calls served by the jnp schedule while a circuit is
                     open before one half-open probe is allowed.  Counted
                     in calls, not wall time, so breaker tests are
                     deterministic.
parseval_tol         relative energy-ratio tolerance for fp32 plans.
parseval_tol_lowp    the same for sub-fp32 dtypes (bf16/f16 plans).
hermitian_tol        relative residual tolerance of the rfft symmetry
                     checks (fp32 plans).
hermitian_tol_lowp   the same for sub-fp32 dtypes: a *healthy* bf16
                     kernel's symmetry residual sits at the bf16
                     quantisation floor (~1e-2 relative), far above the
                     fp32 tolerance — without the dtype-aware knob every
                     healthy bf16 execution would count as a guard
                     failure and walk the circuit breaker into
                     ``runtime_circuit_open``.
measure_timeout_s    per-candidate autotune measurement watchdog (seconds);
                     ``None`` disables the watchdog thread entirely.
"""
from __future__ import annotations

import contextlib

GUARD_LEVELS = ("off", "basic", "full")

DEFAULTS = dict(
    enabled=True,
    guard_level="basic",
    guard_jnp=False,
    failure_threshold=3,
    cooldown_calls=4,
    parseval_tol=1e-3,
    parseval_tol_lowp=5e-2,
    hermitian_tol=1e-3,
    hermitian_tol_lowp=5e-2,
    measure_timeout_s=120.0,
)

_state = dict(DEFAULTS)


def get(key: str):
    return _state[key]


def configure(**kw) -> dict:
    """Update resilience knobs; unknown keys raise.  Returns the previous
    values of the keys that changed (handy for manual restore)."""
    bad = set(kw) - set(DEFAULTS)
    if bad:
        raise KeyError(f"unknown resilience option(s): {sorted(bad)}; "
                       f"valid: {sorted(DEFAULTS)}")
    if "guard_level" in kw and kw["guard_level"] not in GUARD_LEVELS:
        raise ValueError(f"guard_level must be one of {GUARD_LEVELS}, "
                         f"got {kw['guard_level']!r}")
    prev = {k: _state[k] for k in kw}
    _state.update(kw)
    return prev


def reset() -> None:
    _state.clear()
    _state.update(DEFAULTS)


@contextlib.contextmanager
def overrides(**kw):
    """Temporarily apply knobs (tests): restores prior values on exit."""
    prev = configure(**kw)
    try:
        yield
    finally:
        _state.update(prev)
