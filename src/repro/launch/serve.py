"""Serving driver: batched requests through the slot-based engine.

``python -m repro.launch.serve --arch h2o-danube-1.8b --reduced`` serves a
reduced model with synthetic prompts on local devices.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import numpy as np
    import repro.configs as C
    from repro.models import model as M
    from repro.serve.engine import Engine, ServeConfig

    cfg = C.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, ServeConfig(batch_size=args.batch_size,
                                  max_len=args.max_len,
                                  temperature=args.temperature), params)
    rng = np.random.default_rng(0)
    reqs = [(i, rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
             .astype(np.int32)) for i in range(args.requests)]
    t0 = time.time()
    out = eng.run(reqs, max_new=args.max_new)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve] {len(out)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s)")
    for rid in sorted(out)[:4]:
        print(f"[serve] req {rid}: {out[rid][:12]}")


if __name__ == "__main__":
    main()
