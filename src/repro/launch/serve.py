"""Serving driver: LM decode through the slot-based engine, or spectral
transforms through the continuous-batching spectral server.

``python -m repro.launch.serve --arch h2o-danube-1.8b --reduced`` serves a
reduced model with synthetic prompts on local devices.

``python -m repro.launch.serve --workload spectral --buckets 64x64,128x128``
stands up a :class:`repro.serve.spectral.SpectralServer` over the named
shape buckets (c2c + rfft per shape) and drives a closed-loop ragged mix
through it, printing throughput, tail latency and the per-bucket snapshot.
"""
from __future__ import annotations

import argparse
import time


def _parse_buckets(spec: str):
    shapes = []
    for part in spec.split(","):
        dims = tuple(int(d) for d in part.lower().split("x"))
        if len(dims) not in (1, 2):
            raise SystemExit(f"--buckets wants NxM or N entries, got {part}")
        shapes.append(dims)
    return shapes


def _spectral_main(args) -> None:
    from repro.serve.spectral import (BucketConfig, MixItem, SpectralServer,
                                      closed_loop, open_loop)

    shapes = _parse_buckets(args.buckets)
    buckets = [BucketConfig(s, kind=k) for s in shapes
               for k in ("c2c", "rfft") if len(s) == 2 or k == "c2c"]
    mix = [MixItem(b.shape, b.kind, inverse=b.inverse) for b in buckets]
    with SpectralServer(buckets, unmatched=args.unmatched) as srv:
        rep = srv.prewarm_report
        print(f"[serve] spectral: {len(buckets)} buckets pre-warmed in "
              f"{rep.total_s:.2f}s"
              + (f", degraded: {rep.degraded}" if rep.degraded else ""))
        if args.qps > 0:
            res = open_loop(srv, mix, qps=args.qps,
                            duration_s=args.duration, seed=0)
        else:
            res = closed_loop(srv, mix, requests=args.requests,
                              concurrency=args.batch_size, seed=0)
        print(f"[serve] {res['completed']} completed "
              f"({res['achieved_qps']:.1f} req/s), "
              f"p50={res['p50_ms']:.1f}ms p99={res['p99_ms']:.1f}ms, "
              f"rejected={res['rejected']} timed_out={res['timed_out']}")
        snap = srv.snapshot()
        for lbl in sorted(snap["buckets"]):
            c = snap["buckets"][lbl]["counters"]
            if c["admitted"]:
                print(f"[serve]   {lbl}: admitted={c['admitted']} "
                      f"completed={c['completed']} "
                      f"fallback={c['fallback_served']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "spectral"), default="lm")
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--buckets", default="64x64,128x128",
                    help="spectral: comma-separated bucket shapes (NxM)")
    ap.add_argument("--unmatched", choices=("reject", "pad_up"),
                    default="reject")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="spectral: >0 switches to open-loop at this rate")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="spectral: open-loop duration (seconds)")
    args = ap.parse_args()

    if args.workload == "spectral":
        _spectral_main(args)
        return

    import jax
    import numpy as np
    import repro.configs as C
    from repro.models import model as M
    from repro.serve.engine import Engine, ServeConfig

    cfg = C.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, ServeConfig(batch_size=args.batch_size,
                                  max_len=args.max_len,
                                  temperature=args.temperature), params)
    rng = np.random.default_rng(0)
    reqs = [(i, rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))
             .astype(np.int32)) for i in range(args.requests)]
    t0 = time.time()
    out = eng.run(reqs, max_new=args.max_new)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve] {len(out)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s)")
    for rid in sorted(out)[:4]:
        print(f"[serve] req {rid}: {out[rid][:12]}")


if __name__ == "__main__":
    main()
