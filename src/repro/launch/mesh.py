"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before *any* jax
initialisation, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def auto_axis_types_kw(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` on jax versions that have
    ``jax.sharding.AxisType``, ``{}`` on older ones (where Auto is already
    the only behaviour) — keeps mesh construction version-tolerant."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (shard_map-compatible)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **auto_axis_types_kw(len(axes)))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for this mesh ((pod, data) when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axis(mesh) -> str:
    return "model"
