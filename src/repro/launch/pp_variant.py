import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Pipeline-parallel train-step variant for the multi-pod mesh.

Hillclimb iteration for the collective-bound nemotron cell: instead of
FSDP-gathering every layer's weights across the whole machine per
microbatch, split the depth into one stage per pod (GPipe over the `pod`
axis, repro.dist.pipeline).  Weights then shard (pod-stage, data, model)
with NO cross-pod weight collectives; only microbatch activations cross
pods (ppermute), plus the usual intra-pod TP/DP collectives.

    python -m repro.launch.pp_variant --arch nemotron-4-340b [--microbatches 8]
"""

import argparse
import json
import time


def build_pp_train_step(arch: str, seq_len: int, global_batch: int,
                        n_microbatches: int):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import repro.configs as C
    from repro.models import model as M, layers
    from repro.train import optimizer as opt_lib
    from . import mesh as mesh_lib, sharding as sh

    import dataclasses
    cfg = C.get_config(arch)
    # f32 everywhere: XLA's AllReducePromotion pass crashes ('Invalid binary
    # instruction opcode copy') cloning the bf16 all-reduces this pipeline's
    # autodiff emits under partial-auto shard_map (XLA bug).  The PP-vs-FSDP
    # comparison is about the collective schedule; byte counts are scaled
    # by 0.5 when comparing against the bf16 baseline (see EXPERIMENTS.md).
    cfg = dataclasses.replace(cfg, dtype="float32")
    mesh = mesh_lib.make_production_mesh(multi_pod=True)
    n_stages = mesh.shape["pod"]
    assert cfg.repeat % n_stages == 0
    per_stage = cfg.repeat // n_stages

    ap = M.abstract_params(cfg)

    def split_stages(a):
        return jax.ShapeDtypeStruct((n_stages, per_stage, *a.shape[1:]),
                                    a.dtype)

    ap_pp = dict(ap, blocks=jax.tree.map(split_stages, ap["blocks"]))

    # shardings: stage dim -> pod; inner dims follow the tp2d rules with the
    # pod axis stripped (it now carries the stage dim, not DP)
    base = sh.param_shardings(cfg, mesh, ap)

    def _strip_pod(ax):
        axes = ax if isinstance(ax, tuple) else (ax,)
        kept = tuple(a for a in axes if a not in (None, "pod"))
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    def pp_spec(spec_leaf, abstract_leaf):
        inner = tuple(_strip_pod(ax) for ax in spec_leaf.spec)
        return NamedSharding(mesh, P("pod", *inner))

    pshard = dict(
        {k: v for k, v in sh.param_shardings(cfg, mesh, ap).items()
         if k != "blocks"},
        blocks=jax.tree.map(pp_spec, base["blocks"], ap["blocks"]))

    ocfg = opt_lib.AdamWConfig(moments_dtype="bfloat16")
    from repro.train.train_step import abstract_opt_state
    ao = abstract_opt_state(cfg, ocfg, ap_pp)
    oshard = {"step": NamedSharding(mesh, P()),
              "m": pshard, "v": pshard}

    from repro.data.pipeline import make_batch_specs
    bspec = make_batch_specs(cfg, seq_len, global_batch)
    bshard = sh.batch_shardings(cfg, mesh, bspec)

    positions = None

    def stage_fn(sp, x):
        from repro.models import actsharding
        pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                               (x.shape[0], x.shape[1]))

        def body(x, lp):
            # pin (data, SP-over-model) sharding: the per-layer residuals
            # the scan saves for backward are otherwise unsharded —
            # measured 670 GiB temp without this constraint
            x = actsharding.constrain(x)
            for j, blk in enumerate(cfg.block_pattern):
                x, a = M._block_apply(lp[f"b{j}"], None, blk, x, cfg, pos)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, sp)
        return actsharding.constrain(x)

    from repro.dist.pipeline import pipelined_apply

    def train_step(params, opt_state, batch):
        def loss_fn(params):
            x = layers.embed(params["embed"], batch["tokens"], cfg)
            x = pipelined_apply(mesh, "pod", stage_fn, params["blocks"],
                                x, n_microbatches, partial_auto=True)
            x = layers.norm_apply(params["final_norm"], x, cfg)
            logits = layers.unembed(params["embed"], x, cfg)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                                     -1)[..., 0]
            return -ll.mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_state, metrics = opt_lib.adamw_update(
            ocfg, grads, opt_state, params)
        return new_params, new_state, dict(metrics, loss=loss)

    return (train_step, (ap_pp, ao, bspec), (pshard, oshard, bshard),
            mesh, cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nemotron-4-340b")
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--out", default="runs/dryrun/pp_variant")
    args = ap.parse_args()

    import jax
    from repro.analysis.hloparse import analyze
    from repro.analysis.roofline import HW
    from repro.models import actsharding
    from . import mesh as mesh_lib

    step, absargs, shardings, mesh, cfg = build_pp_train_step(
        args.arch, args.seq_len, args.global_batch, args.microbatches)

    t0 = time.time()
    with mesh, actsharding.activation_spec(mesh, ("data",), "model"):
        compiled = jax.jit(step, in_shardings=shardings).lower(
            *absargs).compile()
    cost = analyze(compiled.as_text())
    rec = {
        "variant": f"pp_{args.arch}", "microbatches": args.microbatches,
        "compile_s": round(time.time() - t0, 2),
        "flops": cost.flops, "traffic_bytes": cost.traffic,
        "collective_bytes": cost.collectives,
        "collective_total": cost.collective_total,
        "compute_s": cost.flops / HW["peak_flops_bf16"],
        "memory_s": cost.traffic / HW["hbm_bw"],
        "collective_s": cost.collective_total / HW["ici_bw"],
    }
    try:
        rec["temp_bytes"] = int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:
        pass
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"{args.arch}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[pp] {args.arch}: compute {rec['compute_s']:.2f}s "
          f"memory {rec['memory_s']:.2f}s collective {rec['collective_s']:.2f}s "
          f"temp {rec.get('temp_bytes', 0)/2**30:.1f} GiB")


if __name__ == "__main__":
    main()
