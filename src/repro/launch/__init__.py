"""repro.launch — production mesh, sharding rules, dry-run, drivers."""
