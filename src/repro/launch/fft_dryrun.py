import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede every jax-importing module (see dryrun.py).

"""Dry-run of the paper's own workload at production scale: a distributed
2-D FFT on the 16x16 (and 2x16x16) mesh, with the collective-schedule
variants from repro.dist.pencil.  Emits loop-aware roofline terms per
variant — the §Perf FFT iteration log reads from this.

    python -m repro.launch.fft_dryrun --size 16384 [--mesh both]
"""

import argparse
import json
import time


def run_variant(name, mesh, fn, args, in_shardings, out_dir, size):
    import jax
    from repro.analysis.hloparse import analyze
    from repro.analysis.roofline import HW

    t0 = time.time()
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_shardings).lower(*args) \
            .compile()
    cost = analyze(compiled.as_text())
    rec = {
        "variant": name, "size": size,
        "devices": int(len(jax.devices())),
        "compile_s": round(time.time() - t0, 2),
        "flops": cost.flops,
        "traffic_bytes": cost.traffic,
        "collective_bytes": cost.collectives,
        "collective_total": cost.collective_total,
        "compute_s": cost.flops / HW["peak_flops_f32"],
        "memory_s": cost.traffic / HW["hbm_bw"],
        "collective_s": cost.collective_total / HW["ici_bw"],
    }
    try:
        mem = compiled.memory_analysis()
        rec["temp_bytes"] = int(mem.temp_size_in_bytes)
    except Exception:
        pass
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[fft-dryrun] {name}: compute {rec['compute_s']:.2e}s "
          f"memory {rec['memory_s']:.2e}s collective {rec['collective_s']:.2e}s "
          f"(coll {rec['collective_total']/2**30:.2f} GiB/dev)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=16384,
                    help="global H=W (paper used 1024; production-scale default 16384)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="runs/fft_dryrun")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.complexmath import SplitComplex
    from repro.dist import pencil
    from . import mesh as mesh_lib

    n = args.size

    def specs(mesh, axes):
        sh = NamedSharding(mesh, P(axes, None))
        ab = jax.ShapeDtypeStruct((n, n), jnp.float32)
        return (ab, ab), (sh, sh)

    if args.mesh in ("single", "both"):
        mesh = mesh_lib.make_production_mesh()
        flat = ("data", "model")                  # all 256 chips on the FFT
        (a_re, a_im), (sh_re, sh_im) = specs(mesh, flat)

        def mk(fn):
            return lambda re, im: tuple(fn(SplitComplex(re, im)))

        run_variant("pfft2_base_256", mesh,
                    mk(lambda z: pencil.pfft2(z, mesh, flat)),
                    (a_re, a_im), ((sh_re, sh_im)), args.out, n)
        run_variant("pfft2_chunks4_256", mesh,
                    mk(lambda z: pencil.pfft2(z, mesh, flat, chunks=4)),
                    (a_re, a_im), ((sh_re, sh_im)), args.out, n)
        run_variant("pfft2_hier_256", mesh,
                    mk(lambda z: pencil.pfft2_hierarchical(
                        z, mesh, pod_axis="data", inner_axis="model")),
                    (a_re, a_im), ((sh_re, sh_im)), args.out, n)
        # real-input transform: halves row-pass FLOPs and transpose bytes
        sh_r = NamedSharding(mesh, P(flat, None))
        ar = jax.ShapeDtypeStruct((n, n), jnp.float32)

        def rfft2_packed(x):
            # pack even/odd columns as complex -> half-width 2-D pencil FFT
            z = SplitComplex(x[:, 0::2], x[:, 1::2])
            return tuple(pencil.pfft2(z, mesh, flat))

        run_variant("prfft2_packed_256", mesh, rfft2_packed,
                    (ar,), ((sh_r,)), args.out, n)

    if args.mesh in ("multi", "both"):
        mesh = mesh_lib.make_production_mesh(multi_pod=True)
        flat = ("pod", "data", "model")
        (a_re, a_im), (sh_re, sh_im) = specs(mesh, flat)
        run_variant("pfft2_base_512", mesh,
                    lambda re, im: tuple(pencil.pfft2(
                        SplitComplex(re, im), mesh, flat)),
                    (a_re, a_im), ((sh_re, sh_im)), args.out, n)
        # hierarchical: intra-pod hop on (data, model), inter-pod hop on pod
        spec_in = NamedSharding(mesh, P(("pod", "data", "model"), None))

        def hier(re, im):
            z = SplitComplex(re, im)
            out = pencil.pfft2_hierarchical(z, mesh, pod_axis="pod",
                                            inner_axis=("data", "model"))
            return tuple(out)

        run_variant("pfft2_hier_512", mesh, hier, (a_re, a_im),
                    ((spec_in, spec_in)), args.out, n)


if __name__ == "__main__":
    main()
