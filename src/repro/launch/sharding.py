"""Logical sharding rules: param/optimizer/batch/cache PartitionSpecs per
architecture profile.

Profiles (DESIGN.md §5):
- ``tp2d`` (default): Megatron-style tensor parallelism on the ``model``
  axis (column-parallel up-projections, row-parallel down-projections,
  vocab-parallel embeddings) combined with FSDP-style sharding of the other
  weight dim over ``data``.  Experts shard over ``model`` (EP).
- ``fsdp``: pure ZeRO-3 — every large tensor sharded over the combined
  (data, model) axes on its largest divisible dim.  Used where head counts
  don't divide the model axis (qwen1.5: 20 heads, xlstm: 4 heads).

Every rule degrades gracefully: a mesh axis is dropped from a spec whenever
the corresponding tensor dim is not divisible by the axis size, so any config
compiles on any mesh (elastic rescaling).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from . import mesh as mesh_lib

# Archs whose HEAD counts don't divide the model axis still shard cleanly on
# their FLAT projection dims (20 heads x 128 = 2560 % 16 == 0), so tp2d is
# the default everywhere.  A data-dim ZeRO-3 weight sharding ("fsdp") is kept
# selectable for experiments, but the XLA SPMD partitioner resolves its
# param/activation conflicts by replicating global activations ("involuntary
# full rematerialization") — measured 145 GB temp vs 12 GB under tp2d for
# xlstm-350m/train_4k; see EXPERIMENTS.md §Perf notes.
FSDP_ARCHS: set = set()

# param leaf names by parallelism role
_COL_PARALLEL = {"wq", "wk", "wv", "wi", "wg", "up", "in_proj", "router"}
_ROW_PARALLEL = {"wo", "down", "out_proj"}


def profile_for(cfg: ModelConfig) -> str:
    return "fsdp" if cfg.name in FSDP_ARCHS else "tp2d"


def _axis_sizes(mesh: Mesh):
    return {a: mesh.shape[a] for a in mesh.axis_names}


def _fit(spec_axes, shape, mesh: Mesh):
    """Drop mesh axes whose size does not divide the tensor dim."""
    sizes = _axis_sizes(mesh)
    out = []
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([sizes[a] for a in axes]))
        out.append(ax if dim % total == 0 else None)
    return P(*out)


def _param_spec(path_keys, shape, cfg: ModelConfig, mesh: Mesh,
                profile: str) -> P:
    name = path_keys[-1]
    in_moe = "moe" in path_keys
    data = mesh_lib.data_axes(mesh)
    data = data if len(data) > 1 else (data[0] if data else None)
    ndim = len(shape)

    if ndim <= 1:
        return P(*([None] * ndim))

    if profile == "fsdp":
        # embeddings stay vocab-parallel on `model` even under fsdp so the
        # CE head's logits shard over vocab instead of replicating
        if name == "tok":
            return _fit(("model", data), shape, mesh)
        if name == "head":
            return _fit((data, "model"), shape, mesh)
        # ZeRO-3: biggest dim over every device
        all_axes = tuple(mesh.axis_names)
        big = int(np.argmax(shape))
        spec = [None] * ndim
        spec[big] = all_axes
        fitted = _fit(spec, shape, mesh)
        if fitted[big] is not None:
            return fitted
        spec[big] = data                       # degrade: data axes only
        return _fit(spec, shape, mesh)

    # --- tp2d ---
    if in_moe and name in ("wi", "wg"):        # (R, E, d, ff): EP + FSDP
        return _fit((None, "model", data, None), shape, mesh)
    if in_moe and name == "wo":                # (R, E, ff, d)
        return _fit((None, "model", None, data), shape, mesh)
    if name == "tok":                          # (V, d) vocab-parallel
        return _fit(("model", data), shape, mesh)
    if name == "head":                         # (d, V)
        return _fit((data, "model"), shape, mesh)
    if name in _COL_PARALLEL:                  # (..., d_in, d_out)
        spec = [None] * (ndim - 2) + [data, "model"]
        return _fit(spec, shape, mesh)
    if name in _ROW_PARALLEL:                  # (..., d_in, d_out)
        spec = [None] * (ndim - 2) + ["model", data]
        return _fit(spec, shape, mesh)
    if name in ("bi", "bq", "bk", "bv"):       # column-parallel biases
        spec = [None] * (ndim - 1) + ["model"]
        return _fit(spec, shape, mesh)
    if name in ("wi", "wf"):                   # mlstm gate projections
        spec = [None] * (ndim - 2) + [data, None]
        return _fit(spec, shape, mesh)
    return P(*([None] * ndim))


def param_shardings(cfg: ModelConfig, mesh: Mesh, abstract_params: Any):
    """NamedSharding pytree matching the param tree."""
    profile = profile_for(cfg)

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        spec = _param_spec(keys, leaf.shape, cfg, mesh, profile)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_shardings(cfg: ModelConfig, mesh: Mesh, abstract_opt: Any,
                  abstract_params: Any):
    """Optimizer moments shard like their params; scalars replicate."""
    pshard = param_shardings(cfg, mesh, abstract_params)

    def like_params(sub):
        return jax.tree.map(lambda s: s, pshard)

    out = {}
    for k, v in abstract_opt.items():
        if k == "step":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = like_params(v)
    return out


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_specs: Any):
    """Batch dim over (pod, data); model dim of stub embeddings unsharded."""
    data = mesh_lib.data_axes(mesh)
    data = data if len(data) > 1 else (data[0] if data else None)

    def one(leaf):
        spec = [data] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, _fit(spec, leaf.shape, mesh))

    return jax.tree.map(one, batch_specs)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, abstract_cache: Any,
                    batch: int):
    """Decode caches: batch over data when divisible, else SP — shard the
    cache's sequence (slots) dim over data; recurrent states shard their
    head dim over model."""
    data = mesh_lib.data_axes(mesh)
    data = data if len(data) > 1 else (data[0] if data else None)
    sizes = _axis_sizes(mesh)
    dsize = int(np.prod([sizes[a] for a in (data if isinstance(data, tuple)
                                            else (data,))])) if data else 1
    batch_ok = batch % dsize == 0 and batch >= dsize

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = keys[-1]
        nd = len(leaf.shape)
        # leading dim is the scan-stacked repeat axis (from init_cache);
        # actual tensor dims start at 1
        if batch_ok:
            spec = [None, data] + [None] * (nd - 2)
            if name in ("k", "v"):
                # NOTE: when kv heads don't divide the model axis (qwen1.5)
                # the cache stays replicated over `model`.  Sharding the
                # slots dim instead was tried and REFUTED: SPMD all-gathers
                # the whole cache per decoded token (collective term
                # 0.02 s -> 4.3 s measured); see EXPERIMENTS.md §Perf D1.
                spec = [None, data, None, "model", None][:nd]
            return NamedSharding(mesh, _fit(spec, leaf.shape, mesh))
        # SP: shard sequence/slots (dim 2 for k/v/pos), heads over model
        if name in ("k", "v"):
            spec = [None, None, data, "model", None][:nd]
        elif name == "pos":
            spec = [None, None, data][:nd]
        elif name in ("ssm", "c"):
            spec = [None, None, "model"] + [None] * (nd - 3)
        else:
            spec = [None] * nd
        return NamedSharding(mesh, _fit(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


def replicated(mesh: Mesh, tree: Any):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
