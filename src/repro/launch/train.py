"""End-to-end training driver.

``python -m repro.launch.train --arch h2o-danube-1.8b --reduced --steps 200``
runs a reduced config on local devices; on a real cluster the same driver
binds the production mesh (--mesh single|multi) and full config.  Features:
deterministic data, jit'd train step with sharded params/optimizer, async
atomic checkpoints every --ckpt-every steps, automatic resume (elastic: the
checkpoint restores onto whatever mesh is present), bf16 gradient-compression
flag, microbatch accumulation.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--fft-backend", choices=["jnp", "pallas"], default=None,
                    help="override the config's fft_backend (fft_conv plans "
                         "+ fourier_mix) for A/B runs")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", choices=["none", "bf16"], default="none")
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import repro.configs as C
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as M, actsharding
    from repro.train import optimizer as opt_lib
    from repro.train.checkpoint import CheckpointManager
    from repro.train.train_step import make_train_step, init_opt_state
    from . import mesh as mesh_lib, sharding as sh

    cfg = C.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.fft_backend is not None:
        cfg = dataclasses.replace(cfg, fft_backend=args.fft_backend)
    dcfg = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch)
    data = SyntheticLM(dcfg, cfg)
    ocfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                               total_steps=args.steps)
    compress = None if args.compress == "none" else args.compress
    step_fn = make_train_step(cfg, ocfg, microbatches=args.microbatches,
                              compress=compress)

    mesh = None
    if args.mesh != "none":
        mesh = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = init_opt_state(cfg, ocfg, params, compress=compress)
    print(f"[train] {cfg.name}: {M.param_count(params)/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.global_batch} x {args.seq_len}")

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        (params, opt_state), extra = mgr.restore(
            latest, (params, opt_state))
        start = int(extra.get("data_step", latest))
        print(f"[train] resumed from step {latest}")

    if mesh is not None:
        pshard = sh.param_shardings(cfg, mesh, jax.eval_shape(lambda: params))
        params = jax.device_put(params, pshard)
        ctx = lambda: actsharding.activation_spec(
            mesh, mesh_lib.data_axes(mesh), "model")
    else:
        ctx = contextlib.nullcontext

    with (mesh if mesh is not None else contextlib.nullcontext()), ctx():
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        t0 = time.time()
        t_steady = None                       # set after step 1 (post-compile)
        for step in range(start, args.steps):
            batch = data.batch_at(step)
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
            if t_steady is None:
                jax.block_until_ready(metrics["loss"])
                t_steady = time.time()        # compile excluded from tok/s
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                mgr.save_async(step, (params, opt_state),
                               extra={"data_step": step + 1})
        jax.block_until_ready(params)
        steady_steps = args.steps - start - 1
        if steady_steps > 0:
            toks = steady_steps * args.global_batch * args.seq_len
            print(f"[train] tokens/sec {toks / (time.time() - t_steady):.0f} "
                  f"(fft_backend={cfg.fft_backend}, steady steps "
                  f"{steady_steps})", flush=True)
        mgr.wait()
        mgr.save(args.steps, (params, opt_state),
                 extra={"data_step": args.steps})
    print("[train] done")


if __name__ == "__main__":
    main()
