import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# host device count at first init.  All other imports are deferred into
# functions for the same reason (and so tests can import helpers under a
# 1-device runtime).

import argparse
import json
import re
import time


HW = {  # TPU v5e per-chip constants (roofline §EXPERIMENTS.md)
    "peak_flops_bf16": 197e12,      # FLOP/s
    "peak_flops_f32": 98.5e12,
    "hbm_bw": 819e9,                # B/s
    "ici_bw": 50e9,                 # B/s per link (per-chip assumed)
    "hbm_per_chip": 16e9,           # bytes
    "board_power_w": 215.0,         # chip TDP-ish, for the energy model
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-payload bytes of every collective op in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m:
            out[m.group(2)] += _shape_bytes(m.group(1))
            out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def build_cell(arch: str, shape_name: str, mesh, *, overrides=None,
               microbatches=None):
    """Returns (fn, abstract_args, in_shardings, meta) for one cell.

    overrides: dict of ModelConfig field replacements (hillclimb variants);
    microbatches: grad-accumulation override for train cells.
    """
    import dataclasses
    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.data.pipeline import make_batch_specs
    from repro.models import model as M
    from repro.serve import engine as E
    from repro.train import optimizer as opt_lib
    from repro.train.train_step import make_train_step, abstract_opt_state
    from . import sharding as sh

    cfg = C.get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = C.SHAPES[shape_name]
    ap = M.abstract_params(cfg)
    pshard = sh.param_shardings(cfg, mesh, ap)
    meta = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "n_params": M.param_count(ap),
        "n_active": M.active_param_count(cfg, ap),
        "profile": sh.profile_for(cfg),
        "dtype": cfg.dtype,
    }

    if cell.kind == "train":
        # memory ladder for the 100B+ configs: bf16 optimizer moments
        # (halves optimizer HBM) and 4-way microbatch accumulation
        # (quarters live activation memory) — see EXPERIMENTS.md §Dry-run
        big = meta["n_params"] > 5e10
        moments = "bfloat16" if big else "float32"
        micro = microbatches if microbatches else (4 if big else 1)
        meta["microbatches"] = micro
        ocfg = opt_lib.AdamWConfig(moments_dtype=moments)
        ao = abstract_opt_state(cfg, ocfg, ap)
        oshard = sh.opt_shardings(cfg, mesh, ao, ap)
        bspec = make_batch_specs(cfg, cell.seq_len, cell.global_batch)
        bshard = sh.batch_shardings(cfg, mesh, bspec)
        fn = make_train_step(cfg, ocfg, microbatches=micro)
        return fn, (ap, ao, bspec), (pshard, oshard, bshard), meta

    if cell.kind == "prefill":
        bspec = make_batch_specs(cfg, cell.seq_len, cell.global_batch)
        bspec.pop("labels")
        bshard = sh.batch_shardings(cfg, mesh, bspec)
        acache = jax.eval_shape(
            lambda: M.init_cache(cfg, cell.global_batch, cell.seq_len,
                                 jnp.bfloat16))
        cshard = sh.cache_shardings(cfg, mesh, acache, cell.global_batch)
        fn = E.prefill_fn(cfg)
        return fn, (ap, bspec, acache), (pshard, bshard, cshard), meta

    # decode: one new token against a seq_len-deep cache.  KV caches are
    # bf16 regardless of model dtype (standard serving practice — qwen1.5's
    # f32 32k cache measured 200 GiB/dev before this).
    import jax.numpy as jnp
    b = cell.global_batch
    acache = jax.eval_shape(
        lambda: M.init_cache(cfg, b, cell.seq_len, jnp.bfloat16))
    cshard = sh.cache_shardings(cfg, mesh, acache, b)
    toks = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    tshard = sh.batch_shardings(cfg, mesh, toks)
    fn = E.decode_fn(cfg)
    return fn, (ap, toks, acache, pos), (pshard, tshard, cshard, tshard), meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save_dir: str = "runs/dryrun", verbose: bool = True,
             overrides=None, microbatches=None, tag: str = "") -> dict:
    import jax
    from repro.models import actsharding
    from . import mesh as mesh_lib

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    fn, args, in_shardings, meta = build_cell(arch, shape_name, mesh,
                                              overrides=overrides,
                                              microbatches=microbatches)
    meta["mesh"] = mesh_name
    if tag:
        meta["tag"] = tag
        shape_name = f"{shape_name}__{tag}"
    meta["devices"] = int(len(jax.devices()))
    batch_axes = mesh_lib.data_axes(mesh)
    # decode with unshardable batch (long_500k, B=1): no batch pinning —
    # the cache SP sharding governs instead
    cell_batch = meta["global_batch"]
    dsize = 1
    for a in batch_axes:
        dsize *= mesh.shape[a]
    pin = cell_batch % dsize == 0 and cell_batch >= dsize

    t0 = time.time()
    import contextlib
    ctx = (actsharding.activation_spec(mesh, batch_axes, "model")
           if pin else contextlib.nullcontext())
    with mesh, ctx:
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec = dict(meta, lower_s=round(t_lower, 2), compile_s=round(t_compile, 2))
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as ex:                                  # pragma: no cover
        rec["memory"] = {"error": str(ex)}
    try:
        cost = compiled.cost_analysis()
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       ("flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as ex:                                  # pragma: no cover
        rec["cost"] = {"error": str(ex)}
    hlo_text = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo_text)     # text-static payload
    try:
        from repro.analysis.hloparse import analyze
        cost = analyze(hlo_text)
        rec["loop_aware"] = {                           # per-device, loop-exact
            "flops": cost.flops,
            "traffic_bytes": cost.traffic,
            "collective_bytes": cost.collectives,
            "collective_total": cost.collective_total,
        }
    except Exception as ex:                             # pragma: no cover
        rec["loop_aware"] = {"error": repr(ex)[:300]}

    os.makedirs(os.path.join(save_dir, mesh_name), exist_ok=True)
    out = os.path.join(save_dir, mesh_name, f"{arch}__{shape_name}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    try:                       # keep the HLO so the analyzer can be re-run
        import zstandard
        with open(out.replace(".json", ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(
                hlo_text.encode()))
    except Exception:
        pass
    if verbose:
        flops = rec["cost"].get("flops", 0)
        print(f"[dryrun] {mesh_name} {arch} {shape_name}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops/dev {flops:.3e} "
              f"coll {rec['collectives']['total']/2**30:.2f} GiB "
              f"-> {out}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="every runnable (arch x shape) cell")
    ap.add_argument("--save-dir", default="runs/dryrun")
    ap.add_argument("--set", nargs="*", default=[], metavar="KEY=VALUE",
                    help="ModelConfig overrides for hillclimb variants")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--tag", default="", help="variant tag for the artifact")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    import repro.configs as C

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = C.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, C.SHAPES[args.shape])]

    failures = []
    for arch, cell in cells:
        for mp in meshes:
            try:
                run_cell(arch, cell.shape, multi_pod=mp,
                         save_dir=args.save_dir,
                         overrides=overrides or None,
                         microbatches=args.microbatches, tag=args.tag)
            except Exception as ex:
                failures.append((arch, cell.shape, mp, repr(ex)[:200]))
                print(f"[dryrun] FAIL {arch} {cell.shape} multi={mp}: {ex}",
                      flush=True)
    skipped = C.SKIPPED_CELLS
    print(f"[dryrun] done; {len(failures)} failures, "
          f"{len(skipped)} cells skipped by design")
    for s in skipped:
        print(f"[dryrun] skipped: {s}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
