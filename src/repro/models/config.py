"""Model configuration: one dataclass drives every architecture in the pool.

A model is a stack of ``repeat`` copies of a *super-block* — a static tuple
of block types — so heterogeneous stacks (zamba2's mamba+shared-attention,
xlstm's mLSTM/sLSTM mix) scan cleanly: params of the repeated super-block are
stacked on a leading axis and the whole depth is one ``lax.scan``
(compile-time O(1) in depth — essential for the 512-device dry-runs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # depth = repeat x len(block_pattern)
    block_pattern: Tuple[str, ...]   # e.g. ("attn_mlp",) / ("mamba2",)*5+("shared_attn",)
    repeat: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # --- attention options ---
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None      # SWA width (tokens)
    causal: bool = True
    attn_chunk: int = 512            # streaming-softmax block size

    # --- mlp / norm ---
    mlp_type: str = "swiglu"         # swiglu | gelu | relu2
    mlp_bias: bool = False
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5

    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_prefill_cap_scale: float = 2.0   # prefill capacity headroom
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # --- xLSTM ---
    mlstm_chunk: int = 128
    slstm_head_dim: Optional[int] = None

    # --- io ---
    input_mode: str = "tokens"       # tokens | embeddings (vlm/audio stubs)
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 128

    # --- technique integration (DESIGN.md §4) ---
    token_mixing: str = "attention"  # attention | fourier (FNet mixing)
    use_fft_conv: bool = False       # Mamba2 conv branch via repro.core.fftconv
    fft_backend: str = "jnp"         # jnp | pallas: backend for the FFT paths
    #   (fft_conv plans + fourier_mix); pallas requests demote with a
    #   registry-visible reason when no kernel schedule exists

    # --- numerics ---
    dtype: str = "float32"           # activation/param dtype
    remat: bool = True               # checkpoint each super-block in train

    @property
    def n_layers(self) -> int:
        return self.repeat * len(self.block_pattern)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        shrink = dict(
            d_model=max(32, self.resolved_head_dim),
            n_heads=4, n_kv_heads=min(4, max(1, self.n_kv_heads)),
            head_dim=16, d_ff=64, vocab_size=256, repeat=2,
            rope_theta=self.rope_theta,
            sliding_window=16 if self.sliding_window else None,
            attn_chunk=16, ssm_chunk=16, mlstm_chunk=16,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            n_experts=8 if self.n_experts else 0,
            n_experts_active=min(2, self.n_experts_active),
            moe_d_ff=32 if self.n_experts else 0,
            vocab_pad_multiple=32,
            dtype="float32",         # reduced configs always test in f32
        )
        shrink["d_model"] = 64
        shrink.update(overrides)
        return dataclasses.replace(self, **shrink)


# Parameter counts are computed from the abstract param tree (see
# repro.models.model.param_count / active_param_count) rather than an
# analytic formula — one source of truth for MODEL_FLOPS = 6*N*D.
