"""Core transformer layers: norms, RoPE, GQA attention, MLPs, embeddings.

Pure JAX, dict pytrees, init/apply pairs.  Attention uses a streaming
(online-softmax) formulation scanned over KV chunks so peak activation
memory is O(S * chunk) instead of O(S^2) — the pure-JAX stand-in for a
flash-attention kernel (kernel effort in this repo is reserved for the
paper's FFT hot spots; see DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

NEG_INF = -1e30


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p, x, cfg: ModelConfig):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(dt)


def rms_head_norm(x, scale, eps=1e-6):
    """Per-head RMS norm (qk-norm): x (..., head_dim)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[..., None, :]                        # (B, S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (streaming softmax)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h * hd)),
        "wk": _init(ks[1], (d, kv * hd)),
        "wv": _init(ks[2], (d, kv * hd)),
        "wo": _init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend_chunked(q, k, v, cfg: ModelConfig, q_positions, kv_positions):
    """Streaming-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D).  Scans over KV chunks with a
    running (max, denom, acc) — O(Sq * chunk) live memory.
    Causality and sliding windows are applied from positions.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    chunk = min(cfg.attn_chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1_000_000)
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, sq, kvh, group, hd) * scale

    def step(carry, i):
        # dynamic-slice the chunk out of the cache: pre-stacking transposed
        # (nc, B, C, KV, D) copies materialised the WHOLE cache as a new
        # (f32) buffer per layer — 1.1 TB/layer for qwen1.5 decode_32k
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(kv_positions, i * chunk, chunk,
                                          axis=1)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32)
        mask = pb[:, None, :] <= q_positions[:, :, None]   # causal
        if cfg.sliding_window is not None:
            mask &= pb[:, None, :] > (q_positions[:, :, None]
                                      - cfg.sliding_window)
        mask &= pb[:, None, :] >= 0                        # padding
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", pexp, vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kvh, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, group), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, group, hd), jnp.float32)
    if not cfg.causal:
        q_positions = jnp.full_like(q_positions, skv + 1)  # attend everywhere
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  jnp.arange(n_chunks, dtype=jnp.int32))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention_apply(p, x, cfg: ModelConfig, positions):
    """Full-sequence attention (training / prefill).

    Uses the flash custom-VJP path: lax.scan autodiff would otherwise save
    O(S^2/chunk) probability blocks per layer (see repro.models.flash)."""
    from .flash import flash_attention
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = flash_attention(q, k, v, positions, positions, cfg.attn_chunk,
                          cfg.sliding_window, cfg.causal)
    return out.reshape(b, s, -1) @ p["wo"]


def attention_decode(p, x, cfg: ModelConfig, cache, position):
    """One-token decode with a KV cache (see repro.models.cache)."""
    from . import cache as cache_lib
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, position[:, None])
    cache, k_all, v_all, kv_pos = cache_lib.kv_update(cache, k[:, 0], v[:, 0],
                                                      position)
    out = _attend_chunked(q, k_all, v_all, cfg, position[:, None], kv_pos)
    return out.reshape(b, 1, -1) @ p["wo"], cache


def attention_prefill(p, x, cfg: ModelConfig, positions, cache):
    """Bulk prefill: full-sequence attention + write K/V into the cache.

    Only the last min(S, slots) positions are written (a sliding-window ring
    keeps just the window; later positions win by construction, no duplicate
    scatter indices)."""
    from .flash import flash_attention
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = flash_attention(q, k, v, positions, positions, cfg.attn_chunk,
                          cfg.sliding_window, cfg.causal)
    slots = cache["k"].shape[1]
    keep = min(s, slots)
    k_t, v_t = k[:, -keep:], v[:, -keep:]
    pos_t = positions[:, -keep:]
    idx = pos_t % slots
    rows = jnp.arange(b)[:, None]
    cache = {"k": cache["k"].at[rows, idx].set(k_t.astype(cache["k"].dtype)),
             "v": cache["v"].at[rows, idx].set(v_t.astype(cache["v"].dtype)),
             "pos": cache["pos"].at[rows, idx].set(pos_t.astype(jnp.int32))}
    return out.reshape(b, s, -1) @ p["wo"], cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        p = {"wi": _init(ks[0], (d, ff)), "wg": _init(ks[1], (d, ff)),
             "wo": _init(ks[2], (ff, d))}
    else:
        p = {"wi": _init(ks[0], (d, ff)), "wo": _init(ks[2], (ff, d))}
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((ff,), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    return p


def mlp_apply(p, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif cfg.mlp_type == "gelu":
        h = x @ p["wi"]
        if cfg.mlp_bias:
            h = h + p["bi"]
        h = jax.nn.gelu(h)
    elif cfg.mlp_type == "relu2":                 # nemotron-4 squared-ReLU
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:
        raise ValueError(cfg.mlp_type)
    out = h @ p["wo"]
    if cfg.mlp_bias:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------------------
# Embeddings / head
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig):
    p = {"tok": _init(key, (cfg.padded_vocab, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = _init(jax.random.fold_in(key, 1),
                          (cfg.d_model, cfg.padded_vocab))
    return p


def embed(p, tokens, cfg: ModelConfig):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return x @ p["head"]
