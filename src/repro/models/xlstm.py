"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan).  Beck et al. 2024 (arXiv:2405.04517).

mLSTM is a gated linear-attention recurrence:
    m_t = max(f~_t + m_{t-1}, i~_t)                       (stabilizer)
    C_t = e^{f~_t+m_{t-1}-m_t} C_{t-1} + e^{i~_t-m_t} k_t v_t^T
    n_t = e^{f~_t+m_{t-1}-m_t} n_{t-1} + e^{i~_t-m_t} k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, e^{-m_t})
Training/prefill use an exact chunkwise form (intra-chunk QxQ decay matrix +
inter-chunk (dk, dv) state scan) — O(S * chunk) live memory, mirroring the
attention path.  Decode updates (C, n, m) in O(1).

sLSTM has recurrent gate connections (h_{t-1} enters every gate), so it is
inherently sequential: a lax.scan over time with per-head block-diagonal
recurrent weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init

LOG_EPS = -30.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    din = 2 * d                                   # proj factor 2
    nh = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": _init(ks[0], (d, 2 * din)),         # -> (x branch, z gate)
        "conv_w": _init(ks[1], (4, din), scale=0.5),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "wq": _init(ks[2], (din, din)),
        "wk": _init(ks[3], (din, din)),
        "wv": _init(ks[4], (din, din)),
        "wi": _init(ks[5], (din, nh), scale=0.02),
        "bi": jnp.zeros((nh,), jnp.float32),
        "wf": _init(ks[6], (din, nh), scale=0.02),
        "bf": jnp.full((nh,), 3.0, jnp.float32),  # open forget gates at init
        "out_norm": jnp.ones((din,), jnp.float32),
        "down": _init(ks[7], (din, d)),
    }


def _mlstm_chunked(q, k, v, ilog, flog, chunk, init_state=None):
    """q,k,v: (B,S,H,D); ilog/flog: (B,S,H) log-space gates.
    Returns h (B,S,H,D) and the final (C, n, m) state."""
    bsz, s, nh, dh = q.shape
    qc = min(chunk, s)
    assert s % qc == 0, (s, qc)
    nc = s // qc
    scale = 1.0 / np.sqrt(dh)

    rs = lambda t: t.reshape(bsz, nc, qc, *t.shape[2:])
    qch, kch, vch = rs(q), rs(k), rs(v)
    ich, fch = rs(ilog), rs(flog)
    fcs = jnp.cumsum(fch, axis=2)                        # F_t within chunk

    # intra-chunk log decay: D~[t,u] = F_t - F_u + i~_u  (u <= t)
    dlog = (fcs[:, :, :, None, :] - fcs[:, :, None, :, :]
            + ich[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((qc, qc), bool))
    dlog = jnp.where(tri[None, None, :, :, None], dlog, -jnp.inf)

    def body(carry, inp):
        c_prev, n_prev, m_prev = carry                   # (B,H,D,D),(B,H,D),(B,H)
        qb, kb, vb, db, fcb, ib = inp
        inter_log = fcb + m_prev[:, None, :]             # (B,qc,H)
        m_t = jnp.maximum(jnp.max(db, axis=2), inter_log)
        a = jnp.exp(db - m_t[:, :, None, :])             # (B,qc,qc,H)
        qk = jnp.einsum("bthd,buhd->btuh", qb, kb,
                        preferred_element_type=jnp.float32) * scale
        s_mat = a * qk
        numer = jnp.einsum("btuh,buhd->bthd", s_mat, vb,
                           preferred_element_type=jnp.float32)
        inter_w = jnp.exp(inter_log - m_t)               # (B,qc,H)
        numer += inter_w[..., None] * jnp.einsum(
            "bthd,bhde->bthe", qb * scale, c_prev,
            preferred_element_type=jnp.float32)
        denom = s_mat.sum(axis=2) + inter_w * jnp.einsum(
            "bthd,bhd->bth", qb * scale, n_prev,
            preferred_element_type=jnp.float32)
        denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_t))
        h = numer / denom[..., None]
        # chunk-end state update
        f_end = fcb[:, -1, :]                            # (B,H)
        up_log = f_end[:, None, :] - fcb + ib            # (B,qc,H)
        m_new = jnp.maximum(f_end + m_prev, jnp.max(up_log, axis=1))
        w_up = jnp.exp(up_log - m_new[:, None, :])
        decay = jnp.exp(f_end + m_prev - m_new)
        c_new = (decay[..., None, None] * c_prev
                 + jnp.einsum("buh,buhd,buhe->bhde", w_up, kb, vb,
                              preferred_element_type=jnp.float32))
        n_new = (decay[..., None] * n_prev
                 + jnp.einsum("buh,buhd->bhd", w_up, kb,
                              preferred_element_type=jnp.float32))
        return (c_new, n_new, m_new), h

    if init_state is None:
        c0 = jnp.zeros((bsz, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((bsz, nh, dh), jnp.float32)
        m0 = jnp.full((bsz, nh), LOG_EPS, jnp.float32)
    else:
        c0, n0, m0 = init_state

    mv = lambda t: jnp.moveaxis(t, 1, 0)
    (c_f, n_f, m_f), hseq = jax.lax.scan(
        body, (c0, n0, m0),
        (mv(qch), mv(kch), mv(vch), mv(dlog), mv(fcs), mv(ich)))
    h = jnp.moveaxis(hseq, 0, 1).reshape(bsz, s, nh, dh)
    return h.astype(q.dtype), (c_f, n_f, m_f)


def _mlstm_qkv(p, x, cfg: ModelConfig, conv_state=None):
    """Shared projection path.  x: (B, S, d).  Returns q,k,v,ilog,flog,z and
    the updated conv ring state (for decode)."""
    bsz, s, _ = x.shape
    d = cfg.d_model
    din = 2 * d
    nh = cfg.n_heads
    dh = din // nh
    u = x @ p["up"]
    xb, z = u[..., :din], u[..., din:]
    kw = p["conv_w"].shape[0]
    if conv_state is None:
        xp = jnp.pad(xb, ((0, 0), (kw - 1, 0), (0, 0)))
        new_conv = None
    else:
        xp = jnp.concatenate([conv_state, xb], axis=1)
        new_conv = xp[:, 1:]
    xc = sum(xp[:, i:i + s] * p["conv_w"][i] for i in range(kw))
    xc = jax.nn.silu(xc + p["conv_b"])
    q = (xc @ p["wq"]).reshape(bsz, s, nh, dh)
    k = (xc @ p["wk"]).reshape(bsz, s, nh, dh)
    v = (xb @ p["wv"]).reshape(bsz, s, nh, dh)
    ilog = (xc @ p["wi"] + p["bi"]).astype(jnp.float32)
    flog = jax.nn.log_sigmoid((xc @ p["wf"] + p["bf"]).astype(jnp.float32))
    return q, k, v, ilog, flog, z, new_conv


def _mlstm_out(p, h, z, cfg: ModelConfig):
    bsz, s = h.shape[:2]
    din = 2 * cfg.d_model
    y = h.reshape(bsz, s, din)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]
         ).astype(h.dtype)
    return (y * jax.nn.silu(z)) @ p["down"]


def mlstm_apply(p, x, cfg: ModelConfig):
    q, k, v, ilog, flog, z, _ = _mlstm_qkv(p, x, cfg)
    h, _ = _mlstm_chunked(q, k, v, ilog, flog, cfg.mlstm_chunk)
    return _mlstm_out(p, h, z, cfg)


def mlstm_prefill(p, x, cfg: ModelConfig, state):
    """Full-sequence mixer that also returns decode state (conv tail, C/n/m)."""
    bsz, s, _ = x.shape
    din = 2 * cfg.d_model
    u = x @ p["up"]
    xb = u[..., :din]
    q, k, v, ilog, flog, z, _ = _mlstm_qkv(p, x, cfg)
    h, (c, n, m) = _mlstm_chunked(q, k, v, ilog, flog, cfg.mlstm_chunk)
    kw = p["conv_w"].shape[0]
    tail = jnp.pad(xb, ((0, 0), (max(kw - 1 - s, 0), 0), (0, 0)))[:, -(kw - 1):]
    new_state = {"conv": tail.astype(state["conv"].dtype),
                 "c": c, "n": n, "m": m}
    return _mlstm_out(p, h, z, cfg), new_state


def mlstm_decode(p, x, cfg: ModelConfig, state):
    """One-token decode.  state: dict(conv, c, n, m)."""
    q, k, v, ilog, flog, z, new_conv = _mlstm_qkv(p, x, cfg,
                                                  conv_state=state["conv"])
    qb, kb, vb = q[:, 0], k[:, 0], v[:, 0]               # (B,H,D)
    il, fl = ilog[:, 0], flog[:, 0]                      # (B,H)
    c, n, m = state["c"], state["n"], state["m"]
    m_new = jnp.maximum(fl + m, il)
    decay = jnp.exp(fl + m - m_new)
    inw = jnp.exp(il - m_new)
    c = decay[..., None, None] * c + inw[..., None, None] * (
        kb[..., :, None] * vb[..., None, :])
    n = decay[..., None] * n + inw[..., None] * kb
    scale = 1.0 / np.sqrt(qb.shape[-1])
    numer = jnp.einsum("bhd,bhde->bhe", qb * scale, c)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qb * scale, n)),
                        jnp.exp(-m_new))
    h = (numer / denom[..., None])[:, None]              # (B,1,H,D)
    out = _mlstm_out(p, h.astype(x.dtype), z, cfg)
    return out, {"conv": new_conv, "c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = cfg.slstm_head_dim or d // nh
    ks = jax.random.split(key, 10)
    p = {"out_norm": jnp.ones((nh * dh,), jnp.float32),
         "down": _init(ks[8], (nh * dh, d))}
    for gi, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = _init(ks[gi], (d, nh * dh))
        p[f"r{g}"] = _init(ks[4 + gi], (nh, dh, dh), scale=1.0 / np.sqrt(dh))
        p[f"b{g}"] = (jnp.full((nh * dh,), 3.0, jnp.float32) if g == "f"
                      else jnp.zeros((nh * dh,), jnp.float32))
    return p


def _slstm_cell(p, xg, state, nh, dh):
    """One step.  xg: dict of per-gate input projections (B, nh, dh)."""
    c, n, m, h = state

    # ONE batched dot for all four recurrent gates (batch dim = head).
    # Two prior forms were measured worse on train_4k (EXPERIMENTS §Perf
    # X1/X2): einsum lowered to broadcast-mul-reduce (49 TB/step-group of
    # outer products), and four separate dots still materialised backward
    # outer products; the fused (nh, dh, 4*dh) dot gives XLA one dense
    # contraction in both directions.
    r_cat = jnp.concatenate([p[f"r{g}"] for g in "ifzo"], axis=-1)
    ht = jnp.swapaxes(h, 0, 1)                           # (nh, B, dh)
    rec_all = jax.lax.dot_general(
        ht, r_cat, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)              # (nh, B, 4*dh)
    rec_all = jnp.swapaxes(rec_all, 0, 1)                # (B, nh, 4*dh)
    rec = {g: rec_all[..., i * dh:(i + 1) * dh]
           for i, g in enumerate("ifzo")}
    il = xg["i"] + rec["i"]
    fl = xg["f"] + rec["f"]
    zv = jnp.tanh(xg["z"] + rec["z"])
    ov = jax.nn.sigmoid(xg["o"] + rec["o"])
    fl = jax.nn.log_sigmoid(fl)                          # stabilized f~
    m_new = jnp.maximum(fl + m, il)
    i_s = jnp.exp(il - m_new)
    f_s = jnp.exp(fl + m - m_new)
    c_new = f_s * c + i_s * zv
    n_new = f_s * n + i_s
    h_new = ov * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def slstm_apply(p, x, cfg: ModelConfig):
    bsz, s, d = x.shape
    nh = cfg.n_heads
    dh = (cfg.slstm_head_dim or d // nh)
    xg = {g: (x @ p[f"w{g}"] + p[f"b{g}"]).reshape(bsz, s, nh, dh)
          for g in "ifzo"}

    def step(state, xs):
        new = _slstm_cell(p, xs, state, nh, dh)
        return new, new[3]

    z0 = jnp.zeros((bsz, nh, dh), jnp.float32)
    state0 = (z0, z0, jnp.full((bsz, nh, dh), LOG_EPS, jnp.float32), z0)
    mv = lambda t: jnp.moveaxis(t, 1, 0)
    _, hseq = jax.lax.scan(step, state0, {g: mv(xg[g]) for g in "ifzo"})
    h = jnp.moveaxis(hseq, 0, 1).reshape(bsz, s, nh * dh)
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = (h * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]).astype(x.dtype)
    return h @ p["down"]


def slstm_prefill(p, x, cfg: ModelConfig, state):
    """Full-sequence sLSTM that also returns the final recurrent state."""
    bsz, s, d = x.shape
    nh = cfg.n_heads
    dh = (cfg.slstm_head_dim or d // nh)
    xg = {g: (x @ p[f"w{g}"] + p[f"b{g}"]).reshape(bsz, s, nh, dh)
          for g in "ifzo"}

    def step(st, xs):
        new = _slstm_cell(p, xs, st, nh, dh)
        return new, new[3]

    mv = lambda t: jnp.moveaxis(t, 1, 0)
    final, hseq = jax.lax.scan(step, state, {g: mv(xg[g]) for g in "ifzo"})
    h = jnp.moveaxis(hseq, 0, 1).reshape(bsz, s, nh * dh)
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = (h * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]).astype(x.dtype)
    return h @ p["down"], final


def slstm_decode(p, x, cfg: ModelConfig, state):
    """One-token decode.  state: tuple (c, n, m, h)."""
    bsz, _, d = x.shape
    nh = cfg.n_heads
    dh = (cfg.slstm_head_dim or d // nh)
    xg = {g: (x[:, 0] @ p[f"w{g}"] + p[f"b{g}"]).reshape(bsz, nh, dh)
          for g in "ifzo"}
    new = _slstm_cell(p, xg, state, nh, dh)
    h = new[3].reshape(bsz, 1, nh * dh)
    ms = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    h = (h * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]).astype(x.dtype)
    return h @ p["down"], new
