"""Mamba2 (SSD) mixer block — chunked parallel scan for training/prefill,
O(1) recurrent state for decode.

Follows the state-space-duality formulation (Dao & Gu, 2024): per head h,
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T     (state: P x N)
    y_t = C_t . h_t + D_h x_t
computed chunk-parallel: an intra-chunk quadratic term plus an inter-chunk
state scan.  The short causal conv on the (x, B, C) streams can optionally
run through the paper's FFT library (``use_fft_conv``, DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init, norm_init, norm_apply


def mamba2_init(key, cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.d_inner
    ns = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = din + 2 * ns
    ks = jax.random.split(key, 5)
    p = {
        # in_proj emits [z (gate), x, B, C, dt]
        "in_proj": _init(ks[0], (d, 2 * din + 2 * ns + nh)),
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_ch), scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),     # A = -exp(a_log)
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, nh))),
        "out_proj": _init(ks[2], (din, d)),
        "out_norm": jnp.ones((din,), jnp.float32),
    }
    return p


def _causal_conv(u, w, b, cfg: ModelConfig, init_state=None):
    """Depthwise causal conv along seq: u (B, S, C), w (K, C)."""
    k = w.shape[0]
    if cfg.use_fft_conv and init_state is None:
        from repro.core.fftconv import fft_conv
        # (B, S, C) -> (B, C, S) signals, depthwise kernels (C, K)
        y = fft_conv(jnp.moveaxis(u, -1, -2), w.T[None],   # broadcast batch
                     backend=cfg.fft_backend)
        y = jnp.moveaxis(y, -2, -1)
    else:
        if init_state is None:
            up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
        else:
            up = jnp.concatenate([init_state, u], axis=1)
        y = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(y + b)


def _ssd_chunked(x, dt, a, b_in, c_in, d_skip, cfg: ModelConfig,
                 init_state=None):
    """Chunk-parallel SSD.

    x: (B, S, H, P); dt: (B, S, H); a: (H,) negative decay rates;
    b_in/c_in: (B, S, N).  Returns y (B, S, H, P) and final state
    (B, H, P, N).
    """
    bsz, s, nh, hp = x.shape
    ns = b_in.shape[-1]
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    da = dt * a                                            # (B, S, H) <= 0
    xc = x.reshape(bsz, nc, q, nh, hp)
    dtc = dt.reshape(bsz, nc, q, nh)
    dac = da.reshape(bsz, nc, q, nh)
    bc = b_in.reshape(bsz, nc, q, ns)
    cc = c_in.reshape(bsz, nc, q, ns)

    seg = jnp.cumsum(dac, axis=2)                          # within-chunk csum
    # intra-chunk: L[t, u] = exp(seg_t - seg_u) for u <= t
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]    # (B,NC,q,q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bctn,bcun->bctu", cc, bc,
                    preferred_element_type=jnp.float32)     # (B,NC,q,q)
    dx = dtc[..., None] * xc                               # (B,NC,q,H,P)
    y_intra = jnp.einsum("bctu,bctuh,bcuhp->bcthp", cb, l_mat, dx,
                         preferred_element_type=jnp.float32)

    # chunk-final states: S_c = sum_u exp(seg_end - seg_u) B_u (dt_u x_u)
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)        # (B,NC,q,H)
    state_c = jnp.einsum("bcun,bcuh,bcuhp->bchpn", bc,
                         decay_to_end, dx,
                         preferred_element_type=jnp.float32)

    # inter-chunk scan: carry running state across chunks
    chunk_decay = jnp.exp(seg[:, :, -1, :])                # (B,NC,H)

    def scan_fn(h_prev, inp):
        s_c, g = inp                                       # (B,H,P,N), (B,H)
        h_new = h_prev * g[..., None, None] + s_c
        return h_new, h_prev

    h0 = (jnp.zeros((bsz, nh, hp, ns), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    sc_t = jnp.moveaxis(state_c, 1, 0)                     # (NC,B,H,P,N)
    gd_t = jnp.moveaxis(chunk_decay, 1, 0)                 # (NC,B,H)
    h_last, h_prevs = jax.lax.scan(scan_fn, h0, (sc_t, gd_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,NC,H,P,N)

    # inter-chunk contribution: y_t += C_t . (decay_from_start_t * h_prev)
    decay_from_start = jnp.exp(seg)                        # (B,NC,q,H)
    y_inter = jnp.einsum("bctn,bchpn,bcth->bcthp", cc, h_prevs,
                         decay_from_start,
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(bsz, s, nh, hp)
    y = y + d_skip[None, None, :, None] * x
    return y.astype(x.dtype), h_last


def _split_proj(p, u, cfg: ModelConfig):
    din, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = u[..., :din]
    xbc = u[..., din:din + din + 2 * ns]
    dt_raw = u[..., -nh:]
    return z, xbc, dt_raw


def mamba2_apply(p, x, cfg: ModelConfig):
    """Full-sequence mixer: x (B, S, d) -> (B, S, d)."""
    bsz, s, _ = x.shape
    din, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    u = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], cfg)
    xin = xbc[..., :din].reshape(bsz, s, nh, hp)
    b_in = xbc[..., din:din + ns]
    c_in = xbc[..., din + ns:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])            # (B,S,H)
    a = -jnp.exp(p["a_log"])
    y, _ = _ssd_chunked(xin, dt, a, b_in, c_in, p["d_skip"], cfg)
    y = y.reshape(bsz, s, din) * jax.nn.silu(z)
    # grouped RMS norm over inner dim
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]
         ).astype(x.dtype)
    return y @ p["out_proj"]


def mamba2_prefill(p, x, cfg: ModelConfig, state):
    """Full-sequence mixer that also returns decode state (conv tail + SSM)."""
    bsz, s, _ = x.shape
    din, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    u = x @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(p, u, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"], cfg)
    xin = xbc[..., :din].reshape(bsz, s, nh, hp)
    b_in = xbc[..., din:din + ns]
    c_in = xbc[..., din + ns:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, h_last = _ssd_chunked(xin, dt, a, b_in, c_in, p["d_skip"], cfg)
    y = y.reshape(bsz, s, din) * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]
         ).astype(x.dtype)
    k = p["conv_w"].shape[0]
    tail = jnp.pad(xbc_raw, ((0, 0), (max(k - 1 - s, 0), 0), (0, 0)))[:, -(k - 1):]
    new_state = {"conv": tail.astype(state["conv"].dtype), "ssm": h_last}
    return y @ p["out_proj"], new_state


def mamba2_decode(p, x, cfg: ModelConfig, state):
    """One-token decode: x (B, 1, d); state dict w/ 'conv' and 'ssm'."""
    bsz = x.shape[0]
    din, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    u = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    # conv via ring state (B, K-1, C)
    conv_in = jnp.concatenate([state["conv"], xbc], axis=1)
    k = p["conv_w"].shape[0]
    y = sum(conv_in[:, i:i + 1] * p["conv_w"][i] for i in range(k))
    xbc = jax.nn.silu(y + p["conv_b"])
    new_conv = conv_in[:, 1:]
    xin = xbc[..., :din].reshape(bsz, nh, hp)
    b_in = xbc[:, 0, din:din + ns]
    c_in = xbc[:, 0, din + ns:]
    dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])      # (B,H)
    a = -jnp.exp(p["a_log"])
    g = jnp.exp(dt * a)                                    # (B,H)
    h = state["ssm"]                                       # (B,H,P,N)
    h = h * g[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xin, b_in, dt)
    y = jnp.einsum("bn,bhpn->bhp", c_in, h)
    y = y + p["d_skip"][None, :, None] * xin
    y = y.reshape(bsz, 1, din) * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6) * p["out_norm"]
         ).astype(x.dtype)
    return y @ p["out_proj"], {"conv": new_conv, "ssm": h}
