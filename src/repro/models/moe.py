"""Token-choice top-k MoE with GShard-style group-wise capacity dispatch.

Routing is token-choice top-k (Qwen3-MoE / Phi-3.5-MoE convention).  Tokens
route within *groups* (one sequence per group, the GShard convention): each
group has per-expert capacity C = k * Tg * capacity_factor / E, which keeps
every dispatch tensor O(k * T * d) globally and makes the token dim shard
cleanly over the data axis while experts shard over the model axis (EP).

Dispatch avoids the O(T*E*C) one-hot of classic GShard: per (group, expert)
we ``top_k`` the assignment scores over the group's tokens, gather at most C
tokens, run the expert FFN as one batched (G, E, C, d) einsum, and
scatter-add weighted outputs back.  Overflow tokens are dropped (capacity
dropping); an auxiliary load-balance loss keeps drops rare.  ``dropless=True``
(decode) sets C = Tg so generation is never corrupted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init


def moe_init(key, cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {"router": _init(ks[0], (d, e), scale=0.02)}
    if cfg.mlp_type == "swiglu":
        p["wi"] = _init(ks[1], (e, d, ff))
        p["wg"] = _init(ks[2], (e, d, ff))
        p["wo"] = _init(ks[3], (e, ff, d))
    else:
        p["wi"] = _init(ks[1], (e, d, ff))
        p["wo"] = _init(ks[3], (e, ff, d))
    return p


def _capacity(cfg: ModelConfig, tg: int) -> int:
    c = int(np.ceil(cfg.n_experts_active * tg * cfg.capacity_factor
                    / cfg.n_experts))
    return max(1, min(c, tg))


def moe_apply(p, x, cfg: ModelConfig, *, dropless: bool = False,
              cap_scale: float = 1.0):
    """x: (B, S, d) -> (B, S, d), aux_loss (scalar).

    Groups = sequences (B groups of S tokens); decode (S==1) folds the whole
    batch into one group.

    dropless=True sets capacity = Tg — exact, used for DECODE where Tg = B
    is small.  For prefill use cap_scale (e.g. 2.0): capacity-with-headroom;
    cap = Tg there would materialise an (G, E, Tg, d) dispatch tensor
    (222 GB/device for qwen3-moe prefill_32k — measured).
    """
    b, s, d = x.shape
    if s == 1:                                   # decode: one group of B
        g, tg = 1, b
    else:
        g, tg = b, s
    e, k = cfg.n_experts, cfg.n_experts_active
    cap = tg if dropless else min(tg, int(_capacity(cfg, tg) * cap_scale))
    xf = x.reshape(g, tg, d)

    logits = (xf @ p["router"]).astype(jnp.float32)        # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                   # (G, Tg, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # per-token-per-expert combine weight (G, Tg, E), zero if not chosen
    rows = jnp.arange(tg)[None, :, None]
    gidx = jnp.arange(g)[:, None, None]
    combine = jnp.zeros((g, tg, e), probs.dtype).at[
        gidx, rows, topi].set(topw)

    # expert-side selection: top-C tokens per (group, expert)
    sel_w, sel_idx = jax.lax.top_k(combine.transpose(0, 2, 1), cap)  # (G,E,C)
    live = sel_w > 0.0
    xe = jnp.take_along_axis(
        xf[:, None], sel_idx[..., None].astype(jnp.int32), axis=2)   # (G,E,C,d)

    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) * \
            jnp.einsum("gecd,edf->gecf", xe, p["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["wi"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])          # (G, E, C, d)
    ye = ye * (sel_w * live)[..., None].astype(ye.dtype)

    out = jnp.zeros((g, tg, d), ye.dtype).at[
        jnp.arange(g)[:, None, None], sel_idx].add(ye, mode="drop")

    # Switch-style load-balance aux loss (per group, then averaged)
    me = probs.mean(axis=1)                                # (G, E)
    ce = combine.astype(jnp.bool_).astype(jnp.float32).mean(axis=1) * e / k
    aux = cfg.router_aux_weight * e * jnp.mean(jnp.sum(me * ce, axis=-1))
    return out.reshape(b, s, d).astype(x.dtype), aux
