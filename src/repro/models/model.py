"""The unified LM: config-driven assembly of every architecture in the pool.

Depth is ``repeat`` copies of a super-block (``cfg.block_pattern``), scanned
with stacked params — compile time is O(pattern), not O(layers), which keeps
the 512-device dry-runs of 94-layer models tractable.  Zamba2-style shared
blocks live outside the scan and are closed over (one copy of the weights,
applied every super-block).

Entry points:
  init_params / abstract_params         param pytrees (dict-of-dicts)
  forward(params, cfg, batch)           logits, aux
  loss_fn(params, cfg, batch)           scalar loss, metrics
  init_cache / decode_step              serving path (one token, cached)
  param_count / active_param_count      N for MODEL_FLOPS = 6*N*D
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import actsharding
from . import cache as cache_lib
from . import layers, moe, ssm, xlstm
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, blk: str, cfg: ModelConfig):
    if blk == "attn_mlp":
        k1, k2 = jax.random.split(key)
        return {"norm1": layers.norm_init(cfg), "attn": layers.attention_init(k1, cfg),
                "norm2": layers.norm_init(cfg), "mlp": layers.mlp_init(k2, cfg)}
    if blk == "attn_moe":
        k1, k2 = jax.random.split(key)
        return {"norm1": layers.norm_init(cfg), "attn": layers.attention_init(k1, cfg),
                "norm2": layers.norm_init(cfg), "moe": moe.moe_init(k2, cfg)}
    if blk == "fourier_mlp":
        return {"norm1": layers.norm_init(cfg), "norm2": layers.norm_init(cfg),
                "mlp": layers.mlp_init(key, cfg)}
    if blk == "mamba2":
        return {"norm": layers.norm_init(cfg), "mixer": ssm.mamba2_init(key, cfg)}
    if blk == "mlstm":
        return {"norm": layers.norm_init(cfg), "mixer": xlstm.mlstm_init(key, cfg)}
    if blk == "slstm":
        return {"norm": layers.norm_init(cfg), "mixer": xlstm.slstm_init(key, cfg)}
    if blk == "shared_attn":
        return {}                       # weights live in params["shared"]
    raise ValueError(blk)


def _superblock_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{j}": _block_init(ks[j], blk, cfg)
            for j, blk in enumerate(cfg.block_pattern)}


def init_params(key, cfg: ModelConfig):
    k_embed, k_blocks, k_shared = jax.random.split(key, 3)
    params = {"embed": layers.embedding_init(k_embed, cfg)}
    block_keys = jax.random.split(k_blocks, cfg.repeat)
    params["blocks"] = jax.vmap(
        lambda k: _superblock_init(k, cfg))(block_keys)
    if "shared_attn" in cfg.block_pattern:
        k1, k2 = jax.random.split(k_shared)
        params["shared"] = {
            "norm1": layers.norm_init(cfg),
            "attn": layers.attention_init(k1, cfg),
            "norm2": layers.norm_init(cfg),
            "mlp": layers.mlp_init(k2, cfg)}
    params["final_norm"] = layers.norm_init(cfg)
    dtype = jnp.dtype(cfg.dtype)
    if dtype != jnp.float32:
        params = jax.tree.map(lambda a: a.astype(dtype), params)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def param_count(tree) -> int:
    return int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree)))


def active_param_count(cfg: ModelConfig, tree) -> int:
    """Params touched per token (MoE: active experts only)."""
    total = param_count(tree)
    if cfg.n_experts == 0:
        return total
    # subtract the inactive fraction of expert weights
    def expert_size(sb):
        e_params = [v for k, v in sb.items() if k in ("wi", "wg", "wo")]
        return sum(int(np.prod(x.shape)) for x in e_params)
    moe_total = 0
    blocks = jax.tree.leaves  # noqa (visual aid only)
    for j, blk in enumerate(cfg.block_pattern):
        if blk == "attn_moe":
            sb = {k: v for k, v in
                  _abstract_block(cfg, j).items()}
            moe_total += expert_size(sb["moe"]) * cfg.repeat
    inactive = moe_total * (1.0 - cfg.n_experts_active / cfg.n_experts)
    return int(total - inactive)


def _abstract_block(cfg: ModelConfig, j: int):
    blk = cfg.block_pattern[j]
    return jax.eval_shape(
        lambda: _block_init(jax.random.PRNGKey(0), blk, cfg))


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _block_apply(bp, shared, blk: str, x, cfg: ModelConfig, positions):
    aux = jnp.zeros((), jnp.float32)
    if blk == "attn_mlp":
        x = x + layers.attention_apply(bp["attn"],
                                       layers.norm_apply(bp["norm1"], x, cfg),
                                       cfg, positions)
        x = x + layers.mlp_apply(bp["mlp"],
                                 layers.norm_apply(bp["norm2"], x, cfg), cfg)
    elif blk == "attn_moe":
        x = x + layers.attention_apply(bp["attn"],
                                       layers.norm_apply(bp["norm1"], x, cfg),
                                       cfg, positions)
        y, aux = moe.moe_apply(bp["moe"],
                               layers.norm_apply(bp["norm2"], x, cfg), cfg)
        x = x + y
    elif blk == "fourier_mlp":
        from repro.core.spectral import fourier_mix
        x = x + fourier_mix(layers.norm_apply(bp["norm1"], x, cfg),
                            backend=cfg.fft_backend)
        x = x + layers.mlp_apply(bp["mlp"],
                                 layers.norm_apply(bp["norm2"], x, cfg), cfg)
    elif blk == "mamba2":
        x = x + ssm.mamba2_apply(bp["mixer"],
                                 layers.norm_apply(bp["norm"], x, cfg), cfg)
    elif blk == "mlstm":
        x = x + xlstm.mlstm_apply(bp["mixer"],
                                  layers.norm_apply(bp["norm"], x, cfg), cfg)
    elif blk == "slstm":
        x = x + xlstm.slstm_apply(bp["mixer"],
                                  layers.norm_apply(bp["norm"], x, cfg), cfg)
    elif blk == "shared_attn":
        sp = shared
        x = x + layers.attention_apply(sp["attn"],
                                       layers.norm_apply(sp["norm1"], x, cfg),
                                       cfg, positions)
        x = x + layers.mlp_apply(sp["mlp"],
                                 layers.norm_apply(sp["norm2"], x, cfg), cfg)
    else:
        raise ValueError(blk)
    return x, aux


def hidden_states(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                  positions=None):
    """Trunk: embeddings -> scanned super-blocks -> final norm.
    Returns (x (B,S,d), aux_loss)."""
    if tokens is not None:
        x = layers.embed(params["embed"], tokens, cfg)
        b, s = tokens.shape
    else:
        assert embeds is not None, "need tokens or embeds"
        x = embeds
        b, s = embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    shared = params.get("shared")
    x = actsharding.constrain(x)        # pin batch sharding after the gather

    def superblock(x, sbp):
        x = actsharding.constrain(x)
        aux = jnp.zeros((), jnp.float32)
        for j, blk in enumerate(cfg.block_pattern):
            x, a = _block_apply(sbp[f"b{j}"], shared, blk, x, cfg, positions)
            aux = aux + a
        return x, aux

    if cfg.remat:
        superblock = jax.checkpoint(superblock)
    x, auxs = jax.lax.scan(superblock, x, params["blocks"])
    x = layers.norm_apply(params["final_norm"], x, cfg)
    return x, auxs.sum()


def _pad_bias(cfg: ModelConfig, dtype):
    return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                     0.0, -1e30).astype(dtype)


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            positions=None):
    """Returns (logits, aux_loss).  tokens (B,S) or embeds (B,S,d).

    ``cfg.input_mode`` picks the *default* input spec (dry-run/training);
    both kinds are accepted here — a VLM prefills on patch embeddings but
    can also run text-only on tokens.
    """
    x, aux = hidden_states(params, cfg, tokens=tokens, embeds=embeds,
                           positions=positions)
    logits = layers.unembed(params["embed"], x, cfg)
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits + _pad_bias(cfg, logits.dtype)
    return logits, aux


# sequence-chunk size for the CE head: bounds the live (B, chunk, V) logits
# slab — the full (B, S, V) tensor is never materialised (big-vocab models
# would otherwise spend tens of GB per device on it).
LOSS_CHUNK = 512


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: dict(tokens|embeds, labels, [mask]).  Next-token CE, computed
    over sequence chunks with rematerialisation."""
    x, aux = hidden_states(params, cfg, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"))
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    b, s, d = x.shape
    c = min(LOSS_CHUNK, s)
    n_chunks = s // c if s % c == 0 else 1
    if s % c != 0:
        c = s

    xc = x.reshape(b, n_chunks, c, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, c).swapaxes(0, 1)
    mc = mask.reshape(b, n_chunks, c).swapaxes(0, 1)

    @jax.checkpoint
    def ce_chunk(carry, inp):
        xb, lb, mb = inp
        logits = layers.unembed(params["embed"], xb, cfg)
        logits = (logits + _pad_bias(cfg, logits.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0] - lse
        return carry - jnp.sum(ll * mb), None

    ce_sum, _ = jax.lax.scan(ce_chunk, jnp.zeros((), jnp.float32),
                             (xc, lc, mc))
    ce = ce_sum / jnp.maximum(mask.sum(), 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32):
    """Stacked (repeat, ...) caches matching the param scan."""
    def one(_):
        return {f"b{j}": cache_lib.block_cache_init(blk, cfg, batch, max_len,
                                                    dtype)
                for j, blk in enumerate(cfg.block_pattern)}
    return jax.vmap(one)(jnp.arange(cfg.repeat))


def _block_prefill(bp, shared, blk: str, x, cfg: ModelConfig, cache,
                   positions):
    if blk in ("attn_mlp", "attn_moe"):
        h = layers.norm_apply(bp["norm1"], x, cfg)
        y, cache = layers.attention_prefill(bp["attn"], h, cfg, positions,
                                            cache)
        x = x + y
        h = layers.norm_apply(bp["norm2"], x, cfg)
        if blk == "attn_mlp":
            x = x + layers.mlp_apply(bp["mlp"], h, cfg)
        else:
            # prefill: capacity with headroom (dropless cap=Tg would
            # materialise a (G,E,Tg,d) dispatch tensor; see moe_apply)
            y, _ = moe.moe_apply(bp["moe"], h, cfg,
                                 cap_scale=cfg.moe_prefill_cap_scale)
            x = x + y
    elif blk == "fourier_mlp":
        from repro.core.spectral import fourier_mix
        x = x + fourier_mix(layers.norm_apply(bp["norm1"], x, cfg),
                            backend=cfg.fft_backend)
        x = x + layers.mlp_apply(bp["mlp"],
                                 layers.norm_apply(bp["norm2"], x, cfg), cfg)
    elif blk == "mamba2":
        y, cache = ssm.mamba2_prefill(bp["mixer"],
                                      layers.norm_apply(bp["norm"], x, cfg),
                                      cfg, cache)
        x = x + y
    elif blk == "mlstm":
        y, cache = xlstm.mlstm_prefill(bp["mixer"],
                                       layers.norm_apply(bp["norm"], x, cfg),
                                       cfg, cache)
        x = x + y
    elif blk == "slstm":
        y, cache = xlstm.slstm_prefill(bp["mixer"],
                                       layers.norm_apply(bp["norm"], x, cfg),
                                       cfg, cache)
        x = x + y
    elif blk == "shared_attn":
        sp = shared
        h = layers.norm_apply(sp["norm1"], x, cfg)
        y, cache = layers.attention_prefill(sp["attn"], h, cfg, positions,
                                            cache)
        x = x + y
        x = x + layers.mlp_apply(sp["mlp"],
                                 layers.norm_apply(sp["norm2"], x, cfg), cfg)
    else:
        raise ValueError(blk)
    return x, cache


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            cache=None, positions=None):
    """Serving prefill: forward over the prompt, caches populated.
    Returns (logits (B, S, V), cache')."""
    if tokens is not None:
        x = layers.embed(params["embed"], tokens, cfg)
        b, s = tokens.shape
    else:
        x = embeds
        b, s = embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    shared = params.get("shared")

    x = actsharding.constrain(x)

    def superblock(x, inp):
        sbp, sbc = inp
        x = actsharding.constrain(x)
        new_c = {}
        for j, blk in enumerate(cfg.block_pattern):
            x, c = _block_prefill(sbp[f"b{j}"], shared, blk, x, cfg,
                                  sbc[f"b{j}"], positions)
            new_c[f"b{j}"] = c
        return x, new_c

    x, new_cache = jax.lax.scan(superblock, x, (params["blocks"], cache))
    x = layers.norm_apply(params["final_norm"], x, cfg)
    logits = layers.unembed(params["embed"], x, cfg)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_bias
    return logits, new_cache


def _block_decode(bp, shared, blk: str, x, cfg: ModelConfig, cache, position):
    if blk in ("attn_mlp", "attn_moe"):
        h = layers.norm_apply(bp["norm1"], x, cfg)
        y, cache = layers.attention_decode(bp["attn"], h, cfg, cache, position)
        x = x + y
        h = layers.norm_apply(bp["norm2"], x, cfg)
        if blk == "attn_mlp":
            x = x + layers.mlp_apply(bp["mlp"], h, cfg)
        else:
            y, _ = moe.moe_apply(bp["moe"], h, cfg, dropless=True)
            x = x + y
    elif blk == "fourier_mlp":
        # parameter-free mixing degenerates at S=1: identity on decode
        x = x + layers.mlp_apply(bp["mlp"],
                                 layers.norm_apply(bp["norm2"], x, cfg), cfg)
    elif blk == "mamba2":
        y, cache = ssm.mamba2_decode(bp["mixer"],
                                     layers.norm_apply(bp["norm"], x, cfg),
                                     cfg, cache)
        x = x + y
    elif blk == "mlstm":
        y, cache = xlstm.mlstm_decode(bp["mixer"],
                                      layers.norm_apply(bp["norm"], x, cfg),
                                      cfg, cache)
        x = x + y
    elif blk == "slstm":
        y, cache = xlstm.slstm_decode(bp["mixer"],
                                      layers.norm_apply(bp["norm"], x, cfg),
                                      cfg, cache)
        x = x + y
    elif blk == "shared_attn":
        sp = shared
        h = layers.norm_apply(sp["norm1"], x, cfg)
        y, cache = layers.attention_decode(sp["attn"], h, cfg, cache, position)
        x = x + y
        x = x + layers.mlp_apply(sp["mlp"],
                                 layers.norm_apply(sp["norm2"], x, cfg), cfg)
    else:
        raise ValueError(blk)
    return x, cache


def decode_step(params, cfg: ModelConfig, tokens, cache, position):
    """One decode step.  tokens: (B,) int32; position: (B,) absolute
    position.  Returns (logits (B, V), cache').  Embedding-input archs
    (vlm/audio) still decode over tokens — the stub frontend only feeds
    prefill/training."""
    x = layers.embed(params["embed"], tokens[:, None], cfg)
    shared = params.get("shared")
    x = actsharding.constrain(x)

    def superblock(x, inp):
        sbp, sbc = inp
        x = actsharding.constrain(x)
        new_c = {}
        for j, blk in enumerate(cfg.block_pattern):
            x, c = _block_decode(sbp[f"b{j}"], shared, blk, x, cfg,
                                 sbc[f"b{j}"], position)
            new_c[f"b{j}"] = c
        return x, new_c

    x, new_cache = jax.lax.scan(superblock, x, (params["blocks"], cache))
    x = layers.norm_apply(params["final_norm"], x, cfg)
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    if cfg.padded_vocab != cfg.vocab_size:
        pad_bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_bias
    return logits, new_cache
