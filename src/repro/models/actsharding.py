"""Activation-sharding hints.

The SPMD partitioner loses the batch sharding at the embedding gather (the
table is vocab-sharded; the gather's output comes out replicated), after
which every downstream activation is global — measured 112 GB temp for
h2o-danube/train_4k instead of ~7 GB.  The fix is the standard one: pin the
batch axis of activations with ``with_sharding_constraint`` at the trunk
boundaries.

Model code stays mesh-agnostic: the launch layer installs a spec via
``activation_spec(mesh, batch_axes, model_axis)`` around tracing; without an
installed spec ``constrain`` is a no-op (unit tests, single device).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE: dict = {"mesh": None, "batch": None, "model": None}


@contextlib.contextmanager
def activation_spec(mesh: Mesh, batch_axes, model_axis: Optional[str] = None):
    old = dict(_STATE)
    _STATE.update(mesh=mesh, batch=batch_axes, model=model_axis)
    try:
        yield
    finally:
        _STATE.update(old)


def constrain(x, *, kind: str = "batch"):
    """Pin activation sharding.

    Batch axis always pins to the data axes.  For 3-D (B, S, d) hiddens the
    sequence axis additionally shards over ``model`` when divisible —
    Megatron-style sequence parallelism for the inter-block residuals: the
    scan carry saved for backward is then 1/model-size per device (the 94
    saved carries of qwen3-moe would otherwise be ~50 GB/device).  XLA
    inserts the all-gather before use / reduce-scatter after, fusing with
    the existing TP collectives.
    """
    mesh = _STATE["mesh"]
    if mesh is None or x.ndim == 0:
        return x
    model = _STATE["model"]
    spec_axes = [_STATE["batch"]] + [None] * (x.ndim - 1)
    if (x.ndim == 3 and model is not None
            and x.shape[1] % mesh.shape[model] == 0 and x.shape[1] > 1):
        spec_axes[1] = model
    spec = P(*spec_axes)
    # inside a (partial-)manual shard_map the constraint must bind to the
    # context's abstract mesh (its axis_types differ from the outer mesh)
    try:
        cur = jax.sharding.get_abstract_mesh()
        if cur is not None and cur.axis_names:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(cur, spec))
    except Exception:
        pass
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_tree(tree, **kw):
    return jax.tree.map(lambda v: constrain(v, **kw), tree)
