"""repro.models — config-driven model zoo (pure JAX, dict pytrees)."""
from .config import ModelConfig
from . import model, layers, moe, ssm, xlstm, cache
