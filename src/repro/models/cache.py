"""Decode-time state: KV caches (full + sliding-window ring), SSM and xLSTM
recurrent states.

A cache is a dict pytree so it stacks cleanly along the scan axis (one slice
per super-block repeat).  KV caches write at ``position`` (full) or
``position % window`` (ring) and carry an explicit per-slot position plane —
attention masking reads positions, never pointer arithmetic, so ring
wraparound falls out of the same streaming-softmax mask used in training
(sliding-window + causal + emptiness are all position predicates).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def kv_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    """KV cache for one attention layer.  Ring-sized for SWA archs."""
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, slots, kv, hd), dtype),
        "v": jnp.zeros((batch, slots, kv, hd), dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def kv_update(cache, k_new, v_new, position):
    """Insert one token's K/V.  k_new/v_new: (B, KV, D); position: (B,).

    Returns (cache', k_all, v_all, kv_positions) where kv_positions carries
    -1 for empty slots (masked off by the attention's position predicate).
    """
    slots = cache["k"].shape[1]
    b = k_new.shape[0]
    idx = position % slots
    rows = jnp.arange(b)
    k = cache["k"].at[rows, idx].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[rows, idx].set(v_new.astype(cache["v"].dtype))
    pos = cache["pos"].at[rows, idx].set(position)
    new = {"k": k, "v": v, "pos": pos}
    return new, k, v, pos


def ssm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
    }


def mlstm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    din = 2 * cfg.d_model
    nh = cfg.n_heads
    dh = din // nh
    return {
        "conv": jnp.zeros((batch, 3, din), dtype),
        "c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -30.0, jnp.float32),
    }


def slstm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    nh = cfg.n_heads
    dh = cfg.slstm_head_dim or cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return (z, z, jnp.full((batch, nh, dh), -30.0, jnp.float32), z)


def block_cache_init(block: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.float32):
    if block in ("attn_mlp", "attn_moe", "shared_attn"):
        return kv_init(cfg, batch, max_len, dtype)
    if block == "mamba2":
        return ssm_state_init(cfg, batch, dtype)
    if block == "mlstm":
        return mlstm_state_init(cfg, batch, dtype)
    if block == "slstm":
        return slstm_state_init(cfg, batch, dtype)
    if block == "fourier_mlp":
        return {}                     # parameter-free mixer: no decode state
    raise ValueError(block)
