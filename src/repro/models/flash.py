"""Streaming-softmax (flash) attention with a custom VJP.

``jax.lax.scan``'s autodiff saves every per-chunk intermediate for the
backward pass — for nemotron-4 train_4k that is ~13 GB *per chunk step*
per layer, which is why the naive scan version measured 659 GB temp.  The
flash formulation saves only (q, k, v, out, lse) and *recomputes* the
probability blocks in the backward scan — the standard FlashAttention-2
residual set, here in pure JAX (the kernel budget of this repo is reserved
for the paper's FFT hot spots, see DESIGN.md).

Supports GQA grouping, causal masking, sliding windows and padding via
position predicates — same semantics as the forward-only streaming version
in layers._attend_chunked (which remains the decode path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _mask(pb, qp, window, causal):
    m = pb[:, None, :] >= 0                       # padding
    if causal:
        m &= pb[:, None, :] <= qp[:, :, None]
    if window is not None:
        m &= pb[:, None, :] > (qp[:, :, None] - window)
    return m                                      # (B, Sq, C)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention(q, k, v, q_pos, kv_pos, chunk, window, causal):
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D); positions int32 (B,S*).
    Returns (B,Sq,H,D).  Differentiable in q, k, v."""
    out, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, chunk, window, causal)
    return out


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, chunk, window, causal):
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    c = min(chunk, skv)
    nc = -(-skv // c)
    pad = nc * c - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    scale = 1.0 / np.sqrt(d)
    qg = (q * scale).reshape(b, sq, kvh, g, d)

    def step(carry, i):
        # dynamic-slice chunks (stacked transposed copies would materialise
        # the whole K/V per layer — see layers._attend_chunked)
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * c, c, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * c, c, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(kv_pos, i * c, c, axis=1)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32)
        msk = _mask(pb, q_pos, window, causal)
        s = jnp.where(msk[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  jnp.arange(nc, dtype=jnp.int32))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).reshape(b, sq, h, d).astype(q.dtype)
    lse = m + jnp.log(l_safe)                     # (B,Sq,KV,G)
    return out, lse


def _flash_fwd(q, k, v, q_pos, kv_pos, chunk, window, causal):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, chunk, window, causal)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(chunk, window, causal, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    c = min(chunk, skv)
    nc = -(-skv // c)
    pad = nc * c - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    scale = 1.0 / np.sqrt(d)
    qg = (q * scale).reshape(b, sq, kvh, g, d)
    dog = dout.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    og = out.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    # delta = rowsum(dout * out)  (B,Sq,KV,G)
    delta = jnp.sum(dog * og, axis=-1)

    def step(dq_acc, i):
        kb = jax.lax.dynamic_slice_in_dim(k, i * c, c, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * c, c, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(kv_pos, i * c, c, axis=1)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32)
        msk = _mask(pb, q_pos, window, causal)
        s = jnp.where(msk[:, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                       # (B,Sq,KV,G,C)
        dv = jnp.einsum("bqkgc,bqkgd->bckd", p, dog,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", dog, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])                      # (B,Sq,KV,G,C)
        dq_acc = dq_acc + jnp.einsum("bqkgc,bckd->bqkgd", ds, kb,
                                     preferred_element_type=jnp.float32)
        dk = jnp.einsum("bqkgc,bqkgd->bckd", ds, qg,
                        preferred_element_type=jnp.float32)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0,
                                  jnp.arange(nc, dtype=jnp.int32))
    # scale folds into qg: dL/dq = scale * dL/dqg; dk already uses qg
    dq = (dq * scale).reshape(b, sq, h, d).astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, kvh, d)[:, :skv] \
        .astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, kvh, d)[:, :skv] \
        .astype(v.dtype)
    return dq, dk, dv, None, None


flash_attention.defvjp(_flash_fwd, _flash_bwd)
