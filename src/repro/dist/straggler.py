"""Straggler mitigation: deterministic work rebalancing + ejection policy.

Production fleets are heterogeneous in practice (thermal throttling, noisy
neighbours, a failing NIC); a synchronous data-parallel step runs at the
speed of the slowest host.  ``rebalance`` reassigns per-host work shares
inversely proportional to measured step times — deterministically, so every
host computes the identical assignment from the identical timing gossip and
no coordinator round is needed — and ``should_eject`` flags hosts so slow
that dropping them beats carrying them.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


def rebalance(times: Sequence[float], total: int, *,
              smoothing: float = 1.0,
              prev_assignment: Optional[Sequence[int]] = None) -> List[int]:
    """Split ``total`` work units over hosts inversely to ``times``.

    Guarantees: the result sums to ``total``, every host gets at least one
    unit, and the function is a pure deterministic map of its inputs (ties
    broken by speed then index).  At ``smoothing=1.0`` (the default) a
    slower host additionally never receives more than a faster one.
    ``smoothing`` in (0, 1] damps reassignment swings: the target share is
    ``smoothing * speed_share + (1 - smoothing) * previous_share`` (uniform
    when ``prev_assignment`` is None) — deliberately biased toward the
    previous assignment, so with a small ``smoothing`` a skewed
    ``prev_assignment`` can outweigh current speeds for a few rounds; the
    speed-monotonicity guarantee applies to the blended shares, not to raw
    speeds.
    """
    n = len(times)
    assert n > 0 and total >= n, (n, total)
    speed = np.array([1.0 / max(float(t), 1e-12) for t in times])
    share = speed / speed.sum()
    if smoothing < 1.0:
        if prev_assignment is not None:
            prev = np.asarray(prev_assignment, dtype=np.float64)
        else:
            prev = np.ones(n)
        prev_share = prev / prev.sum()
        share = smoothing * share + (1.0 - smoothing) * prev_share
        share = share / share.sum()

    # one guaranteed unit each, then largest-remainder apportionment of the
    # rest; the floor (and the remainder at equal floors) is monotone in
    # share, so hosts with larger blended shares never get fewer units —
    # which at smoothing=1.0 is the slower-never-gets-more invariant
    quota = (total - n) * share
    floors = np.floor(quota).astype(int)
    assign = 1 + floors
    leftover = total - int(assign.sum())
    rem = quota - floors
    order = sorted(range(n), key=lambda i: (-rem[i], -share[i], i))
    for i in order[:leftover]:
        assign[i] += 1
    return [int(a) for a in assign]


def should_eject(times: Sequence[float], *,
                 eject_threshold: float = 3.0) -> Tuple[List[int], float]:
    """Hosts slower than ``eject_threshold`` x the median step time.

    Returns ``(indices, median)``.  The median (not the mean) is the
    yardstick so one pathological host cannot mask itself by dragging the
    average up.
    """
    med = float(np.median(np.asarray(times, dtype=np.float64)))
    idx = [i for i, t in enumerate(times)
           if float(t) > eject_threshold * med]
    return idx, med
