"""Distributed pencil FFTs: the paper's Section 5 schedule at multi-device
scale.

The single-chip 2-D FFT in the paper is *local row FFTs -> global transpose
-> local column FFTs*; scaled across devices that global transpose becomes
an ``all_to_all`` over pencils (the slab/pencil decomposition every
distributed FFT library is built on).  Four transforms live here:

- :func:`pfft2`               2-D FFT, rows sharded over one mesh axis.  One
                              all_to_all replaces the HBM transpose; the
                              optional ``chunks=`` schedule splits the row
                              pass so each chunk's all_to_all can overlap the
                              next chunk's compute (the paper's
                              communication-hiding ambition, expressed as a
                              static interleaving XLA is free to pipeline).
- :func:`pfft2_hierarchical`  Two-hop transpose for a (pod, data) mesh: one
                              intra-pod all_to_all then one inter-pod
                              all_to_all, so the scarce pod-to-pod bandwidth
                              only ever carries already-pencilised tiles.
- :func:`pfft3`               3-D FFT over a 2-D process grid (pencil
                              decomposition proper; the paper's future-work
                              case): Z local, then two axis exchanges.
- :func:`pfft1d`              Distributed Bailey four-step for one giant 1-D
                              FFT: column FFTs, twiddle correction, row FFTs
                              with the two inter-step transposes as
                              all_to_alls.  Output stays in the four-step
                              (h, w) layout (flattened, row-sharded); the
                              matching ``inverse=True`` consumes exactly that
                              layout, so roundtrips are exact.
- :func:`prfft2` / :func:`pirfft2`  Real-input 2-D pencil FFT: the row pass
                              is an rfft (half the FLOPs), and the
                              all_to_all ships only the Hermitian-unique
                              half spectrum — the Nyquist column rides in
                              the DC column's imaginary plane (both are
                              real for real input), so exactly W/2 complex
                              pencils cross the wire: **half** of
                              :func:`pfft2`'s exchange bytes, the ROADMAP's
                              "halve the all_to_all bytes" follow-on.

Every all_to_all optionally passes through the compressed wire formats of
:mod:`repro.dist.compression` (``compress="bf16"``/``"int8"``), and records
its per-device payload bytes — as priced by
:func:`repro.dist.compression.wire_bytes` — in a module-level wire log
(:func:`reset_wire_log` / :func:`wire_log` / :func:`logged_exchange_bytes`)
so tests and benchmarks can pin *measured* exchange traffic against the
:func:`repro.tt.trace.trace_dist` prediction.  ``verify=True`` on
:func:`pfft2` / :func:`prfft2` / :func:`pirfft2` additionally checksums
every exchange in-graph (global payload energy) and retries the transform
once on mismatch — :class:`ExchangeIntegrityError` on a repeat failure,
never a silent wrong answer (see the exchange-integrity block below).

All local 1-D passes route through the plan registry
(:mod:`repro.core.plan`) via ``algo="auto"``, so the fused/Stockham kernels
and any autotune decisions from the single-chip path are reused per local
shape; ``backend="pallas"`` switches the local passes onto the Pallas
kernels.  Everything operates on :class:`~repro.core.complexmath.SplitComplex`
(separate re/im planes — no complex dtype anywhere, mirroring the Tensix
constraint).
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.complexmath import SplitComplex
from repro.core import fft1d
from repro.core import plan as plan_lib

from repro.resilience import faults as _faults

from ._compat import all_to_all, shard_map_unchecked
from .compression import all_to_all_compressed, wire_bytes


# ---------------------------------------------------------------------------
# Wire log: measured exchange traffic
# ---------------------------------------------------------------------------
# Every _a2a records the per-device payload it ships (as priced by
# compression.wire_bytes for its wire format) at trace time — payload shapes
# are static, so tracers log exactly what a real wire counter would.  The
# byte total is a plain running counter; per-entry records are kept in a
# bounded deque so a long-running loop that never resets cannot leak.

_WIRE_LOG = collections.deque(maxlen=1024)
_WIRE_TOTAL = 0


def reset_wire_log() -> None:
    global _WIRE_TOTAL
    _WIRE_TOTAL = 0
    _WIRE_LOG.clear()


def wire_log() -> list:
    """Recent entries ``{"tag", "method", "bytes"}``, one per all_to_all
    traced (most recent 1024)."""
    return list(_WIRE_LOG)


def logged_exchange_bytes() -> int:
    """Total per-device payload bytes shipped since the last reset."""
    return _WIRE_TOTAL


def _log_wire(tag: str, method: str, nbytes: int) -> None:
    global _WIRE_TOTAL
    _WIRE_TOTAL += nbytes
    _WIRE_LOG.append({"tag": tag, "method": method, "bytes": nbytes})


# ---------------------------------------------------------------------------
# Exchange integrity: energy checksum, verified post-exchange
# ---------------------------------------------------------------------------
# An all_to_all is a permutation of the payload, so the *global* payload
# energy (sum of squares, psum'd over the mesh axis) is conserved exactly —
# a lightweight in-graph checksum with no extra wire beyond two scalar
# psums.  A dropped shard removes ~1/p of the energy, a scaled/garbled
# payload shifts it, and a NaN/Inf poisons the comparison (NaN <= tol is
# False) — all detected by one relative-delta test.  ``verify=True`` on
# :func:`pfft2` / :func:`prfft2` / :func:`pirfft2` threads every exchange's
# delta out of the shard_map as a replicated scalar, checks it eagerly, and
# retries the whole transform **once** on mismatch (a transient wire fault
# does not recur; the injected ``dist.exchange`` faults are consumed on the
# first attempt, which is exactly the transient model).  A second mismatch
# raises :class:`ExchangeIntegrityError` — never a silent wrong answer.
# Lossy wire formats legitimately perturb energy, hence per-method
# tolerances.

_VERIFY_TOL = {"none": 1e-3, "bf16": 2e-2, "int8": 2e-2}

_EXCHANGE_LOG = collections.deque(maxlen=256)


class ExchangeIntegrityError(RuntimeError):
    """A pencil exchange failed its energy checksum even after retry."""

    def __init__(self, tag: str, delta: float, tol: float):
        self.tag, self.delta, self.tol = tag, delta, tol
        super().__init__(
            f"exchange checksum mismatch in {tag!r}: relative energy "
            f"delta {delta:.3g} > {tol:g} after retry")


def reset_exchange_log() -> None:
    _EXCHANGE_LOG.clear()


def exchange_log() -> list:
    """Recent verification events ``{"tag", "method", "delta", "ok",
    "attempt"}`` — one per verified transform attempt (most recent 256)."""
    return list(_EXCHANGE_LOG)


def _payload_energy(x: SplitComplex):
    return (jnp.sum(jnp.square(x.re.astype(jnp.float32)))
            + jnp.sum(jnp.square(x.im.astype(jnp.float32))))


def _wire_fault(y: SplitComplex, axis_name: str, tag: str) -> SplitComplex:
    """The ``dist.exchange`` fault site: corrupt the payload *received on
    device 0* (``lax.axis_index`` mask) when an armed spec fires.  Consulted
    at trace time — the pencil bodies are re-traced per transform call, so
    visit counting works, and a one-shot spec is consumed by the first
    attempt, leaving the retry clean."""
    spec = _faults.fire("dist.exchange", tag)
    if spec is None:
        return y
    bad = _faults.apply_corruption(y, spec)
    on0 = jax.lax.axis_index(axis_name) == 0
    return SplitComplex(jnp.where(on0, bad.re, y.re),
                        jnp.where(on0, bad.im, y.im))


def _max_delta(collect):
    d = collect[0]
    for extra in collect[1:]:
        d = jnp.maximum(d, extra)
    return d


def _run_verified(run, *, tag: str, method: str, retries: int = 1):
    """Eager driver for ``verify=True`` transforms: run, check the
    replicated delta, retry once, raise on repeat mismatch."""
    tol = _VERIFY_TOL.get(method, _VERIFY_TOL["none"])
    delta = float("nan")
    for attempt in range(1 + retries):
        out, d = run()
        delta = float(jax.device_get(d))
        ok = delta <= tol                    # NaN compares False: poisoned
        _EXCHANGE_LOG.append({"tag": tag, "method": method, "delta": delta,
                              "ok": bool(ok), "attempt": attempt})
        if ok:
            return out
    raise ExchangeIntegrityError(tag, delta, tol)


# ---------------------------------------------------------------------------
# Local helpers (run inside shard_map on per-device blocks)
# ---------------------------------------------------------------------------

def _fft_last(x: SplitComplex, *, inverse: bool, backend: str) -> SplitComplex:
    """1-D FFT of the last axis through the plan registry (algo="auto")."""
    pl = plan_lib.get_plan((x.shape[-1],), dtype=x.dtype, inverse=inverse,
                           backend=backend)
    return pl(x)


def _fft_axis(x: SplitComplex, axis: int, *, inverse: bool,
              backend: str) -> SplitComplex:
    re = jnp.moveaxis(x.re, axis, -1)
    im = jnp.moveaxis(x.im, axis, -1)
    y = _fft_last(SplitComplex(re, im), inverse=inverse, backend=backend)
    return SplitComplex(jnp.moveaxis(y.re, -1, axis),
                        jnp.moveaxis(y.im, -1, axis))


def _a2a(x: SplitComplex, axis_name: str, split_axis: int, concat_axis: int,
         *, method: str = "none", tag: str = "a2a",
         collect=None) -> SplitComplex:
    """One pencil exchange.  ``collect`` (a list) arms the energy checksum:
    the exchange's relative global-energy delta is appended as a traced
    replicated scalar for the transform body to return."""
    _log_wire(tag, method, wire_bytes((x.re, x.im), method))
    if collect is not None:
        e0 = jax.lax.psum(_payload_energy(x), axis_name)
    if method == "none":
        y = SplitComplex(
            all_to_all(x.re, axis_name, split_axis, concat_axis),
            all_to_all(x.im, axis_name, split_axis, concat_axis))
    else:
        y = SplitComplex(
            all_to_all_compressed(x.re, axis_name, split_axis, concat_axis,
                                  method),
            all_to_all_compressed(x.im, axis_name, split_axis, concat_axis,
                                  method))
    y = _wire_fault(y, axis_name, tag)
    if collect is not None:
        e1 = jax.lax.psum(_payload_energy(y), axis_name)
        collect.append(jnp.abs(e1 - e0) / (e0 + 1e-30))
    return y


def _swap_last2(x: SplitComplex) -> SplitComplex:
    return SplitComplex(jnp.swapaxes(x.re, -1, -2),
                        jnp.swapaxes(x.im, -1, -2))


# ---------------------------------------------------------------------------
# 2-D pencil FFT over one mesh axis
# ---------------------------------------------------------------------------

def pfft2(x: SplitComplex, mesh, axis: str = "data", *, chunks: int = 1,
          transposed_output: bool = True, inverse: bool = False,
          compress: str = "none", backend: str = "jnp",
          verify: bool = False) -> SplitComplex:
    """2-D FFT of a (H, W) array whose rows are sharded over ``axis``.

    Schedule per device (p = mesh size along ``axis``):

    1. local row FFTs on the (H/p, W) slab — in ``chunks`` slices, each
       immediately followed by its all_to_all so communication of chunk c
       can overlap compute of chunk c+1;
    2. all_to_all pencil transpose (H/p, W) -> (H, W/p);
    3. local column FFTs on the now-resident columns.

    With ``transposed_output=True`` (default) the result is returned as the
    (W, H) transpose — column-major frequencies — sharded over ``axis``;
    this needs *no second all_to_all* (only a local transpose), exactly like
    the paper's fused kernel leaves the transpose implicit.  With
    ``transposed_output=False`` a second all_to_all restores natural (H, W)
    row-sharded order, so ``pfft2(pfft2(x), inverse=True)`` roundtrips.
    ``compress`` routes the exchanges through the
    :mod:`repro.dist.compression` wire formats.  ``verify=True`` checksums
    every exchange (global payload energy, conserved by any permutation),
    retries the transform once on mismatch and raises
    :class:`ExchangeIntegrityError` if the retry fails too.
    """
    h, w = x.shape[-2], x.shape[-1]
    p = mesh.shape[axis]
    assert h % p == 0 and w % p == 0, (x.shape, p)
    assert (h // p) % chunks == 0, (h, p, chunks)

    def run(collect=None):
        def body(re, im):
            rows = re.shape[0]                   # H/p local rows
            rc = rows // chunks
            pieces = []
            for c in range(chunks):
                sl = slice(c * rc, (c + 1) * rc)
                y = _fft_last(SplitComplex(re[sl], im[sl]),
                              inverse=inverse, backend=backend)
                pieces.append(_a2a(y, axis, 1, 0, method=compress,
                                   tag="pfft2/a2a",
                                   collect=collect))  # (p*rc, W/p)
            if chunks == 1:
                z = pieces[0]
            else:
                # chunk-major (chunks, p, rc, W/p) -> natural (p, chunks, ..)
                sr = jnp.stack([q.re for q in pieces]) \
                        .reshape(chunks, p, rc, -1)
                si = jnp.stack([q.im for q in pieces]) \
                        .reshape(chunks, p, rc, -1)
                z = SplitComplex(sr.transpose(1, 0, 2, 3).reshape(h, -1),
                                 si.transpose(1, 0, 2, 3).reshape(h, -1))
            z = _fft_axis(z, 0, inverse=inverse, backend=backend)  # (H, W/p)
            if transposed_output:
                out = _swap_last2(z)             # (W/p, H): local only
            else:
                out = _a2a(z, axis, 0, 1, method=compress,
                           tag="pfft2/a2a_out",
                           collect=collect)      # (H/p, W): natural order
            if collect is None:
                return out
            return out, _max_delta(collect)

        out_spec = P(axis, None)
        outs = SplitComplex(out_spec, out_spec)
        fn = shard_map_unchecked(body, mesh=mesh,
                       in_specs=(P(axis, None), P(axis, None)),
                       out_specs=outs if collect is None else (outs, P()))
        return fn(x.re, x.im)

    if not verify:
        return run()
    return _run_verified(lambda: run(collect=[]), tag="pfft2",
                         method=compress)


# ---------------------------------------------------------------------------
# Real-input 2-D pencil FFT (the halved-exchange schedule)
# ---------------------------------------------------------------------------
# Layout of the exchanged/returned half spectrum ("packed"): an rfft row has
# W/2+1 bins, but bins 0 (DC) and W/2 (Nyquist) are exactly real, so the
# Nyquist bin is carried in the DC bin's imaginary slot.  W real samples
# become exactly W/2 complex values per row — information-tight — and the
# pencil exchange ships W/2 columns instead of pfft2's W.  After the column
# FFTs the packed column 0 holds FFT(dc_col) + i*FFT(nyq_col); because
# dc_col/nyq_col are real, :func:`unpack_half_spectrum` recovers both with
# the standard Hermitian untangle (a local O(H) post-pass, no extra wire).


def _pack_rows(y: SplitComplex) -> SplitComplex:
    """(..., W/2+1) row half-spectra -> (..., W/2) packed (Nyquist into the
    DC imaginary plane; both bins are exactly real for real input)."""
    hw = y.shape[-1] - 1
    return SplitComplex(
        y.re[..., :hw],
        jnp.concatenate([y.re[..., hw:], y.im[..., 1:hw]], axis=-1))


def _unpack_rows(z: SplitComplex) -> SplitComplex:
    """Inverse of :func:`_pack_rows`: (..., W/2) packed -> (..., W/2+1)."""
    zero = jnp.zeros_like(z.re[..., :1])
    return SplitComplex(
        jnp.concatenate([z.re[..., :1], z.re[..., 1:], z.im[..., :1]], -1),
        jnp.concatenate([zero, z.im[..., 1:], zero], -1))


def _split_packed_col(z: SplitComplex):
    """Hermitian-untangle one packed column Z = A + i*B (A, B the FFTs of
    two real length-H sequences) into (A, B).  Acts on the last axis."""
    h = z.shape[-1]
    idx = (-jnp.arange(h)) % h
    cr = jnp.take(z.re, idx, axis=-1)          # conj(Z[-k]): re
    ci = -jnp.take(z.im, idx, axis=-1)         # conj(Z[-k]): im
    a = SplitComplex((z.re + cr) * 0.5, (z.im + ci) * 0.5)
    b = SplitComplex((z.im - ci) * 0.5, (cr - z.re) * 0.5)
    return a, b


def unpack_half_spectrum(spec_t: SplitComplex) -> SplitComplex:
    """Expand :func:`prfft2`'s packed transposed output (..., W/2, H) into
    the standard transposed half spectrum (..., W/2+1, H) —
    ``numpy.fft.rfft2(x).T`` — by untangling the packed column 0 into the
    DC and Nyquist columns.  Pure jnp; run it on the gathered result (or
    any full-H shard)."""
    dc, nyq = _split_packed_col(
        SplitComplex(spec_t.re[..., 0, :], spec_t.im[..., 0, :]))
    cat = lambda r0, body, rn: jnp.concatenate(
        [r0[..., None, :], body, rn[..., None, :]], axis=-2)
    return SplitComplex(cat(dc.re, spec_t.re[..., 1:, :], nyq.re),
                        cat(dc.im, spec_t.im[..., 1:, :], nyq.im))


def pack_half_spectrum(spec_t: SplitComplex) -> SplitComplex:
    """Inverse of :func:`unpack_half_spectrum`: fold a standard transposed
    half spectrum (..., W/2+1, H) into the packed (..., W/2, H) layout
    :func:`pirfft2` consumes (row 0 := DC + i*Nyquist)."""
    dc = SplitComplex(spec_t.re[..., 0, :], spec_t.im[..., 0, :])
    ny = SplitComplex(spec_t.re[..., -1, :], spec_t.im[..., -1, :])
    row0_re = dc.re - ny.im
    row0_im = dc.im + ny.re
    return SplitComplex(
        jnp.concatenate([row0_re[..., None, :], spec_t.re[..., 1:-1, :]], -2),
        jnp.concatenate([row0_im[..., None, :], spec_t.im[..., 1:-1, :]], -2))


def _fit_last(x: SplitComplex, n: int) -> SplitComplex:
    """Truncate / zero-pad the last axis to ``n`` (numpy ``fft(a, n=...)``
    semantics: crop or append trailing zeros)."""
    cur = x.shape[-1]
    if cur == n:
        return x
    if cur > n:
        return SplitComplex(x.re[..., :n], x.im[..., :n])
    pad = [(0, 0)] * (x.re.ndim - 1) + [(0, n - cur)]
    return SplitComplex(jnp.pad(x.re, pad), jnp.pad(x.im, pad))


def prfft2(x: jnp.ndarray, mesh, axis: str = "data", *,
           transposed_output: bool = True, compress: str = "none",
           backend: str = "jnp", verify: bool = False) -> SplitComplex:
    """Real-input 2-D pencil FFT of a real (H, W) array row-sharded over
    ``axis``: the distributed :func:`repro.core.fft2d.rfft2`.

    Schedule per device (p = mesh size along ``axis``):

    1. local row rfft via the plan registry's ``kind="rfft"`` entries
       ((H/p, W) real -> (H/p, W/2+1) half spectra, half the row FLOPs;
       ``backend="pallas"`` runs the inner transform on the 1-D kernels);
    2. pack: Nyquist bin into the DC bin's imaginary plane -> (H/p, W/2);
    3. all_to_all of the W/2 packed pencils — **half** of :func:`pfft2`'s
       exchange bytes — to (H, W/(2p));
    4. local column FFTs on the full-height packed pencils.

    Output (default) is the packed transposed half spectrum (W/2, H)
    sharded over ``axis``; :func:`unpack_half_spectrum` expands it to the
    standard (W/2+1, H) = ``rfft2(x).T``.  ``transposed_output=False``
    spends a second (still packed, still halved) all_to_all to return the
    natural row-sharded (H/p, W/2) layout instead.  ``verify=True``
    checksums the exchanges as in :func:`pfft2`.
    """
    h, w = x.shape[-2], x.shape[-1]
    p = mesh.shape[axis]
    assert w % 2 == 0, f"prfft2 needs an even width, got {x.shape}"
    assert h % p == 0 and (w // 2) % p == 0, (x.shape, p)

    def run(collect=None):
        def body(xr):
            pl = plan_lib.get_plan((w,), dtype=xr.dtype, kind="rfft",
                                   backend=backend)
            y = _pack_rows(pl(xr))               # (H/p, W/2) packed
            z = _a2a(y, axis, 1, 0, method=compress,
                     tag="prfft2/a2a", collect=collect)  # (H, W/(2p))
            z = _fft_axis(z, 0, inverse=False, backend=backend)
            if transposed_output:
                out = _swap_last2(z)             # (W/(2p), H)
            else:
                out = _a2a(z, axis, 0, 1, method=compress,
                           tag="prfft2/a2a_out",
                           collect=collect)      # (H/p, W/2) natural
            if collect is None:
                return out
            return out, _max_delta(collect)

        out_spec = P(axis, None)
        outs = SplitComplex(out_spec, out_spec)
        fn = shard_map_unchecked(body, mesh=mesh, in_specs=(P(axis, None),),
                                 out_specs=outs if collect is None
                                 else (outs, P()))
        return fn(x)

    if not verify:
        return run()
    return _run_verified(lambda: run(collect=[]), tag="prfft2",
                         method=compress)


def pirfft2(xf: SplitComplex, mesh, axis: str = "data", *, s=None,
            compress: str = "none", backend: str = "jnp",
            verify: bool = False) -> jnp.ndarray:
    """Inverse of :func:`prfft2`: packed transposed half spectrum (W/2, H)
    sharded over ``axis`` -> real (H, W) row-sharded.

    ``s=(h, w)`` follows ``numpy.fft.irfft2`` truncate/pad semantics.  Both
    fits are *local*: the H fit happens on the full-height pencils before
    the inverse column FFTs, and the W fit on the complete row half-spectra
    after the exchange — so explicit shapes never cost extra wire.
    """
    hw, h_in = xf.shape[-2], xf.shape[-1]
    w_full = 2 * hw
    p = mesh.shape[axis]
    h_out, w_out = (int(s[0]), int(s[1])) if s is not None else (h_in, w_full)
    assert w_out % 2 == 0 and w_out >= 2, \
        f"pirfft2 needs an even output width, got s={s}"
    assert hw % p == 0 and h_out % p == 0, (xf.shape, s, p)

    def run(collect=None):
        def body(re, im):
            zin = SplitComplex(re, im)               # (W/(2p), h_in)
            z = _fit_last(zin, h_out)                # numpy ifft n= fit
            z = _fft_last(z, inverse=True, backend=backend)  # (W/(2p), h_out)
            if h_out != h_in:
                # the H fit breaks the packed column's Hermitian symmetry (a
                # cropped/padded DC column no longer inverse-transforms to a
                # real signal), so the packed column is untangled at full
                # height, fitted and transformed as two real columns, and
                # spliced back on the device that owns global column 0
                dc, ny = _split_packed_col(
                    SplitComplex(zin.re[0], zin.im[0]))
                a = _fft_last(_fit_last(dc, h_out), inverse=True,
                              backend=backend)
                b = _fft_last(_fit_last(ny, h_out), inverse=True,
                              backend=backend)
                own0 = jax.lax.axis_index(axis) == 0
                z = SplitComplex(
                    z.re.at[0].set(jnp.where(own0, a.re, z.re[0])),
                    z.im.at[0].set(jnp.where(own0, b.re, z.im[0])))
            z = _a2a(z, axis, 1, 0, method=compress,
                     tag="pirfft2/a2a", collect=collect)  # (W/2, h_out/p)
            z = _swap_last2(z)                       # (h_out/p, W/2) packed
            half = fft1d._fit_half_spectrum(_unpack_rows(z), w_out)
            pl = plan_lib.get_plan((w_out,), dtype=z.dtype, kind="rfft",
                                   inverse=True, backend=backend)
            out = pl(half)                           # real (h_out/p, w_out)
            if collect is None:
                return out
            return out, _max_delta(collect)

        out_spec = P(axis, None)
        fn = shard_map_unchecked(body, mesh=mesh,
                                 in_specs=(P(axis, None), P(axis, None)),
                                 out_specs=out_spec if collect is None
                                 else (out_spec, P()))
        return fn(xf.re, xf.im)

    if not verify:
        return run()
    return _run_verified(lambda: run(collect=[]), tag="pirfft2",
                         method=compress)


def exchange_bytes(h: int, w: int, devices: int, *, real: bool = False,
                   method: str = "none", dtype=jnp.float32,
                   transposed_output: bool = True) -> int:
    """Per-device all_to_all *payload* bytes of one :func:`pfft2` /
    :func:`prfft2` call — exactly what the wire log records.
    :func:`repro.tt.trace.trace_dist` prices the (devices-1)/devices
    fraction of this that actually leaves the chip.  ``real=True`` halves
    the column count (the packed half spectrum); the per-element wire
    width derives from :func:`repro.dist.compression.wire_bytes` on a
    probe leaf so the two pricings can never drift."""
    import numpy as np
    cols = w // 2 if real else w
    legs = 1 if transposed_output else 2
    per_elem = wire_bytes(np.zeros((1,), jnp.dtype(dtype)), method)
    return legs * 2 * (h // devices) * cols * per_elem


# ---------------------------------------------------------------------------
# Hierarchical two-hop transpose (multi-pod)
# ---------------------------------------------------------------------------

def pfft2_hierarchical(x: SplitComplex, mesh, pod_axis: str = "pod",
                       data_axis: str = "data", *, inverse: bool = False,
                       backend: str = "jnp") -> SplitComplex:
    """2-D pencil FFT on a (pod, data) mesh with a two-hop transpose.

    Rows are sharded over *both* axes (``P((pod, data), None)``).  Instead of
    one flat all_to_all over all pod*data devices, the pencil exchange runs
    as (1) an intra-pod all_to_all over ``data_axis`` — the cheap hop, full
    row blocks — then (2) an inter-pod all_to_all over ``pod_axis`` that
    only moves already-narrowed (W/data) pencils.  Output is the (W, H)
    transpose sharded ``P((data, pod), None)`` — the data-major tiling is
    what makes the two-hop chunk order line up with the natural column
    order, so no cross-device reshuffle is ever needed.
    """
    h, w = x.shape[-2], x.shape[-1]
    np_, nd = mesh.shape[pod_axis], mesh.shape[data_axis]
    ndev = np_ * nd
    assert h % ndev == 0 and w % ndev == 0, (x.shape, np_, nd)

    def body(re, im):
        y = _fft_last(SplitComplex(re, im), inverse=inverse, backend=backend)
        # hop 1 (intra-pod): (H/(np*nd), W) -> (H/np, W/nd); rows stay
        # natural because each pod's devices hold contiguous row blocks
        y = _a2a(y, data_axis, 1, 0)
        # hop 2 (inter-pod): (H/np, W/nd) -> (H, W/(nd*np)); peer-major
        # concat over pods is again the natural row order
        y = _a2a(y, pod_axis, 1, 0)
        y = _fft_axis(y, 0, inverse=inverse, backend=backend)
        return _swap_last2(y)                    # (W/(nd*np), H)

    out_spec = P((data_axis, pod_axis), None)
    fn = shard_map_unchecked(body, mesh=mesh,
                   in_specs=(P((pod_axis, data_axis), None),) * 2,
                   out_specs=SplitComplex(out_spec, out_spec))
    return fn(x.re, x.im)


# ---------------------------------------------------------------------------
# 3-D pencil FFT over a 2-D process grid
# ---------------------------------------------------------------------------

def pfft3(x: SplitComplex, mesh, axes=("data", "model"), *,
          inverse: bool = False, backend: str = "jnp") -> SplitComplex:
    """3-D FFT of an (X, Y, Z) array on a 2-D process grid — the pencil
    decomposition proper (the paper's future-work case).

    Input is sharded ``P(axes[0], axes[1], None)``: every device owns a
    Z-pencil.  Three local FFT passes separated by two single-axis
    all_to_alls (never a global one):

    1. FFT along Z (local);
    2. all_to_all over ``axes[1]``: trade Z for Y -> Y-pencils; FFT along Y;
    3. all_to_all over ``axes[0]``: trade Y for X -> X-pencils; FFT along X.

    Output is returned transposed to (Z, Y, X) — a local transpose of the
    final X-pencils — sharded ``P(axes[1], axes[0], None)``.
    """
    a, b = axes
    na, nb = mesh.shape[a], mesh.shape[b]
    gx, gy, gz = x.shape[-3], x.shape[-2], x.shape[-1]
    assert gx % na == 0 and gy % (na * nb) == 0 and gz % nb == 0, \
        (x.shape, na, nb)

    def body(re, im):
        z = _fft_last(SplitComplex(re, im), inverse=inverse, backend=backend)
        z = _a2a(z, b, 2, 1)                     # (X/na, Y, Z/nb)
        z = _fft_axis(z, 1, inverse=inverse, backend=backend)
        z = _a2a(z, a, 1, 0)                     # (X, Y/na, Z/nb)
        z = _fft_axis(z, 0, inverse=inverse, backend=backend)
        t = lambda q: jnp.transpose(q, (2, 1, 0))
        return SplitComplex(t(z.re), t(z.im))    # (Z/nb, Y/na, X)

    out_spec = P(b, a, None)
    fn = shard_map_unchecked(body, mesh=mesh, in_specs=(P(a, b, None),) * 2,
                   out_specs=SplitComplex(out_spec, out_spec))
    return fn(x.re, x.im)


# ---------------------------------------------------------------------------
# Distributed 1-D four-step FFT
# ---------------------------------------------------------------------------

def fourstep_split(n: int, p: int) -> tuple:
    """Pick the (h, w) four-step factorisation of ``n`` on ``p`` devices:
    start at the flattest shard-compatible shape (p, n/p) and square it up
    while the column count stays even and shardable.  Deterministic, and
    mirrored by the tests so layouts agree."""
    h, w = p, n // p
    while (w > 2 * h) and (w % 2 == 0) and ((w // 2) % p == 0):
        h, w = h * 2, w // 2
    return h, w


def _fourstep_twiddle(h: int, w: int, j2, *, inverse: bool, dtype):
    """T[k1, j2] = exp(-+ 2*pi*i * k1*j2 / n) for the local column block.

    k1*j2 < h*w = n, so the integer product is exact and the angle argument
    never loses precision to a large-phase reduction.
    """
    n = h * w
    k1 = jnp.arange(h, dtype=jnp.int32)[:, None]
    prod = (k1 * j2[None, :]).astype(jnp.float32)
    ang = (2.0 * jnp.pi / n) * prod
    sign = 1.0 if inverse else -1.0
    return SplitComplex(jnp.cos(ang).astype(dtype),
                        (sign * jnp.sin(ang)).astype(dtype))


def pfft1d(x: SplitComplex, mesh, axis: str = "data", *,
           inverse: bool = False, backend: str = "jnp") -> SplitComplex:
    """One giant 1-D FFT sharded over ``axis``: distributed Bailey four-step.

    The length-n sequence is viewed as an (h, w) matrix (row-major,
    ``fourstep_split``): column FFTs of length h, the W_n^{k1*j2} twiddle
    correction, then row FFTs of length w.  The two inter-step transposes
    are the all_to_alls.  The final four-step output transpose is *not*
    performed: the result is the (h, w) frequency matrix flattened row-major
    and row-sharded, i.e. ``out.reshape(h, w).T.ravel()`` is ``fft(x)``.
    ``inverse=True`` consumes exactly this layout and returns natural-order
    samples, so forward->inverse roundtrips bit-exactly in layout.
    """
    (n,) = x.shape
    p = mesh.shape[axis]
    assert n % p == 0, (n, p)
    h, w = fourstep_split(n, p)
    assert h % p == 0 and w % p == 0, (h, w, p)

    def fwd(re, im):
        loc = SplitComplex(re.reshape(h // p, w), im.reshape(h // p, w))
        zz = _a2a(loc, axis, 1, 0)               # (h, w/p): full columns
        zz = _fft_axis(zz, 0, inverse=False, backend=backend)
        d = jax.lax.axis_index(axis)
        j2 = d * (w // p) + jnp.arange(w // p, dtype=jnp.int32)
        t = _fourstep_twiddle(h, w, j2, inverse=False, dtype=zz.dtype)
        zz = SplitComplex(zz.re * t.re - zz.im * t.im,
                          zz.re * t.im + zz.im * t.re)
        zz = _a2a(zz, axis, 0, 1)                # (h/p, w): full rows
        zz = _fft_last(zz, inverse=False, backend=backend)
        return SplitComplex(zz.re.reshape(-1), zz.im.reshape(-1))

    def inv(re, im):
        loc = SplitComplex(re.reshape(h // p, w), im.reshape(h // p, w))
        zz = _fft_last(loc, inverse=True, backend=backend)      # 1/w scale
        zz = _a2a(zz, axis, 1, 0)                # (h, w/p)
        d = jax.lax.axis_index(axis)
        j2 = d * (w // p) + jnp.arange(w // p, dtype=jnp.int32)
        t = _fourstep_twiddle(h, w, j2, inverse=True, dtype=zz.dtype)
        zz = SplitComplex(zz.re * t.re - zz.im * t.im,
                          zz.re * t.im + zz.im * t.re)
        zz = _fft_axis(zz, 0, inverse=True, backend=backend)    # 1/h scale
        zz = _a2a(zz, axis, 0, 1)                # (h/p, w)
        return SplitComplex(zz.re.reshape(-1), zz.im.reshape(-1))

    fn = shard_map_unchecked(inv if inverse else fwd, mesh=mesh,
                   in_specs=(P(axis), P(axis)),
                   out_specs=SplitComplex(P(axis), P(axis)))
    return fn(x.re, x.im)
