"""Distributed pencil FFTs: the paper's Section 5 schedule at multi-device
scale.

The single-chip 2-D FFT in the paper is *local row FFTs -> global transpose
-> local column FFTs*; scaled across devices that global transpose becomes
an ``all_to_all`` over pencils (the slab/pencil decomposition every
distributed FFT library is built on).  Four transforms live here:

- :func:`pfft2`               2-D FFT, rows sharded over one mesh axis.  One
                              all_to_all replaces the HBM transpose; the
                              optional ``chunks=`` schedule splits the row
                              pass so each chunk's all_to_all can overlap the
                              next chunk's compute (the paper's
                              communication-hiding ambition, expressed as a
                              static interleaving XLA is free to pipeline).
- :func:`pfft2_hierarchical`  Two-hop transpose for a (pod, data) mesh: one
                              intra-pod all_to_all then one inter-pod
                              all_to_all, so the scarce pod-to-pod bandwidth
                              only ever carries already-pencilised tiles.
- :func:`pfft3`               3-D FFT over a 2-D process grid (pencil
                              decomposition proper; the paper's future-work
                              case): Z local, then two axis exchanges.
- :func:`pfft1d`              Distributed Bailey four-step for one giant 1-D
                              FFT: column FFTs, twiddle correction, row FFTs
                              with the two inter-step transposes as
                              all_to_alls.  Output stays in the four-step
                              (h, w) layout (flattened, row-sharded); the
                              matching ``inverse=True`` consumes exactly that
                              layout, so roundtrips are exact.

All local 1-D passes route through the plan registry
(:mod:`repro.core.plan`) via ``algo="auto"``, so the fused/Stockham kernels
and any autotune decisions from the single-chip path are reused per local
shape; ``backend="pallas"`` switches the local passes onto the Pallas
kernels.  Everything operates on :class:`~repro.core.complexmath.SplitComplex`
(separate re/im planes — no complex dtype anywhere, mirroring the Tensix
constraint).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.complexmath import SplitComplex
from repro.core import plan as plan_lib

from ._compat import all_to_all, shard_map_unchecked


# ---------------------------------------------------------------------------
# Local helpers (run inside shard_map on per-device blocks)
# ---------------------------------------------------------------------------

def _fft_last(x: SplitComplex, *, inverse: bool, backend: str) -> SplitComplex:
    """1-D FFT of the last axis through the plan registry (algo="auto")."""
    pl = plan_lib.get_plan((x.shape[-1],), dtype=x.dtype, inverse=inverse,
                           backend=backend)
    return pl(x)


def _fft_axis(x: SplitComplex, axis: int, *, inverse: bool,
              backend: str) -> SplitComplex:
    re = jnp.moveaxis(x.re, axis, -1)
    im = jnp.moveaxis(x.im, axis, -1)
    y = _fft_last(SplitComplex(re, im), inverse=inverse, backend=backend)
    return SplitComplex(jnp.moveaxis(y.re, -1, axis),
                        jnp.moveaxis(y.im, -1, axis))


def _a2a(x: SplitComplex, axis_name: str, split_axis: int,
         concat_axis: int) -> SplitComplex:
    return SplitComplex(all_to_all(x.re, axis_name, split_axis, concat_axis),
                        all_to_all(x.im, axis_name, split_axis, concat_axis))


def _swap_last2(x: SplitComplex) -> SplitComplex:
    return SplitComplex(jnp.swapaxes(x.re, -1, -2),
                        jnp.swapaxes(x.im, -1, -2))


# ---------------------------------------------------------------------------
# 2-D pencil FFT over one mesh axis
# ---------------------------------------------------------------------------

def pfft2(x: SplitComplex, mesh, axis: str = "data", *, chunks: int = 1,
          transposed_output: bool = True, inverse: bool = False,
          backend: str = "jnp") -> SplitComplex:
    """2-D FFT of a (H, W) array whose rows are sharded over ``axis``.

    Schedule per device (p = mesh size along ``axis``):

    1. local row FFTs on the (H/p, W) slab — in ``chunks`` slices, each
       immediately followed by its all_to_all so communication of chunk c
       can overlap compute of chunk c+1;
    2. all_to_all pencil transpose (H/p, W) -> (H, W/p);
    3. local column FFTs on the now-resident columns.

    With ``transposed_output=True`` (default) the result is returned as the
    (W, H) transpose — column-major frequencies — sharded over ``axis``;
    this needs *no second all_to_all* (only a local transpose), exactly like
    the paper's fused kernel leaves the transpose implicit.  With
    ``transposed_output=False`` a second all_to_all restores natural (H, W)
    row-sharded order, so ``pfft2(pfft2(x), inverse=True)`` roundtrips.
    """
    h, w = x.shape[-2], x.shape[-1]
    p = mesh.shape[axis]
    assert h % p == 0 and w % p == 0, (x.shape, p)
    assert (h // p) % chunks == 0, (h, p, chunks)

    def body(re, im):
        rows = re.shape[0]                       # H/p local rows
        rc = rows // chunks
        pieces = []
        for c in range(chunks):
            sl = slice(c * rc, (c + 1) * rc)
            y = _fft_last(SplitComplex(re[sl], im[sl]),
                          inverse=inverse, backend=backend)
            pieces.append(_a2a(y, axis, 1, 0))   # (p*rc, W/p), peer-major
        if chunks == 1:
            z = pieces[0]
        else:
            # chunk-major (chunks, p, rc, W/p) -> row-natural (p, chunks, ..)
            sr = jnp.stack([q.re for q in pieces]).reshape(chunks, p, rc, -1)
            si = jnp.stack([q.im for q in pieces]).reshape(chunks, p, rc, -1)
            z = SplitComplex(sr.transpose(1, 0, 2, 3).reshape(h, -1),
                             si.transpose(1, 0, 2, 3).reshape(h, -1))
        z = _fft_axis(z, 0, inverse=inverse, backend=backend)  # (H, W/p)
        if transposed_output:
            return _swap_last2(z)                # (W/p, H): local only
        return _a2a(z, axis, 0, 1)               # (H/p, W): natural order

    out_spec = P(axis, None)
    fn = shard_map_unchecked(body, mesh=mesh,
                   in_specs=(P(axis, None), P(axis, None)),
                   out_specs=SplitComplex(out_spec, out_spec))
    return fn(x.re, x.im)


# ---------------------------------------------------------------------------
# Hierarchical two-hop transpose (multi-pod)
# ---------------------------------------------------------------------------

def pfft2_hierarchical(x: SplitComplex, mesh, pod_axis: str = "pod",
                       data_axis: str = "data", *, inverse: bool = False,
                       backend: str = "jnp") -> SplitComplex:
    """2-D pencil FFT on a (pod, data) mesh with a two-hop transpose.

    Rows are sharded over *both* axes (``P((pod, data), None)``).  Instead of
    one flat all_to_all over all pod*data devices, the pencil exchange runs
    as (1) an intra-pod all_to_all over ``data_axis`` — the cheap hop, full
    row blocks — then (2) an inter-pod all_to_all over ``pod_axis`` that
    only moves already-narrowed (W/data) pencils.  Output is the (W, H)
    transpose sharded ``P((data, pod), None)`` — the data-major tiling is
    what makes the two-hop chunk order line up with the natural column
    order, so no cross-device reshuffle is ever needed.
    """
    h, w = x.shape[-2], x.shape[-1]
    np_, nd = mesh.shape[pod_axis], mesh.shape[data_axis]
    ndev = np_ * nd
    assert h % ndev == 0 and w % ndev == 0, (x.shape, np_, nd)

    def body(re, im):
        y = _fft_last(SplitComplex(re, im), inverse=inverse, backend=backend)
        # hop 1 (intra-pod): (H/(np*nd), W) -> (H/np, W/nd); rows stay
        # natural because each pod's devices hold contiguous row blocks
        y = _a2a(y, data_axis, 1, 0)
        # hop 2 (inter-pod): (H/np, W/nd) -> (H, W/(nd*np)); peer-major
        # concat over pods is again the natural row order
        y = _a2a(y, pod_axis, 1, 0)
        y = _fft_axis(y, 0, inverse=inverse, backend=backend)
        return _swap_last2(y)                    # (W/(nd*np), H)

    out_spec = P((data_axis, pod_axis), None)
    fn = shard_map_unchecked(body, mesh=mesh,
                   in_specs=(P((pod_axis, data_axis), None),) * 2,
                   out_specs=SplitComplex(out_spec, out_spec))
    return fn(x.re, x.im)


# ---------------------------------------------------------------------------
# 3-D pencil FFT over a 2-D process grid
# ---------------------------------------------------------------------------

def pfft3(x: SplitComplex, mesh, axes=("data", "model"), *,
          inverse: bool = False, backend: str = "jnp") -> SplitComplex:
    """3-D FFT of an (X, Y, Z) array on a 2-D process grid — the pencil
    decomposition proper (the paper's future-work case).

    Input is sharded ``P(axes[0], axes[1], None)``: every device owns a
    Z-pencil.  Three local FFT passes separated by two single-axis
    all_to_alls (never a global one):

    1. FFT along Z (local);
    2. all_to_all over ``axes[1]``: trade Z for Y -> Y-pencils; FFT along Y;
    3. all_to_all over ``axes[0]``: trade Y for X -> X-pencils; FFT along X.

    Output is returned transposed to (Z, Y, X) — a local transpose of the
    final X-pencils — sharded ``P(axes[1], axes[0], None)``.
    """
    a, b = axes
    na, nb = mesh.shape[a], mesh.shape[b]
    gx, gy, gz = x.shape[-3], x.shape[-2], x.shape[-1]
    assert gx % na == 0 and gy % (na * nb) == 0 and gz % nb == 0, \
        (x.shape, na, nb)

    def body(re, im):
        z = _fft_last(SplitComplex(re, im), inverse=inverse, backend=backend)
        z = _a2a(z, b, 2, 1)                     # (X/na, Y, Z/nb)
        z = _fft_axis(z, 1, inverse=inverse, backend=backend)
        z = _a2a(z, a, 1, 0)                     # (X, Y/na, Z/nb)
        z = _fft_axis(z, 0, inverse=inverse, backend=backend)
        t = lambda q: jnp.transpose(q, (2, 1, 0))
        return SplitComplex(t(z.re), t(z.im))    # (Z/nb, Y/na, X)

    out_spec = P(b, a, None)
    fn = shard_map_unchecked(body, mesh=mesh, in_specs=(P(a, b, None),) * 2,
                   out_specs=SplitComplex(out_spec, out_spec))
    return fn(x.re, x.im)


# ---------------------------------------------------------------------------
# Distributed 1-D four-step FFT
# ---------------------------------------------------------------------------

def fourstep_split(n: int, p: int) -> tuple:
    """Pick the (h, w) four-step factorisation of ``n`` on ``p`` devices:
    start at the flattest shard-compatible shape (p, n/p) and square it up
    while the column count stays even and shardable.  Deterministic, and
    mirrored by the tests so layouts agree."""
    h, w = p, n // p
    while (w > 2 * h) and (w % 2 == 0) and ((w // 2) % p == 0):
        h, w = h * 2, w // 2
    return h, w


def _fourstep_twiddle(h: int, w: int, j2, *, inverse: bool, dtype):
    """T[k1, j2] = exp(-+ 2*pi*i * k1*j2 / n) for the local column block.

    k1*j2 < h*w = n, so the integer product is exact and the angle argument
    never loses precision to a large-phase reduction.
    """
    n = h * w
    k1 = jnp.arange(h, dtype=jnp.int32)[:, None]
    prod = (k1 * j2[None, :]).astype(jnp.float32)
    ang = (2.0 * jnp.pi / n) * prod
    sign = 1.0 if inverse else -1.0
    return SplitComplex(jnp.cos(ang).astype(dtype),
                        (sign * jnp.sin(ang)).astype(dtype))


def pfft1d(x: SplitComplex, mesh, axis: str = "data", *,
           inverse: bool = False, backend: str = "jnp") -> SplitComplex:
    """One giant 1-D FFT sharded over ``axis``: distributed Bailey four-step.

    The length-n sequence is viewed as an (h, w) matrix (row-major,
    ``fourstep_split``): column FFTs of length h, the W_n^{k1*j2} twiddle
    correction, then row FFTs of length w.  The two inter-step transposes
    are the all_to_alls.  The final four-step output transpose is *not*
    performed: the result is the (h, w) frequency matrix flattened row-major
    and row-sharded, i.e. ``out.reshape(h, w).T.ravel()`` is ``fft(x)``.
    ``inverse=True`` consumes exactly this layout and returns natural-order
    samples, so forward->inverse roundtrips bit-exactly in layout.
    """
    (n,) = x.shape
    p = mesh.shape[axis]
    assert n % p == 0, (n, p)
    h, w = fourstep_split(n, p)
    assert h % p == 0 and w % p == 0, (h, w, p)

    def fwd(re, im):
        loc = SplitComplex(re.reshape(h // p, w), im.reshape(h // p, w))
        zz = _a2a(loc, axis, 1, 0)               # (h, w/p): full columns
        zz = _fft_axis(zz, 0, inverse=False, backend=backend)
        d = jax.lax.axis_index(axis)
        j2 = d * (w // p) + jnp.arange(w // p, dtype=jnp.int32)
        t = _fourstep_twiddle(h, w, j2, inverse=False, dtype=zz.dtype)
        zz = SplitComplex(zz.re * t.re - zz.im * t.im,
                          zz.re * t.im + zz.im * t.re)
        zz = _a2a(zz, axis, 0, 1)                # (h/p, w): full rows
        zz = _fft_last(zz, inverse=False, backend=backend)
        return SplitComplex(zz.re.reshape(-1), zz.im.reshape(-1))

    def inv(re, im):
        loc = SplitComplex(re.reshape(h // p, w), im.reshape(h // p, w))
        zz = _fft_last(loc, inverse=True, backend=backend)      # 1/w scale
        zz = _a2a(zz, axis, 1, 0)                # (h, w/p)
        d = jax.lax.axis_index(axis)
        j2 = d * (w // p) + jnp.arange(w // p, dtype=jnp.int32)
        t = _fourstep_twiddle(h, w, j2, inverse=True, dtype=zz.dtype)
        zz = SplitComplex(zz.re * t.re - zz.im * t.im,
                          zz.re * t.im + zz.im * t.re)
        zz = _fft_axis(zz, 0, inverse=True, backend=backend)    # 1/h scale
        zz = _a2a(zz, axis, 0, 1)                # (h/p, w)
        return SplitComplex(zz.re.reshape(-1), zz.im.reshape(-1))

    fn = shard_map_unchecked(inv if inverse else fwd, mesh=mesh,
                   in_specs=(P(axis), P(axis)),
                   out_specs=SplitComplex(P(axis), P(axis)))
    return fn(x.re, x.im)
