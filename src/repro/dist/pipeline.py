"""GPipe pipeline parallelism as a shard_map rotation schedule.

One stage per device along a mesh axis; microbatches enter at stage 0,
``ppermute`` rotates activations to the next stage every tick, and the last
stage accumulates outputs.  A run of M microbatches over S stages takes
M + S - 1 ticks (the classic GPipe bubble).  Everything is built from
differentiable collectives (``ppermute``/``psum`` both have transpose
rules), so ``jax.grad`` through :func:`pipelined_apply` yields exactly the
sequential model's gradients — the backward pipeline emerges from autodiff
instead of being hand-scheduled.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._compat import shard_map


def pipelined_apply(mesh, axis: str, stage_fn: Callable, stage_weights,
                    x, n_microbatches: int = 1):
    """Apply ``stage_fn(w_s, x)`` for every stage s in pipeline order.

    ``stage_weights`` is stacked (n_stages, ...) and is sharded one stage
    per device over ``axis`` (n_stages must equal the axis size); ``x`` is
    the full (batch, ...) input, replicated.  Returns the final-stage
    activations for the full batch, replicated — numerically identical to
    the sequential ``for s: x = stage_fn(w[s], x)`` loop, and fully
    differentiable w.r.t. both ``stage_weights`` and ``x``.
    """
    n_stages = int(stage_weights.shape[0])
    assert n_stages == mesh.shape[axis], (n_stages, mesh.shape)
    batch = x.shape[0]
    assert batch % n_microbatches == 0, (batch, n_microbatches)
    mb = batch // n_microbatches
    xs = x.reshape(n_microbatches, mb, *x.shape[1:])
    shift = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_device(w_block, xs_rep):
        w = w_block[0]                        # this device's stage weights
        idx = jax.lax.axis_index(axis)
        cur = jnp.zeros_like(xs_rep[0])
        outs = jnp.zeros_like(xs_rep)
        bubble = jnp.zeros_like(cur)
        for t in range(n_microbatches + n_stages - 1):
            feed = xs_rep[t] if t < n_microbatches else bubble
            cur = jnp.where(idx == 0, feed, cur)       # stage 0 ingests
            y = stage_fn(w, cur)
            if t >= n_stages - 1:                      # last stage emits
                outs = outs.at[t - (n_stages - 1)].set(y)
            cur = jax.lax.ppermute(y, axis, shift)     # rotate to next stage
        # only the last stage's buffer is the pipeline output; psum after
        # masking replicates it (and cuts every other stage's grad path)
        last = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(last, axis)

    fn = shard_map(per_device, mesh=mesh,
                   in_specs=(P(axis), P()), out_specs=P())
    out = fn(stage_weights, xs)
    return out.reshape(batch, *x.shape[1:])
