"""Multi-device layer: pencil FFTs, compressed collectives, straggler
mitigation, pipeline parallelism.

Everything here speaks shard_map + named mesh axes and imports jax through
:mod:`repro.dist._compat`, so one jax-version quirk never takes the whole
distributed layer down (the failure mode that kept four test modules
skipped before this package existed).
"""
from . import compression, pencil, pipeline, straggler  # noqa: F401
from ._compat import all_to_all, make_mesh, shard_map  # noqa: F401
from .compression import (all_to_all_compressed, psum_compressed,  # noqa: F401
                          wire_bytes)
from .pencil import (pfft1d, pfft2, pfft2_hierarchical, pfft3,  # noqa: F401
                     pirfft2, prfft2, pack_half_spectrum,
                     unpack_half_spectrum)
from .pipeline import pipelined_apply  # noqa: F401
from .straggler import rebalance, should_eject  # noqa: F401
