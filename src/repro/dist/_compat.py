"""Version-tolerant imports for the distributed layer.

``shard_map`` has lived in three places across jax releases (top-level
``jax.shard_map`` on new versions, ``jax.experimental.shard_map.shard_map``
before that) and ``jax.sharding.AxisType`` does not exist at all on older
builds — the exact fragility that broke the seed's mesh construction
(fixed in :func:`repro.launch.mesh.auto_axis_types_kw`).  Every
``repro.dist`` module and every multi-device test snippet imports through
this shim instead of hardcoding one layout.
"""
from __future__ import annotations

import jax

from repro.launch.mesh import auto_axis_types_kw, make_mesh  # noqa: F401  (re-export)


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    try:
        from jax.experimental.shard_map import shard_map as sm  # noqa: PLC0415
        return sm
    except ImportError:                    # very old layout: module attr
        from jax.experimental import shard_map as _mod  # noqa: PLC0415
        return _mod.shard_map


shard_map = _resolve_shard_map()


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """shard_map with the output-replication check disabled.

    pallas_call has no replication rule on several jax versions, so any
    shard_map body that may dispatch to the Pallas kernels (the pencil
    FFTs with ``backend="pallas"``) must opt out of the check.  The flag
    itself was renamed across releases (``check_rep`` -> ``check_vma``);
    try both, then fall back to a plain (checked) shard_map.
    """
    for kw in ("check_rep", "check_vma"):
        try:
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **{kw: False})
        except TypeError:
            continue
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def all_to_all(x, axis_name: str, split_axis: int, concat_axis: int):
    """Tiled all_to_all on one array: local ``split_axis`` shrinks by the
    axis size, ``concat_axis`` grows by it (peer-major order)."""
    return jax.lax.all_to_all(x, axis_name, split_axis, concat_axis,
                              tiled=True)
