"""Compressed gradient collectives.

Data-parallel training is all-reduce bound at scale; these helpers trade
collective bytes for quantisation error (which the train step recovers via
error feedback, see :mod:`repro.train.train_step`).  ``psum_compressed``
simulates the wire format faithfully — values really pass through the
compressed representation before the reduction — so the numerics match what
a bandwidth-optimised implementation would produce, while
:func:`wire_bytes` reports the bytes such an implementation would move.

Methods:

- ``"none"``  plain f32 psum (4 B/elem on the wire).
- ``"bf16"``  cast to bfloat16 before the reduce (2 B/elem): ~3 decimal
  digits of mantissa, same range as f32.
- ``"int8"``  per-shard symmetric linear quantisation (1 B/elem + one scale
  per leaf per shard): q = round(x / s), s = max|x| / 127.  The scale is
  computed on the *local* shard so no extra collective is needed to agree
  on it; the reduce sums dequantised shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

METHODS = ("none", "bf16", "int8")


def _psum_one(x, axis_name: str, method: str):
    if method == "none":
        return jax.lax.psum(x, axis_name)
    if method == "bf16":
        wire = x.astype(jnp.bfloat16)
        return jax.lax.psum(wire.astype(x.dtype), axis_name)
    if method == "int8":
        scale = jnp.max(jnp.abs(x)) / 127.0
        scale = jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
        q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
        deq = q.astype(x.dtype) * scale
        return jax.lax.psum(deq, axis_name)
    raise ValueError(f"unknown compression method {method!r}; "
                     f"expected one of {METHODS}")


def psum_compressed(tree, axis_name: str, method: str = "none"):
    """psum every leaf of ``tree`` over ``axis_name`` through the ``method``
    wire format.  Call inside shard_map; accepts a single array or a pytree.
    """
    return jax.tree.map(lambda x: _psum_one(x, axis_name, method), tree)


def all_to_all_compressed(x, axis_name: str, split_axis: int,
                          concat_axis: int, method: str = "none"):
    """Tiled all_to_all of one array through the ``method`` wire format —
    the pencil-FFT exchange sibling of :func:`psum_compressed`.  Call
    inside shard_map.

    ``"bf16"`` casts to bfloat16 for the wire and back.  ``"int8"``
    quantises with one symmetric per-shard scale (q = round(x/s),
    s = max|x|/127); the p scales travel via a tiny all_gather (O(p)
    bytes, not counted by :func:`wire_bytes`) and each received peer
    block is dequantised with its *sender's* scale — the wire really
    carries int8, exactly what :func:`wire_bytes` prices.
    """
    from ._compat import all_to_all
    if method == "none":
        return all_to_all(x, axis_name, split_axis, concat_axis)
    if method == "bf16":
        wire = all_to_all(x.astype(jnp.bfloat16), axis_name, split_axis,
                          concat_axis)
        return wire.astype(x.dtype)
    if method == "int8":
        scale = jnp.max(jnp.abs(x)) / 127.0
        scale = jnp.maximum(scale, jnp.finfo(x.dtype).tiny)
        q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
        qq = all_to_all(q, axis_name, split_axis, concat_axis)
        scales = jax.lax.all_gather(scale, axis_name)      # (p,) sender-major
        p = scales.shape[0]
        # blocks along concat_axis arrive peer-major: block b came from (and
        # was scaled by) device b
        m = jnp.moveaxis(qq, concat_axis, 0)
        blk = m.reshape((p, m.shape[0] // p) + m.shape[1:])
        deq = blk.astype(x.dtype) * scales.reshape((p,) + (1,) * (blk.ndim - 1))
        return jnp.moveaxis(deq.reshape(m.shape), 0, concat_axis)
    raise ValueError(f"unknown compression method {method!r}; "
                     f"expected one of {METHODS}")


def wire_bytes(tree, method: str = "none") -> int:
    """Bytes per device moved over the wire by one all-reduce of ``tree``.

    ``"bf16"`` never *inflates* a leaf (a leaf already narrower than 16 bits
    stays at its own width); ``"int8"`` is 1 B/elem for every leaf (per-leaf
    scales are O(leaves), not counted).
    """
    if method not in METHODS:
        raise ValueError(f"unknown compression method {method!r}; "
                         f"expected one of {METHODS}")
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(np.prod(np.shape(leaf)))
        itemsize = jnp.dtype(leaf.dtype).itemsize
        if method == "bf16":
            itemsize = min(itemsize, 2)
        elif method == "int8":
            itemsize = 1
        total += n * itemsize
    return total
