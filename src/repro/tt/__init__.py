"""repro.tt — analytical Wormhole/Tensix data-movement & energy model.

The paper's central claim is about *data movement and energy*, not raw
speed: the Tensix architecture decouples movement from compute, and the
Wormhole n300 draws ~8x less power and ~2.8x less energy than a 24-core
Xeon on the 2-D FFT (§6).  This package turns that claim — and the §5
data-movement bottlenecks — into testable model queries:

- :mod:`repro.tt.arch`    parameterised hardware tables (Wormhole n300,
                          Grayskull e150, TPU v5e, Xeon 8160) with peak
                          FLOP/s, DRAM/NoC bandwidths, power and pJ/op
                          energy terms, plus the paper's published §6
                          measurement anchors.
- :mod:`repro.tt.tensix`  the five-unit unpacker -> math -> packer backend
                          pipeline as a timeline with circular-buffer
                          double-buffering (the tt-sim backend split).
- :mod:`repro.tt.noc`     tile-granular NoC transfer / global-transpose /
                          all_to_all model (compressed collectives reuse
                          :func:`repro.dist.compression.wire_bytes`).
- :mod:`repro.tt.trace`   walk an :class:`repro.core.plan.FFTPlan` into a
                          stage-level trace: per-stage bytes, seconds,
                          SRAM high-water mark vs budget, energy integral.
- :mod:`repro.tt.report`  markdown/JSON emitters reproducing the paper's
                          Wormhole-vs-Xeon time/power/energy table.

Consumers: :mod:`repro.analysis.roofline` builds its HW dict from
:func:`repro.tt.arch.hw_table`, and the plan autotuner's ``prune="model"``
mode ranks candidates with :func:`repro.tt.trace.predict_cost` before
measuring only the top-k.
"""
from . import arch, noc, report, tensix, trace
from .arch import Arch, ARCHS, chip_grid, get_arch, register_arch, hw_table
from .tensix import PipelineTimeline, pipeline_timeline
from .trace import (DistTrace, PlanTrace, TraceStage, plan_elem_bytes,
                    predict_cost, trace_dist, trace_plan)

__all__ = [
    "arch", "noc", "report", "tensix", "trace",
    "Arch", "ARCHS", "chip_grid", "get_arch", "register_arch", "hw_table",
    "PipelineTimeline", "pipeline_timeline",
    "DistTrace", "PlanTrace", "TraceStage", "plan_elem_bytes",
    "trace_plan", "trace_dist", "predict_cost",
]
