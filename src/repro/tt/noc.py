"""Tile-granular NoC transfer model: point-to-point, global transpose,
and compressed all_to_all.

The Wormhole routes 32x32 tiles over two toroidal NoCs laid over the
physical core grid (``arch.noc_grid``).  The §5 bottleneck — the global
transpose between the row and column FFT passes — is an all-to-all over
that grid: with the image row-banded over P cores, a fraction (P-1)/P of
every plane must cross the NoC, and the sustained rate is set by the
mesh bisection, not the per-link rate.

The distributed-pencil exchanges of :mod:`repro.dist.pencil` reuse the
same math at device granularity via :func:`all_to_all_s`, whose wire
volume comes from :func:`repro.dist.compression.wire_bytes` so the
bf16/int8 compressed collectives are priced exactly as the training
stack ships them.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

from .arch import chip_grid, get_arch
from .tensix import TILE_DIM, TILE_ELEMS


def mean_hops(grid: Tuple[int, int]) -> float:
    """Mean Manhattan hop count between two uniformly random cores of a
    ``(gx, gy)`` torus (each axis contributes ~extent/4)."""
    gx, gy = grid
    return (gx / 4.0) + (gy / 4.0)


def bisection_bw(arch) -> float:
    """Aggregate bytes/s across the mesh bisection: links crossing the cut
    (the shorter grid axis), both toroidal directions, both NoCs."""
    a = get_arch(arch)
    gx, gy = a.noc_grid
    cut_links = max(1, min(gx, gy)) * 2        # torus: two crossings per row
    nocs = 2 if a.kind == "tensix" else 1      # NoC0 + NoC1
    return cut_links * nocs * a.noc_bw


def transfer_s(nbytes: float, arch, *, hops: Optional[float] = None) -> float:
    """Point-to-point transfer: per-hop latency plus serialisation."""
    a = get_arch(arch)
    if hops is None:
        hops = mean_hops(a.noc_grid)
    return hops * a.noc_latency_s + nbytes / a.noc_bw


def n_tiles(h: int, w: int) -> int:
    return math.ceil(h / TILE_DIM) * math.ceil(w / TILE_DIM)


def global_transpose(h: int, w: int, *, arch, elem_bytes: int = 8) -> dict:
    """The §5 global transpose of one (h, w) split-complex plane.

    The plane is row-banded over the cores; transposing moves every tile
    whose destination band differs from its source band — (P-1)/P of the
    plane — across the NoC at bisection rate, plus per-tile routing
    latency amortised over the many tiles in flight (one mean-hop charge
    per wavefront of P tiles).
    """
    a = get_arch(arch)
    p = max(1, a.cores)
    plane = float(h) * float(w) * elem_bytes
    cross = plane * (p - 1) / p
    tiles = n_tiles(h, w)
    lat = mean_hops(a.noc_grid) * a.noc_latency_s * max(1, tiles // p)
    return {
        "noc_bytes": cross,
        "tiles": tiles,
        "seconds": lat + cross / bisection_bw(a),
    }


def eth_hops(devices: int, grid: Optional[Tuple[int, int]] = None) -> float:
    """Mean chip-to-chip hop count of one all_to_all over ``devices`` chips
    laid out on the :func:`repro.tt.arch.chip_grid` mesh (or an explicit
    ``grid``).  Same Manhattan-torus math as the on-chip NoC, one level up."""
    return mean_hops(grid if grid is not None else chip_grid(devices))


def all_to_all_s(tree_or_bytes, devices: int, arch, *,
                 method: str = "none", multichip: bool = False,
                 grid: Optional[Tuple[int, int]] = None) -> dict:
    """One all_to_all over ``devices`` chips (the pencil-FFT exchange).

    ``tree_or_bytes`` is either a pytree (priced per device through
    :func:`repro.dist.compression.wire_bytes`, honouring the compressed
    wire format) or a plain per-device byte count.  Each device keeps its
    diagonal block, so (devices-1)/devices of the payload crosses the
    off-chip links.

    With ``multichip=True`` the exchange is priced on the arch's ethernet/
    ICI fabric instead of a single generic link: the per-device traffic
    stripes across ``eth_links`` links of ``eth_bw`` each, and per-hop
    latency comes from the :func:`eth_hops` chip-grid hop table — this is
    what :func:`repro.tt.trace.trace_dist` charges the dist.pencil
    exchange legs with.
    """
    import numpy as np
    from repro.dist.compression import wire_bytes
    a = get_arch(arch)
    if isinstance(tree_or_bytes, (int, float)):
        # scalar payloads are f32 bytes; derive the wire factor from
        # wire_bytes itself so the two models can never drift
        probe = np.zeros((1,), np.float32)
        per_device = float(tree_or_bytes) \
            * wire_bytes(probe, method) / wire_bytes(probe, "none")
    else:
        per_device = float(wire_bytes(tree_or_bytes, method))
    wire = per_device * max(0, devices - 1) / max(1, devices)
    if multichip:
        bw = (a.eth_bw or a.link_bw) * max(1, a.eth_links)
        lat = a.eth_latency_s or a.noc_latency_s
        hops = eth_hops(devices, grid)
        return {
            "wire_bytes": wire,
            "seconds": wire / bw + hops * lat,
            "method": method,
            "hops": hops,
            "grid": grid if grid is not None else chip_grid(devices),
        }
    return {
        "wire_bytes": wire,
        "seconds": wire / a.link_bw + a.noc_latency_s * max(0, devices - 1),
        "method": method,
    }
