"""Walk an FFTPlan into a stage-level time / traffic / energy trace.

Each :class:`TraceStage` is one sequentially-executed step of the plan —
a kernel launch group, a global transpose, an HBM round-trip — annotated
with the FLOPs and bytes it moves at every level of the hierarchy (DRAM,
NoC, core-local SRAM), its modelled wall time on the chosen
:class:`repro.tt.arch.Arch`, and its energy integral.  The fused 2-D
kernel traces to a *single* stage while the transpose-based path traces
to four: the collapse of the stage list is the paper's §5 optimisation
made visible.

Time per stage:

- ``kind == "tensix"`` — the five-unit pipeline timeline of
  :mod:`repro.tt.tensix` (unpacker/math/packer with double-buffered
  circular buffers; DRAM movers at the ends), plus NoC time where a
  stage crosses the mesh.
- ``kind in ("tpu", "cpu")`` — a per-stage roofline:
  max(compute, DRAM, SRAM, NoC) + launch overhead.

Energy per stage: pJ/op coefficients from the arch table times the op
counts, plus idle power burning for the stage's duration.  SRAM
high-water marks are checked against the arch budget (1.5 MB/core L1 on
Tensix, 16 MiB VMEM on TPU): a plan that does not fit gets
``fits=False`` and an infinite :func:`predict_cost`, which is how the
ROADMAP's "does the 1024x1024 fused tile fit?" question becomes a model
query.  ``prune="model"`` in :func:`repro.core.plan.get_plan` ranks
autotune candidates with :func:`predict_cost`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

from .arch import Arch, get_arch
from . import noc as ttnoc
from . import tensix as tt


def _log2(n: int) -> int:
    return int(n).bit_length() - 1


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def fft_flops(n: int) -> float:
    """Canonical 5 N log2 N real-op count of one complex FFT."""
    return 5.0 * n * _log2(n) if n > 1 else 0.0


def stockham_stage_count(n: int, radix: int) -> int:
    if radix == 2:
        return _log2(n)
    from repro.core.twiddle import stockham_radices
    return len(stockham_radices(n))


def twiddle_bytes(n: int, radix: int, *, elem_bytes: int = 4) -> int:
    """Bytes of the packed twiddle tables staged alongside the data
    (wr+wi planes; see :mod:`repro.core.twiddle`)."""
    if n < 4:
        return 2 * max(n // 4, 1) * elem_bytes
    if radix == 2:
        return 2 * _log2(n) * (n // 2) * elem_bytes
    s4 = _log2(n) // 2
    return 2 * s4 * 3 * (n // 4) * elem_bytes


@dataclasses.dataclass(frozen=True)
class TraceStage:
    name: str
    seconds: float
    flops: float = 0.0
    dram_bytes: float = 0.0          # DRAM read + write
    noc_bytes: float = 0.0           # bytes crossing the NoC/mesh
    sram_bytes: float = 0.0          # core-local SRAM traffic (read + write)
    sram_high_water: int = 0         # peak live working set of this stage
    energy_j: float = 0.0
    bound: str = ""                  # what set the stage's rate

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PlanTrace:
    arch: str
    shape: Tuple[int, ...]
    batch: int
    algo: str
    radix: int
    block_batch: int
    backend: str
    stages: Tuple[TraceStage, ...]
    sram_budget: int
    variant: str = "plain"           # GEMM kernels: "plain" | "compensated"

    @property
    def seconds(self) -> float:
        return sum(s.seconds for s in self.stages)

    @property
    def flops(self) -> float:
        return sum(s.flops for s in self.stages)

    @property
    def dram_bytes(self) -> float:
        return sum(s.dram_bytes for s in self.stages)

    @property
    def noc_bytes(self) -> float:
        return sum(s.noc_bytes for s in self.stages)

    @property
    def energy_j(self) -> float:
        return sum(s.energy_j for s in self.stages)

    @property
    def sram_high_water(self) -> int:
        return max((s.sram_high_water for s in self.stages), default=0)

    @property
    def fits(self) -> bool:
        return self.sram_high_water <= self.sram_budget

    @property
    def power_w(self) -> float:
        return self.energy_j / self.seconds if self.seconds > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": list(self.shape), "batch": self.batch,
            "algo": self.algo, "radix": self.radix,
            "block_batch": self.block_batch, "backend": self.backend,
            "variant": self.variant,
            "seconds": self.seconds, "flops": self.flops,
            "dram_bytes": self.dram_bytes, "noc_bytes": self.noc_bytes,
            "energy_j": self.energy_j, "power_w": self.power_w,
            "sram_high_water": self.sram_high_water,
            "sram_budget": self.sram_budget, "fits": self.fits,
            "stages": [s.to_dict() for s in self.stages],
        }


# ---------------------------------------------------------------------------
# Stage construction
# ---------------------------------------------------------------------------

def _mk_stage(name: str, arch: Arch, *, flops: float = 0.0,
              dram_in: float = 0.0, dram_out: float = 0.0,
              sram_read: float = 0.0, sram_write: float = 0.0,
              sram_high_water: int = 0, noc_bytes: float = 0.0,
              noc_s: float = 0.0, launches: int = 1,
              grid_steps: int = 0) -> TraceStage:
    overhead = launches * arch.launch_overhead_s \
        + grid_steps * arch.launch_overhead_s / 8.0
    if arch.kind == "tensix":
        tl = tt.kernel_timeline(flops=flops, dram_in=dram_in,
                                dram_out=dram_out, sram_read=sram_read,
                                sram_write=sram_write, arch=arch)
        busy = tl.total_s
        bound = tl.bottleneck if busy >= noc_s else "noc"
        seconds = max(busy, noc_s) + overhead
    else:
        terms = {
            "math": flops / arch.peak_flops_f32,
            "dram": (dram_in + dram_out) / arch.dram_bw,
            "sram": (sram_read + sram_write) / (arch.l1_bw * arch.cores),
            "noc": noc_s,
        }
        bound = max(terms, key=terms.get)
        seconds = max(terms.values()) + overhead
    energy = (flops * arch.energy_per_flop_j
              + (dram_in + dram_out) * arch.energy_per_dram_byte_j
              + noc_bytes * arch.energy_per_noc_byte_j
              + (sram_read + sram_write) * arch.energy_per_sram_byte_j
              + arch.idle_power_w * seconds)
    return TraceStage(name=name, seconds=seconds, flops=flops,
                      dram_bytes=dram_in + dram_out, noc_bytes=noc_bytes,
                      sram_bytes=sram_read + sram_write,
                      sram_high_water=int(sram_high_water),
                      energy_j=energy, bound=bound)


def _fft_pass_stage(name: str, arch: Arch, *, n: int, rows: int, algo: str,
                    radix: int, block_batch: int,
                    elem_bytes: int = 8) -> TraceStage:
    """One batched 1-D FFT pass: ``rows`` transforms of length ``n``.

    ``elem_bytes`` is per split-complex element (re+im), 8 for float32.
    Covers every 1-D algo the plan registry dispatches; used both for 1-D
    plans and for the row/column passes of the 2-D row-column path.
    """
    if algo == "auto":
        from repro.core.fft1d import resolve_algo
        algo = resolve_algo(n)
    plane = float(rows) * n * elem_bytes
    bb = max(1, min(block_batch, rows))
    grid_steps = math.ceil(rows / bb)
    half = elem_bytes // 2                    # bytes per component plane elem

    if algo in ("stockham", "stockham2", "cooley_tukey", "cooley_tukey_fused"):
        r = 2 if algo == "stockham2" else radix
        stages = stockham_stage_count(n, r)
        if algo.startswith("cooley_tukey"):   # explicit reorder copies on top
            stages = _log2(n) * (2 if algo == "cooley_tukey" else 1)
            r = 2
        tw = twiddle_bytes(n, r, elem_bytes=half)
        return _mk_stage(name, arch, flops=rows * fft_flops(n),
                         dram_in=plane + tw, dram_out=plane,
                         sram_read=stages * plane, sram_write=stages * plane,
                         sram_high_water=bb * n * elem_bytes * 2 + tw,
                         grid_steps=grid_steps)
    if algo == "four_step":
        from repro.core.fft1d import _best_split
        n1 = _best_split(n)
        n2 = n // max(n1, 1)
        if n1 <= 1:                            # prime: bluestein fallback
            return _fft_pass_stage(name, arch, n=n, rows=rows,
                                   algo="bluestein", radix=radix,
                                   block_batch=block_batch,
                                   elem_bytes=elem_bytes)
        flops = rows * (8.0 * n * (n1 + n2) + 6.0 * n)
        mats = (n1 * n1 + n2 * n2) * elem_bytes
        return _mk_stage(name, arch, flops=flops,
                         dram_in=plane + mats, dram_out=plane,
                         sram_read=3 * plane, sram_write=3 * plane,
                         sram_high_water=bb * n * elem_bytes * 2 + mats,
                         grid_steps=grid_steps)
    if algo == "naive":
        mat = float(n) * n * elem_bytes
        return _mk_stage(name, arch, flops=rows * 8.0 * n * n,
                         dram_in=plane + mat, dram_out=plane,
                         sram_read=plane + mat, sram_write=plane,
                         sram_high_water=int(mat) + bb * n * elem_bytes * 2,
                         grid_steps=grid_steps)
    if algo == "bluestein":
        m = 1 << int(math.ceil(math.log2(max(2 * n - 1, 2))))
        mplane = float(rows) * m * elem_bytes
        stages = 3 * stockham_stage_count(m, 4)
        tw = twiddle_bytes(m, 4, elem_bytes=half)
        return _mk_stage(name, arch, flops=rows * (3 * fft_flops(m) + 10.0 * m),
                         dram_in=plane + tw, dram_out=plane,
                         sram_read=stages * mplane, sram_write=stages * mplane,
                         sram_high_water=bb * m * elem_bytes * 2 + tw,
                         grid_steps=grid_steps)
    raise ValueError(f"no trace model for 1-D algo {algo!r}")


def _transpose_stage(name: str, arch: Arch, *, h: int, w: int, batch: int,
                     elem_bytes: int = 8) -> TraceStage:
    """The global transpose between the two passes of the row-column path:
    a full plane DRAM round-trip, and on a Tensix mesh additionally an
    all-to-all of (P-1)/P of the plane across the NoC (§5)."""
    plane = float(batch) * h * w * elem_bytes
    noc_bytes = noc_s = 0.0
    if arch.kind == "tensix":
        x = ttnoc.global_transpose(h, w, arch=arch, elem_bytes=elem_bytes)
        noc_bytes = batch * x["noc_bytes"]
        noc_s = batch * x["seconds"]
    return _mk_stage(name, arch, dram_in=plane, dram_out=plane,
                     noc_bytes=noc_bytes, noc_s=noc_s,
                     sram_high_water=2 * tt.TILE_ELEMS * elem_bytes)


# ---------------------------------------------------------------------------
# Plan walkers
# ---------------------------------------------------------------------------

def plan_elem_bytes(plan) -> int:
    """Bytes per split-complex element (re+im) of this plan's dtype: 8 for
    float32, 4 for bfloat16/float16 — how the tracer knows a bf16 plan
    moves half the DRAM/NoC/SRAM bytes of an f32 one."""
    import jax.numpy as jnp
    return 2 * jnp.dtype(getattr(plan, "dtype", "float32")).itemsize


def trace_plan(plan, *, arch="wormhole_n300", batch: int = 1) -> PlanTrace:
    """Trace one :class:`repro.core.plan.FFTPlan` (any object exposing
    ``shape / algo / radix / block_batch / backend``, plus ``kind`` and
    ``inverse`` for rfft plans) on ``arch``.

    ``batch`` is the number of independent transforms executed together
    (the leading batch dim).  rfft-kind plans trace their actual schedule
    — inner half-length complex pass plus the O(n) untangle, half-width
    spectrum planes downstream — so the half-spectrum saving shows up in
    the bytes, not as a fudge factor.  Element width comes from the plan's
    dtype (:func:`plan_elem_bytes`): a bfloat16 plan traces at half the
    DRAM/NoC/SRAM cost of the float32 plan of the same shape.
    """
    a = get_arch(arch)
    elem = plan_elem_bytes(plan)
    variant = getattr(plan, "variant", "plain")
    stages: List[TraceStage] = []

    if getattr(plan, "kind", "c2c") == "rfft":
        stages = _rfft_stages(plan, a, batch=batch, elem_bytes=elem)
    elif getattr(plan, "kind", "c2c").startswith("conv"):
        stages = _conv_stages(plan, a, batch=batch, elem_bytes=elem)
    elif len(plan.shape) == 1:
        n = plan.shape[0]
        stages.append(_fft_pass_stage(
            f"fft1d_{plan.algo}", a, n=n, rows=batch, algo=plan.algo,
            radix=plan.radix, block_batch=plan.block_batch,
            elem_bytes=elem))
    elif len(plan.shape) == 2:
        h, w = plan.shape
        if plan.algo == "fused":
            stages.append(_gemm2d_stage(a, h=h, w=w, batch=batch,
                                        block_batch=plan.block_batch,
                                        variant=variant, elem_bytes=elem))
        elif plan.algo == "fused_stockham":
            stages.append(_fused2d_stage(a, h=h, w=w, batch=batch,
                                         radix=plan.radix,
                                         block_batch=plan.block_batch,
                                         elem_bytes=elem))
        elif plan.algo in ("row_col", "auto"):
            bb = plan.block_batch
            stages.append(_fft_pass_stage(
                "row_fft", a, n=w, rows=batch * h,
                algo="stockham" if plan.backend == "pallas" else "auto",
                radix=plan.radix, block_batch=bb, elem_bytes=elem))
            stages.append(_transpose_stage("global_transpose", a, h=h, w=w,
                                           batch=batch, elem_bytes=elem))
            stages.append(_fft_pass_stage(
                "col_fft", a, n=h, rows=batch * w,
                algo="stockham" if plan.backend == "pallas" else "auto",
                radix=plan.radix, block_batch=bb, elem_bytes=elem))
            stages.append(_transpose_stage("output_transpose", a, h=w, w=h,
                                           batch=batch, elem_bytes=elem))
        else:
            raise ValueError(f"no trace model for 2-D algo {plan.algo!r}")
    else:
        d, h, w = plan.shape
        if plan.algo == "fused":
            stages.append(_gemm3d_stage(a, d=d, h=h, w=w, batch=batch,
                                        block_batch=plan.block_batch,
                                        variant=variant, elem_bytes=elem))
        elif plan.algo in ("row_col", "auto"):
            bb = plan.block_batch
            p_algo = "stockham" if plan.backend == "pallas" else "auto"
            # the direct path's per-axis schedule: W pass in place, then
            # each of the H and D passes brackets its 1-D pass with a
            # swap-in/swap-out relayout pair — four full-volume
            # round-trips the fused kernel's absorbed contractions skip
            stages.append(_fft_pass_stage(
                "w_fft", a, n=w, rows=batch * d * h, algo=p_algo,
                radix=plan.radix, block_batch=bb, elem_bytes=elem))
            stages.append(_transpose_stage("transpose_wh_in", a, h=h, w=w,
                                           batch=batch * d,
                                           elem_bytes=elem))
            stages.append(_fft_pass_stage(
                "h_fft", a, n=h, rows=batch * d * w, algo=p_algo,
                radix=plan.radix, block_batch=bb, elem_bytes=elem))
            stages.append(_transpose_stage("transpose_wh_out", a, h=w, w=h,
                                           batch=batch * d,
                                           elem_bytes=elem))
            stages.append(_transpose_stage("transpose_wd_in", a, h=d,
                                           w=h * w, batch=batch,
                                           elem_bytes=elem))
            stages.append(_fft_pass_stage(
                "d_fft", a, n=d, rows=batch * h * w, algo=p_algo,
                radix=plan.radix, block_batch=bb, elem_bytes=elem))
            stages.append(_transpose_stage("transpose_wd_out", a, h=h * w,
                                           w=d, batch=batch,
                                           elem_bytes=elem))
        else:
            raise ValueError(f"no trace model for 3-D algo {plan.algo!r}")

    return PlanTrace(arch=a.name, shape=tuple(plan.shape), batch=batch,
                     algo=plan.algo, radix=plan.radix,
                     block_batch=plan.block_batch, backend=plan.backend,
                     stages=tuple(stages), sram_budget=a.sram_budget,
                     variant=variant)


def _untangle_stage(name: str, a: Arch, *, n: int, rows: int,
                    elem_bytes: int) -> TraceStage:
    """The O(n) rfft pack/untangle (or irfft Hermitian extension): one
    pointwise pass over the half spectrum."""
    half = float(rows) * (n // 2 + 1) * elem_bytes
    return _mk_stage(name, a, flops=10.0 * rows * (n // 2),
                     dram_in=half, dram_out=half,
                     sram_read=half, sram_write=half,
                     sram_high_water=2 * (n // 2 + 1) * elem_bytes)


def _rfft_stages(plan, a: Arch, *, batch: int,
                 elem_bytes: int) -> List[TraceStage]:
    """The real-input schedules as executed by :mod:`repro.core.fft1d` /
    :mod:`repro.core.fft2d`: ``plan.algo`` is the *inner* complex algo —
    half-length (n/2) for the forward packed rfft, full-length for the
    inverse's Hermitian-extended ifft.  The 2-D row pass works on the
    real axis, the column pass on the (w/2+1)-wide half spectrum — the
    halved-transpose-bytes saving the ROADMAP notes for dist.rfft2.
    ``algo="fused"`` (the pallas real-input kernel) traces to ONE stage
    at half-width bytes (:func:`_rfft_fused2d_stage`).
    """
    kw = dict(radix=plan.radix, block_batch=plan.block_batch,
              elem_bytes=elem_bytes)
    if plan.ndim == 2 and plan.algo == "fused":
        h, w = plan.shape
        return [_rfft_fused2d_stage(a, h=h, w=w, batch=batch,
                                    inverse=plan.inverse,
                                    block_batch=plan.block_batch,
                                    elem_bytes=elem_bytes)]
    if plan.ndim == 1:
        n = plan.shape[0]
        inner = n if plan.inverse else n // 2
        tag = "irfft" if plan.inverse else "rfft"
        return [
            _fft_pass_stage(f"{tag}_inner_{plan.algo}", a, n=inner,
                            rows=batch, algo=plan.algo, **kw),
            _untangle_stage(f"{tag}_untangle", a, n=n, rows=batch,
                            elem_bytes=elem_bytes),
        ]
    h, w = plan.shape
    wh = w // 2 + 1
    if plan.inverse:
        return [
            _fft_pass_stage("col_ifft", a, n=h, rows=batch * wh,
                            algo="auto", **kw),
            _transpose_stage("global_transpose", a, h=h, w=wh, batch=batch,
                             elem_bytes=elem_bytes),
            _fft_pass_stage(f"irfft_rows_{plan.algo}", a, n=w,
                            rows=batch * h, algo=plan.algo, **kw),
            _untangle_stage("irfft_extend", a, n=w, rows=batch * h,
                            elem_bytes=elem_bytes),
        ]
    return [
        _fft_pass_stage(f"rfft_rows_{plan.algo}", a, n=w // 2,
                        rows=batch * h, algo=plan.algo, **kw),
        _untangle_stage("rfft_untangle", a, n=w, rows=batch * h,
                        elem_bytes=elem_bytes),
        _transpose_stage("global_transpose", a, h=h, w=wh, batch=batch,
                         elem_bytes=elem_bytes),
        _fft_pass_stage("col_fft", a, n=h, rows=batch * wh, algo="auto",
                        **kw),
    ]


def _fused2d_stage(a: Arch, *, h: int, w: int, batch: int, radix: int,
                   block_batch: int, elem_bytes: int) -> TraceStage:
    """The Stockham-stage fused 2-D kernel (the explicit-algo oracle,
    ``algo="fused_stockham"``): one stage, 2 DRAM plane traversals
    (read + write), everything else VMEM/L1-resident — row pass, in-SRAM
    tile transpose, column pass (:mod:`repro.kernels.fft2d_fused`)."""
    plane = float(h) * w * elem_bytes              # one split-complex image
    total = batch * plane
    bb = max(1, min(block_batch, batch))
    grid_steps = math.ceil(batch / bb)
    half = elem_bytes // 2
    tw = twiddle_bytes(w, radix, elem_bytes=half) \
        + twiddle_bytes(h, radix, elem_bytes=half)
    s_passes = stockham_stage_count(w, radix) + stockham_stage_count(h, radix)
    # each Stockham stage reads+writes the tile in SRAM; the tile transpose
    # adds one more read+write — the round-trip this kernel keeps off DRAM
    sram_rw = (s_passes + 1) * total
    # ping-pong working set: the live tile plus the stage being written,
    # i.e. 2 planes per image in the block, plus both twiddle tables —
    # 2 x 8 MiB at 1024x1024/bb=1, the ROADMAP's 16 MiB VMEM question
    high_water = 2 * bb * int(h * w * elem_bytes) + tw
    return _mk_stage("fused_fft2d_stockham", a,
                     flops=batch * fft_flops(h * w),
                     dram_in=total + tw, dram_out=total,
                     sram_read=sram_rw, sram_write=sram_rw,
                     sram_high_water=high_water, grid_steps=grid_steps)


def fourstep_table_bytes(n: int, *, elem_bytes: int = 8,
                         factors=None) -> int:
    """Bytes of the one-level four-step operand tables the fused rfft
    kernel stages per axis: both factor DFT matrices plus the (n1, n2)
    inter-factor twiddle, re+im planes (``elem_bytes`` per split-complex
    element, matching :func:`repro.kernels.rfft2d_fused.fourstep_tables_np`).
    ``factors`` overrides the 2-D kernel's split rule (the 3-D kernel's
    leaf crossover sits one octave lower)."""
    if factors is None:
        from repro.kernels.rfft2d_fused import fourstep_factors
        factors = fourstep_factors(n)
    n1, n2 = factors
    return (n1 * n1 + n2 * n2 + n1 * n2) * elem_bytes


def _fourstep_pass_flops(n: int, rows: float, factors=None) -> float:
    """Real-op count of ``rows`` one-level four-step passes of length
    ``n``: both factor DFT matmuls (8 real ops per complex MAC) plus the
    pointwise inter-factor twiddle — the 8*n*(n1+n2) + 6*n accounting of
    :func:`_fft_pass_stage`'s four_step arm."""
    if factors is None:
        from repro.kernels.rfft2d_fused import fourstep_factors
        factors = fourstep_factors(n)
    n1, n2 = factors
    return rows * (8.0 * n * (n1 + n2) + 6.0 * n)


def _gemm2d_stage(a: Arch, *, h: int, w: int, batch: int, block_batch: int,
                  variant: str, elem_bytes: int) -> TraceStage:
    """The GEMM-formulated fused 2-D kernel
    (:mod:`repro.kernels.fft2d_gemm`, ``algo="fused"``): ONE stage, 2 DRAM
    plane traversals plus the four-step operand tables, both passes dense
    DFT matmuls with the column transpose absorbed into the contraction.
    The ``compensated`` variant doubles the table bytes (split hi/lo
    pairs) and the table-side flops (two-operand reconstruction + fp32
    accumulation) but keeps the *resident tile* at the storage dtype —
    which is why the bf16 1024x1024 working set fits the 16 MiB budget
    the fp32 one busts."""
    plane = float(h) * w * elem_bytes              # one split-complex image
    total = batch * plane
    bb = max(1, min(block_batch, batch))
    grid_steps = math.ceil(batch / bb)
    tw = fourstep_table_bytes(w, elem_bytes=elem_bytes) \
        + fourstep_table_bytes(h, elem_bytes=elem_bytes)
    flops = batch * (_fourstep_pass_flops(w, float(h))
                     + _fourstep_pass_flops(h, float(w)))
    if variant == "compensated":
        tw *= 2
        flops *= 2
    # each GEMM pass streams its tile through SRAM ~3x: matmul read +
    # write plus the inter-factor twiddle round
    sram_rw = 2 * 3 * total
    # ping-pong working set: live tile + the pass being written, plus the
    # staged operand tables
    high_water = 2 * bb * int(h * w * elem_bytes) + tw
    return _mk_stage("fused_fft2d", a, flops=flops,
                     dram_in=total + tw, dram_out=total,
                     sram_read=sram_rw, sram_write=sram_rw,
                     sram_high_water=high_water, grid_steps=grid_steps)


def _gemm3d_stage(a: Arch, *, d: int, h: int, w: int, batch: int,
                  block_batch: int, variant: str,
                  elem_bytes: int) -> TraceStage:
    """The fused 3-D kernel (:mod:`repro.kernels.fft3d_fused`,
    ``algo="fused"``): ONE stage for all three four-step GEMM passes on a
    VMEM-resident (bb, d, h, w) brick — 2 DRAM volume traversals plus
    three axes of operand tables, both inter-pass relayouts absorbed into
    left-side contractions (vs the row-column schedule's four full-volume
    round-trips)."""
    vol = float(d) * h * w * elem_bytes            # one split-complex volume
    total = batch * vol
    bb = max(1, min(block_batch, batch))
    grid_steps = math.ceil(batch / bb)
    from repro.kernels.fft3d_fused import fourstep_factors3
    fw, fh, fd = (fourstep_factors3(w), fourstep_factors3(h),
                  fourstep_factors3(d))
    tw = (fourstep_table_bytes(w, elem_bytes=elem_bytes, factors=fw)
          + fourstep_table_bytes(h, elem_bytes=elem_bytes, factors=fh)
          + fourstep_table_bytes(d, elem_bytes=elem_bytes, factors=fd))
    flops = batch * (_fourstep_pass_flops(w, float(d) * h, factors=fw)
                     + _fourstep_pass_flops(h, float(d) * w, factors=fh)
                     + _fourstep_pass_flops(d, float(h) * w, factors=fd))
    if variant == "compensated":
        tw *= 2
        flops *= 2
    sram_rw = 3 * 3 * total
    high_water = 2 * bb * int(d * h * w * elem_bytes) + tw
    return _mk_stage("fused_fft3d", a, flops=flops,
                     dram_in=total + tw, dram_out=total,
                     sram_read=sram_rw, sram_write=sram_rw,
                     sram_high_water=high_water, grid_steps=grid_steps)


def _rfft_fused2d_stage(a: Arch, *, h: int, w: int, batch: int,
                        inverse: bool, block_batch: int,
                        elem_bytes: int) -> TraceStage:
    """The fused real-input 2-D kernel
    (:mod:`repro.kernels.rfft2d_fused`): ONE stage moving a real plane on
    one side and a half spectrum on the other — ~half the complex fused
    kernel's DRAM traffic per image — with the half-width tile as the
    VMEM working set (which is what lets the 1024x1024 fp32 case fit the
    16 MiB budget the complex kernel busts).  Both passes are four-step
    DFT matmuls; FLOPs follow the same 8*n*(n1+n2) accounting as
    :func:`_fft_pass_stage`'s four_step arm.
    """
    from repro.kernels.rfft2d_fused import fourstep_factors
    wh = w // 2 + 1
    half = elem_bytes // 2
    real_plane = float(batch) * h * w * half        # the real input/output
    spec_plane = float(batch) * h * wh * elem_bytes  # the half spectrum
    bb = max(1, min(block_batch, batch))
    grid_steps = math.ceil(batch / bb)
    tw = fourstep_table_bytes(w, elem_bytes=elem_bytes) \
        + fourstep_table_bytes(h, elem_bytes=elem_bytes)
    n1w, n2w = fourstep_factors(w)
    n1h, n2h = fourstep_factors(h)
    flops = batch * ((h / 2) * (8.0 * w * (n1w + n2w) + 6.0 * w)  # row pairs
                     + 10.0 * h * wh                              # untangle
                     + wh * (8.0 * h * (n1h + n2h) + 6.0 * h))    # columns
    # each pass streams its tile through SRAM ~3x (matmul in/out + twiddle
    # round), the untangle adds one half-spectrum round-trip
    row_tile = float(batch) * (h // 2) * w * elem_bytes
    sram_rw = 3 * row_tile + 3 * spec_plane + spec_plane
    # working set: the half-width column tile ping-pong (its (w/2+1) * h
    # spectrum is the widest live value) plus the four-step tables
    high_water = 2 * bb * h * wh * elem_bytes + tw
    name = "fused_irfft2d" if inverse else "fused_rfft2d"
    dram_in = (spec_plane if inverse else real_plane) + tw
    dram_out = real_plane if inverse else spec_plane
    return _mk_stage(name, a, flops=flops, dram_in=dram_in,
                     dram_out=dram_out, sram_read=sram_rw,
                     sram_write=sram_rw, sram_high_water=high_water,
                     grid_steps=grid_steps)


def _fftconv_fused_stage(a: Arch, *, m: int, rows: int,
                         elem_bytes: int) -> TraceStage:
    """The fused spectral-convolution kernel
    (:mod:`repro.kernels.fftconv_fused`, conv-kind plans with
    ``algo="fused"``): ONE stage moving one real plane in, the packed
    filter pair (E, F) in, and one real plane out — the product spectrum never
    exists outside VMEM, versus the unfused path's six-plane traffic
    (real in / spectrum out / spectrum + filter in / product out / product
    in / real out; see :func:`_conv_stages`).  ``rows`` is the number of
    convolved signals resident per grid step (the wrapper's row axis —
    e.g. the SSM channel count); the byte accounting mirrors the kernel's
    real operand buffers exactly so the benchmark's model-vs-counted
    traffic ratio is 1.0 by construction."""
    hm = m // 2
    half = elem_bytes // 2
    real_plane = float(rows) * m * half
    # the packed-domain filter operands E and F: two complex length-m/2
    # vectors per row (untangle, pointwise multiply and pre-tangle all
    # folded in — see fftconv_fused.pack_filter)
    ef_bytes = 2.0 * rows * hm * elem_bytes
    # both passes run at the packed half length: forward + inverse
    # length-m/2 four-step tables only
    tw = 2 * fourstep_table_bytes(hm, elem_bytes=elem_bytes)
    flops = (2.0 * _fourstep_pass_flops(hm, float(rows))  # fwd + inv passes
             + 14.0 * rows * hm                  # E*Z + F*conj(rev Z)
             + 2.0 * rows * hm)                  # 2/m output scale
    packed = float(rows) * hm * elem_bytes               # the complex rows
    # each four-step pass streams its (equal-byte) complex tile through
    # SRAM ~3x; the packed-domain multiply-add adds one spectrum round
    sram_rw = 2 * 3 * packed + 3 * packed
    # working set: ping-pong of the packed complex rows plus the staged
    # packed filter pair and both table sets
    high_water = 2 * rows * hm * elem_bytes + int(ef_bytes) + tw
    return _mk_stage("fused_fftconv", a, flops=flops,
                     dram_in=real_plane + ef_bytes + tw,
                     dram_out=real_plane,
                     sram_read=sram_rw, sram_write=sram_rw,
                     sram_high_water=high_water, grid_steps=1)


def _conv_stages(plan, a: Arch, *, batch: int,
                 elem_bytes: int) -> List[TraceStage]:
    """conv-kind plans (fused rfft -> multiply -> irfft).  ``algo="fused"``
    traces to ONE VMEM-resident stage; ``algo="unfused"`` traces the
    registry-composed baseline — forward packed rfft, a pointwise multiply
    with its own spectrum round-trip, and the Hermitian-extended inverse —
    whose summed DRAM bytes are the six-plane traffic the fused kernel
    deletes."""
    m = plan.n
    if plan.algo == "fused":
        return [_fftconv_fused_stage(a, m=m, rows=batch,
                                     elem_bytes=elem_bytes)]
    hm = m // 2
    spec = float(batch) * (hm + 1) * elem_bytes
    kw = dict(radix=plan.radix, block_batch=plan.block_batch,
              elem_bytes=elem_bytes)
    return [
        _fft_pass_stage("conv_rfft_inner", a, n=m // 2, rows=batch,
                        algo="auto", **kw),
        _untangle_stage("conv_rfft_untangle", a, n=m, rows=batch,
                        elem_bytes=elem_bytes),
        # pointwise multiply: product + filter spectra in, product out
        _mk_stage("conv_pointwise_mul", a, flops=6.0 * batch * (hm + 1),
                  dram_in=2 * spec, dram_out=spec,
                  sram_read=2 * spec, sram_write=spec,
                  sram_high_water=3 * (hm + 1) * elem_bytes),
        _fft_pass_stage("conv_irfft_inner", a, n=m, rows=batch,
                        algo="auto", **kw),
        _untangle_stage("conv_irfft_extend", a, n=m, rows=batch,
                        elem_bytes=elem_bytes),
    ]


def predict_cost(plan, *, arch="wormhole_n300", batch: int = 1) -> float:
    """Model cost for autotune ranking: predicted seconds, or +inf when the
    working set busts the arch's SRAM budget (an unrunnable plan must never
    outrank a runnable one)."""
    t = trace_plan(plan, arch=arch, batch=batch)
    return t.seconds if t.fits else float("inf")


# ---------------------------------------------------------------------------
# Distributed pencil schedules (multi-chip)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistTrace:
    """A multi-chip pencil-FFT schedule walked stage by stage: per-shard
    local plan stages (through :func:`trace_plan`) interleaved with the
    inter-chip exchange legs (priced by :func:`repro.tt.noc.all_to_all_s`
    on the arch's ethernet/ICI hop table).  Per-device accounting: stage
    seconds are wall time (every chip runs its shard in parallel), and
    ``exchange_wire_bytes`` is what one device puts on the wire."""
    arch: str
    shape: Tuple[int, ...]
    devices: int
    kind: str                        # "pfft2" | "prfft2"
    method: str                      # compression wire format of the exchange
    backend: str
    elem_bytes: int
    batch: int
    stages: Tuple[TraceStage, ...]
    sram_budget: int

    @property
    def seconds(self) -> float:
        return sum(s.seconds for s in self.stages)

    @property
    def flops(self) -> float:
        return sum(s.flops for s in self.stages)

    @property
    def dram_bytes(self) -> float:
        return sum(s.dram_bytes for s in self.stages)

    @property
    def energy_j(self) -> float:
        return sum(s.energy_j for s in self.stages)

    @property
    def exchange_wire_bytes(self) -> float:
        """Bytes one device ships across chips, all exchange legs summed."""
        return sum(s.noc_bytes for s in self.stages
                   if s.name.startswith("exchange"))

    @property
    def exchange_seconds(self) -> float:
        return sum(s.seconds for s in self.stages
                   if s.name.startswith("exchange"))

    @property
    def sram_high_water(self) -> int:
        return max((s.sram_high_water for s in self.stages), default=0)

    @property
    def fits(self) -> bool:
        return self.sram_high_water <= self.sram_budget

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": list(self.shape),
            "devices": self.devices, "kind": self.kind,
            "method": self.method, "backend": self.backend,
            "elem_bytes": self.elem_bytes, "batch": self.batch,
            "seconds": self.seconds, "flops": self.flops,
            "dram_bytes": self.dram_bytes, "energy_j": self.energy_j,
            "exchange_wire_bytes": self.exchange_wire_bytes,
            "exchange_seconds": self.exchange_seconds,
            "sram_high_water": self.sram_high_water,
            "sram_budget": self.sram_budget, "fits": self.fits,
            "stages": [s.to_dict() for s in self.stages],
        }


def _exchange_stage(name: str, a: Arch, *, payload_bytes: float,
                    devices: int) -> TraceStage:
    """One inter-chip all_to_all leg.  ``payload_bytes`` is the per-device
    payload already in its wire format, so it is priced method="none" here
    (no double compression discount)."""
    x = ttnoc.all_to_all_s(float(payload_bytes), devices, a, multichip=True)
    e_link = a.energy_per_link_byte_j or a.energy_per_noc_byte_j
    energy = x["wire_bytes"] * e_link + a.idle_power_w * x["seconds"]
    return TraceStage(name=name, seconds=x["seconds"],
                      noc_bytes=x["wire_bytes"], energy_j=energy,
                      bound="link")


def trace_dist(shape, *, devices: int, arch="wormhole_n300",
               real: bool = False, method: str = "none",
               dtype="float32", backend: str = "jnp",
               transposed_output: bool = True, batch: int = 1) -> DistTrace:
    """Trace one :func:`repro.dist.pencil.pfft2` (``real=False``) or
    :func:`~repro.dist.pencil.prfft2` (``real=True``) schedule end-to-end
    on ``devices`` chips of ``arch``.

    Local passes resolve through the plan registry — the *same* entries
    the pencil functions execute (rfft-kind rows for ``real=True``) — and
    are traced per shard with :func:`trace_plan`; the exchange legs take
    their per-device payload from
    :func:`repro.dist.pencil.exchange_bytes` (so model and wire log can
    never drift) and their time from the multi-chip hop table.  The
    headline query: ``trace_dist(.., real=True)`` predicts half the
    exchange wire bytes of the complex schedule.
    """
    import jax.numpy as jnp
    from repro.core import plan as plan_lib
    from repro.dist.pencil import exchange_bytes

    a = get_arch(arch)
    h, w = (int(d) for d in shape)
    devices = int(devices)
    cols_total = w // 2 if real else w          # pencils after the exchange
    assert h % devices == 0 and cols_total % devices == 0, \
        (shape, devices, real)
    elem = 2 * jnp.dtype(dtype).itemsize
    kind = "prfft2" if real else "pfft2"
    stages: List[TraceStage] = []

    row_plan = plan_lib.get_plan((w,), dtype=dtype, backend=backend,
                                 kind="rfft" if real else "c2c")
    rt = trace_plan(row_plan, arch=a, batch=batch * h // devices)
    stages += [dataclasses.replace(s, name=f"rows/{s.name}")
               for s in rt.stages]

    payload = batch * exchange_bytes(h, w, devices, real=real, method=method,
                                     dtype=dtype)
    stages.append(_exchange_stage("exchange_a2a", a, payload_bytes=payload,
                                  devices=devices))

    cols = batch * cols_total // devices
    col_plan = plan_lib.get_plan((h,), dtype=dtype, backend=backend)
    ct = trace_plan(col_plan, arch=a, batch=cols)
    stages += [dataclasses.replace(s, name=f"cols/{s.name}")
               for s in ct.stages]

    if real:
        # the local O(H) Hermitian untangle of the packed DC/Nyquist column
        stages.append(_untangle_stage("unpack_nyquist", a, n=2 * h,
                                      rows=batch, elem_bytes=elem))
    if not transposed_output:
        stages.append(_exchange_stage("exchange_a2a_out", a,
                                      payload_bytes=payload,
                                      devices=devices))

    return DistTrace(arch=a.name, shape=(h, w), devices=devices, kind=kind,
                     method=method, backend=backend, elem_bytes=elem,
                     batch=batch, stages=tuple(stages),
                     sram_budget=a.sram_budget)
