"""Markdown/JSON emitters for the Wormhole-vs-Xeon comparison (paper §6).

The paper's headline is an efficiency, not a speed, result: on the 2-D
FFT the Wormhole n300 is slower than the 24-core Xeon baseline but draws
~8x less power and therefore spends ~2.8x less energy.  Two sources
back the table:

- ``source="paper"`` (default) — the published §6 measurement anchors
  stored on each :class:`repro.tt.arch.Arch` (``published["time_ms"]``,
  ``published["power_w"]``).  This reproduces the paper's ratios exactly
  and is what the acceptance test pins.
- ``source="model"`` — the analytic traces of :mod:`repro.tt.trace`
  (fused plan on the accelerator, row-column on the CPU) with the
  energy integral.  Roofline-optimistic by construction; useful for the
  *relative* what-if questions (sizes, block_batch, compression), not
  for absolute cross-arch claims.

``python -m benchmarks.table5_wormhole_model`` emits both.
"""
from __future__ import annotations

import json
from typing import List, Optional, Sequence

from .arch import get_arch
from . import trace as tttrace


def _model_row_seconds(arch, size: int,
                       transform: str = "fft2") -> "tttrace.PlanTrace":
    """Model trace of one (size, size) f32 2-D transform on ``arch``: the
    fused single-kernel schedule on accelerators, row-column on CPUs.
    ``transform="rfft2"`` traces the real-input schedule instead (the
    fused rfft kernel / the jnp rfft row-column path)."""
    from repro.core.plan import FFTPlan

    assert transform in ("fft2", "rfft2"), transform
    a = get_arch(arch)
    kind = "rfft" if transform == "rfft2" else "c2c"
    if a.kind == "cpu":
        from repro.core.fft1d import resolve_algo
        algo = resolve_algo(size // 2) if kind == "rfft" else "row_col"
        plan = FFTPlan(shape=(size, size), algo=algo, block_batch=8,
                       backend="jnp", kind=kind)
    else:
        plan = FFTPlan(shape=(size, size), algo="fused", block_batch=1,
                       backend="pallas", kind=kind)
    return tttrace.trace_plan(plan, arch=a, batch=1)


def compare(arch_a="wormhole_n300", arch_b="xeon_8160", *,
            sizes: Optional[Sequence[int]] = None,
            source: str = "paper", transform: str = "fft2") -> List[dict]:
    """Per-size comparison rows of ``arch_a`` (the paper's accelerator)
    against ``arch_b`` (the baseline).

    Ratios follow the paper's phrasing: ``time_ratio`` is a_time/b_time
    (>1 means a is slower), ``power_ratio`` and ``energy_ratio`` are
    b/a (>1 means a draws/spends less).  ``transform="rfft2"`` compares
    the real-input transform the distributed path actually ships — model
    source only, since the paper published no real-input anchors.
    """
    a, b = get_arch(arch_a), get_arch(arch_b)
    assert source in ("paper", "model"), source
    assert transform in ("fft2", "rfft2"), transform
    if transform == "rfft2" and source != "model":
        raise ValueError("transform='rfft2' has no published anchors; "
                         "pass source='model'")
    if source == "paper":
        ta = a.published.get("time_ms", {})
        tb = b.published.get("time_ms", {})
        common = set(ta) & set(tb)
        if sizes is None:
            sizes = sorted(common)
        if not sizes or not common.issuperset(sizes):
            raise ValueError(
                f"sizes {sorted(set(sizes or ()) - common)} have no "
                f"published anchors for {a.name} vs {b.name} "
                f"(published: {sorted(common)}); pass source='model' or "
                f"extend the arch tables")
        rows = []
        for s in sizes:
            t_a, t_b = float(ta[s]), float(tb[s])
            p_a = float(a.published.get("power_w", a.power_w))
            p_b = float(b.published.get("power_w", b.power_w))
            rows.append(_row(s, source, a.name, b.name,
                             t_a, t_b, p_a, p_b))
        return rows
    rows = []
    for s in (sizes or (256, 512, 1024)):
        tr_a = _model_row_seconds(a, s, transform)
        tr_b = _model_row_seconds(b, s, transform)
        rows.append(_row(s, source, a.name, b.name,
                         tr_a.seconds * 1e3, tr_b.seconds * 1e3,
                         tr_a.power_w, tr_b.power_w, transform=transform))
    return rows


def _row(size, source, name_a, name_b, t_a_ms, t_b_ms, p_a, p_b, *,
         transform: str = "fft2") -> dict:
    e_a = p_a * t_a_ms * 1e-3                  # joules
    e_b = p_b * t_b_ms * 1e-3
    return {
        "size": int(size), "source": source, "transform": transform,
        "arch_a": name_a, "arch_b": name_b,
        "time_a_ms": t_a_ms, "time_b_ms": t_b_ms,
        "power_a_w": p_a, "power_b_w": p_b,
        "energy_a_j": e_a, "energy_b_j": e_b,
        "time_ratio": t_a_ms / t_b_ms,
        "power_ratio": p_b / p_a,
        "energy_ratio": e_b / e_a,
    }


def markdown_table(rows: List[dict]) -> str:
    """The paper's §6 table shape: per-size time/power/energy + ratios."""
    a, b = rows[0]["arch_a"], rows[0]["arch_b"]
    out = [
        f"| size | {a} t (ms) | {b} t (ms) | {a} P (W) | {b} P (W) | "
        f"{a} E (J) | {b} E (J) | slowdown | power x less | energy x less |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        tf = r.get("transform", "fft2")
        cell = f"{r['size']}x{r['size']}" if tf == "fft2" \
            else f"{tf} {r['size']}x{r['size']}"
        out.append(
            f"| {cell} | {r['time_a_ms']:.2f} | "
            f"{r['time_b_ms']:.2f} | {r['power_a_w']:.0f} | "
            f"{r['power_b_w']:.0f} | {r['energy_a_j']:.3f} | "
            f"{r['energy_b_j']:.3f} | {r['time_ratio']:.2f} | "
            f"{r['power_ratio']:.1f} | {r['energy_ratio']:.1f} |")
    return "\n".join(out)


def to_json(rows: List[dict], *, indent: int = 2) -> str:
    return json.dumps({"wormhole_vs_xeon": rows}, indent=indent,
                      sort_keys=True)


# ---------------------------------------------------------------------------
# Distributed pencil schedules (the halved-exchange table)
# ---------------------------------------------------------------------------

def dist_compare(sizes: Sequence[int] = (512, 1024), *, devices: int = 8,
                 arch="wormhole_n300", method: str = "none",
                 backend: str = "jnp") -> List[dict]:
    """Per-size model rows of the complex vs real-input pencil 2-D FFT on
    ``devices`` chips: predicted wall time, energy and per-device exchange
    wire bytes from :func:`repro.tt.trace.trace_dist`.  The headline
    column is ``wire_ratio`` ~ (N/2)/N = 0.5 — the ROADMAP's "halve the
    all_to_all bytes" as a number."""
    rows = []
    for s in sizes:
        tc = tttrace.trace_dist((s, s), devices=devices, arch=arch,
                                method=method, backend=backend)
        tr = tttrace.trace_dist((s, s), devices=devices, arch=arch,
                                method=method, backend=backend, real=True)
        rows.append({
            "size": int(s), "devices": devices, "arch": tc.arch,
            "method": method,
            "pfft2_wire_bytes": tc.exchange_wire_bytes,
            "prfft2_wire_bytes": tr.exchange_wire_bytes,
            "wire_ratio": tr.exchange_wire_bytes / tc.exchange_wire_bytes,
            "pfft2_ms": tc.seconds * 1e3, "prfft2_ms": tr.seconds * 1e3,
            "pfft2_energy_j": tc.energy_j, "prfft2_energy_j": tr.energy_j,
        })
    return rows


def dist_markdown_table(rows: List[dict]) -> str:
    out = [
        "| size | devices | method | pfft2 wire (B/dev) | prfft2 wire "
        "(B/dev) | ratio | pfft2 t (ms) | prfft2 t (ms) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['size']}x{r['size']} | {r['devices']} | {r['method']} | "
            f"{r['pfft2_wire_bytes']:.0f} | {r['prfft2_wire_bytes']:.0f} | "
            f"{r['wire_ratio']:.2f} | {r['pfft2_ms']:.3f} | "
            f"{r['prfft2_ms']:.3f} |")
    return "\n".join(out)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch-a", default="wormhole_n300")
    ap.add_argument("--arch-b", default="xeon_8160")
    ap.add_argument("--source", default="paper", choices=("paper", "model"))
    ap.add_argument("--transform", default="fft2",
                    choices=("fft2", "rfft2"),
                    help="rfft2 compares the real-input transform "
                         "(model source only)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = compare(args.arch_a, args.arch_b, source=args.source,
                   transform=args.transform)
    print(to_json(rows) if args.json else markdown_table(rows))


if __name__ == "__main__":
    main()
