"""Tensix backend pipeline: unpacker -> math -> packer as a timeline.

A Tensix core splits one kernel across dedicated backend units — the
unpacker pulls operand tiles from L1 into source registers, the matrix/
vector (FPU/SFPU) unit computes, the packer writes result tiles back to
L1, and the NoC movers stream tiles between L1, DRAM and other cores
(the unit decomposition of tt-sim's ``pe/tensix/backends/``:
unpacker / matrix / vector / packer / mover).  Units run concurrently,
hand tiles through circular buffers, and double-buffering lets tile
``t+1`` be unpacked while tile ``t`` is in the math unit: the pipeline's
steady-state rate is set by its *slowest* unit, which is exactly how the
Tensix "decouple movement from compute" story turns into numbers.

This module is the purely-architectural piece: given per-unit
seconds-per-tile, produce the pipeline timeline.  :mod:`repro.tt.trace`
derives the per-unit costs from an FFT plan's byte/flop counts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

#: Unit order along the pipeline: NoC/DRAM reader, L1 unpacker, FPU/SFPU
#: math, L1 packer, NoC/DRAM writer.
STAGES: Tuple[str, ...] = ("reader", "unpacker", "math", "packer", "writer")

#: Tensix operand granularity: one 32x32 tile.
TILE_DIM = 32
TILE_ELEMS = TILE_DIM * TILE_DIM


@dataclasses.dataclass(frozen=True)
class PipelineTimeline:
    """Timeline of one kernel through the five-unit pipeline."""
    n_tiles: int
    per_tile_s: Dict[str, float]     # seconds each unit spends per tile
    cb_depth: int                    # circular-buffer depth (2 = double buffer)
    fill_s: float                    # time for the first tile to drain through
    steady_tile_s: float             # issue interval once the pipe is full
    total_s: float
    bottleneck: str                  # unit that sets the steady-state rate
    occupancy: Dict[str, float]      # per-unit busy fraction of total_s

    @property
    def movement_bound(self) -> bool:
        """True when a data-movement unit (not math) sets the rate."""
        return self.bottleneck != "math"


def pipeline_timeline(per_tile_s: Dict[str, float], n_tiles: int, *,
                      cb_depth: int = 2) -> PipelineTimeline:
    """Schedule ``n_tiles`` tiles through the unit pipeline.

    With ``cb_depth >= 2`` the circular buffers decouple the units:
    after a fill of one full traversal, tiles complete every
    ``max(unit)`` seconds.  With ``cb_depth == 1`` (no double buffering)
    each tile must fully drain before the next is admitted, so the whole
    pipeline serialises to ``n_tiles * sum(units)`` — the degenerate
    schedule the paper's un-overlapped first designs correspond to.
    """
    assert n_tiles >= 1 and cb_depth >= 1
    per = {s: float(per_tile_s.get(s, 0.0)) for s in STAGES}
    fill = sum(per.values())
    slowest = max(per, key=per.get)
    if cb_depth == 1:
        steady = fill
        total = n_tiles * fill
    else:
        steady = per[slowest]
        total = fill + (n_tiles - 1) * steady
    occupancy = {s: (n_tiles * v) / total if total > 0 else 0.0
                 for s, v in per.items()}
    return PipelineTimeline(n_tiles=n_tiles, per_tile_s=per,
                            cb_depth=cb_depth, fill_s=fill,
                            steady_tile_s=steady, total_s=total,
                            bottleneck=slowest, occupancy=occupancy)


def stage_costs(*, flops: float, dram_in: float, dram_out: float,
                sram_read: float, sram_write: float, arch) -> Dict[str, float]:
    """Aggregate per-unit seconds for one kernel on a Tensix-like device.

    DRAM traffic is shared device-wide (reader/writer = mover units on the
    DRAM-adjacent cores); unpack/pack bandwidth and FLOP/s scale with the
    number of cores the kernel spreads over.
    """
    l1_bw = arch.l1_bw * arch.cores
    return {
        "reader": dram_in / arch.dram_bw if arch.dram_bw else 0.0,
        "unpacker": sram_read / l1_bw if l1_bw else 0.0,
        "math": flops / arch.peak_flops_f32 if arch.peak_flops_f32 else 0.0,
        "packer": sram_write / l1_bw if l1_bw else 0.0,
        "writer": dram_out / arch.dram_bw if arch.dram_bw else 0.0,
    }


def kernel_timeline(*, flops: float, dram_in: float, dram_out: float,
                    sram_read: float, sram_write: float, arch,
                    elem_bytes: int = 4, cb_depth: int = 2) -> PipelineTimeline:
    """Timeline for one kernel: split its aggregate unit costs over the
    32x32-tile stream the units actually hand around."""
    tile_bytes = TILE_ELEMS * elem_bytes
    moved = max(dram_in + dram_out, sram_read + sram_write, tile_bytes)
    n_tiles = max(1, int(moved // tile_bytes))
    total = stage_costs(flops=flops, dram_in=dram_in, dram_out=dram_out,
                        sram_read=sram_read, sram_write=sram_write, arch=arch)
    per_tile = {s: v / n_tiles for s, v in total.items()}
    return pipeline_timeline(per_tile, n_tiles, cb_depth=cb_depth)
