"""Parameterised hardware tables for the data-movement/energy model.

One :class:`Arch` record per machine the repo reasons about: the paper's
Wormhole n300 and its Xeon Platinum 8160 baseline (§6), the earlier
Grayskull e150 ("Accelerating stencils on the Tenstorrent Grayskull",
Brown & Barton 2024), and the TPU v5e that
:mod:`repro.analysis.roofline` was previously hardcoded to.

Three kinds of numbers live here, kept deliberately separate:

- **rate parameters** (peak FLOP/s, DRAM/NoC/L1 bandwidths, launch
  overhead) feed the analytic time model in :mod:`repro.tt.trace`;
- **energy coefficients** (pJ per flop / DRAM byte / NoC byte / SRAM
  byte, plus idle power) feed its energy integral;
- **published anchors** (``published``) are the paper's §6 *measured*
  figures — 2-D FFT wall time and device power under load — which
  :mod:`repro.tt.report` uses to reproduce the Wormhole-vs-Xeon table
  exactly (~8x power, ~2.8x energy) without trusting the optimistic
  analytic rates.

Rates are aggregate per device (bytes/s, FLOP/s); ``l1_bw`` is per core.
Custom entries register via :func:`register_arch` (see README,
"Modelling the Wormhole").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

KIB = 1024
MIB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    kind: str                       # "tensix" | "tpu" | "cpu"
    cores: int                      # Tensix cores / TensorCores / CPU cores
    clock_hz: float
    peak_flops_f32: float           # aggregate device FLOP/s
    peak_flops_bf16: float
    dram_bw: float                  # aggregate device DRAM bytes/s
    noc_bw: float                   # per-link on-chip NoC bytes/s
    link_bw: float                  # off-chip interconnect bytes/s (ICI/PCIe/UPI)
    l1_bytes: int                   # per-core scratch: Tensix L1 / TPU VMEM / CPU L2
    l1_bw: float                    # per-core scratch bandwidth bytes/s
    dram_bytes: int                 # device memory capacity
    power_w: float                  # measured device power under FFT load
    idle_power_w: float
    launch_overhead_s: float        # per-kernel dispatch cost
    noc_latency_s: float            # per-hop NoC latency
    energy_per_flop_j: float
    energy_per_dram_byte_j: float
    energy_per_noc_byte_j: float
    energy_per_sram_byte_j: float
    noc_grid: Tuple[int, int] = (1, 1)   # physical core grid the NoC routes over
    # -- multi-chip interconnect (the dist.pencil exchange fabric) ----------
    # Wormhole chips talk over 100 Gb/s ethernet links (16 per chip on the
    # n300 generation); TPUs over ICI; CPUs over UPI.  ``eth_bw`` is the
    # per-link rate, ``eth_links`` how many a collective can stripe across,
    # ``eth_latency_s`` the per-hop cost on the chip grid
    # (:func:`chip_grid` / :data:`MULTICHIP_GRIDS`).  Zero falls back to
    # the single-link ``link_bw`` / ``noc_latency_s`` numbers.
    eth_bw: float = 0.0                  # per ethernet/ICI link bytes/s
    eth_links: int = 1                   # parallel links per chip
    eth_latency_s: float = 0.0           # per chip-to-chip hop
    energy_per_link_byte_j: float = 0.0  # serdes energy; 0 -> NoC coefficient
    published: dict = dataclasses.field(default_factory=dict)

    @property
    def sram_budget(self) -> int:
        """Scratch budget one kernel working set is checked against.

        TPU Pallas kernels stage the whole block in one core's VMEM, so the
        budget is per-core; a Tensix/CPU kernel spreads its working set over
        every core's L1/L2.
        """
        if self.kind == "tpu":
            return self.l1_bytes
        return self.l1_bytes * self.cores

    def peak_flops(self, dtype: str = "float32") -> float:
        return self.peak_flops_bf16 if "bf16" in dtype or "bfloat16" in dtype \
            else self.peak_flops_f32


# ---------------------------------------------------------------------------
# Entries
# ---------------------------------------------------------------------------
# Wormhole n300: two Wormhole ASICs, 64 usable Tensix cores each @ ~1 GHz,
# 1.5 MB L1 per core, 24 GB GDDR6 at 576 GB/s aggregate, 32 B/cycle NoC
# links.  The `published` block is the paper's §6 measurement: the n300 is
# ~2.8x *slower* than the Xeon on the 2-D FFT but draws ~8x less power, so
# it spends ~2.8x less energy.
WORMHOLE_N300 = Arch(
    name="wormhole_n300", kind="tensix", cores=128, clock_hz=1.0e9,
    peak_flops_f32=8.2e12, peak_flops_bf16=131e12,
    dram_bw=576e9, noc_bw=32e9, link_bw=32e9,
    l1_bytes=int(1.5 * MIB), l1_bw=64e9, dram_bytes=int(24e9),
    power_w=20.0, idle_power_w=12.0,
    launch_overhead_s=5e-6, noc_latency_s=9e-9,
    energy_per_flop_j=1.2e-12, energy_per_dram_byte_j=15e-12,
    energy_per_noc_byte_j=1.5e-12, energy_per_sram_byte_j=0.4e-12,
    noc_grid=(8, 16),
    eth_bw=12.5e9, eth_links=16, eth_latency_s=1.0e-6,
    energy_per_link_byte_j=30e-12,
    published={
        "workload": "fft2d_f32",
        "source": "paper §6 (Wormhole n300 measured)",
        "time_ms": {256: 0.31, 512: 1.36, 1024: 5.9},
        "power_w": 20.0,
    },
)

# Grayskull e150: 120 Tensix @ 1.2 GHz, 1 MB L1, 8 GB LPDDR4 at 118 GB/s —
# the generation the stencil paper (Brown & Barton 2024) characterised.
GRAYSKULL_E150 = Arch(
    name="grayskull_e150", kind="tensix", cores=120, clock_hz=1.2e9,
    peak_flops_f32=3.5e12, peak_flops_bf16=55e12,
    dram_bw=118.4e9, noc_bw=38.4e9, link_bw=16e9,
    l1_bytes=1 * MIB, l1_bw=51e9, dram_bytes=int(8e9),
    power_w=75.0, idle_power_w=35.0,
    launch_overhead_s=6e-6, noc_latency_s=9e-9,
    energy_per_flop_j=1.6e-12, energy_per_dram_byte_j=22e-12,
    energy_per_noc_byte_j=1.8e-12, energy_per_sram_byte_j=0.5e-12,
    noc_grid=(10, 12),
    eth_bw=16e9, eth_links=1, eth_latency_s=2.0e-6,   # PCIe only, no eth mesh
    energy_per_link_byte_j=35e-12,
)

# TPU v5e: the numbers repro.analysis.roofline previously hardcoded —
# 197 TFLOP/s bf16 (98.5 f32), 819 GB/s HBM, ~50 GB/s/link ICI, 16 GB HBM,
# 215 W — plus the ~16 MiB per-core VMEM budget the fused 2-D kernel's tile
# working set is checked against (ROADMAP: 1024x1024 footprint question).
TPU_V5E = Arch(
    name="tpu_v5e", kind="tpu", cores=1, clock_hz=0.94e9,
    peak_flops_f32=98.5e12, peak_flops_bf16=197e12,
    dram_bw=819e9, noc_bw=819e9, link_bw=50e9,
    l1_bytes=16 * MIB, l1_bw=3e12, dram_bytes=int(16e9),
    power_w=215.0, idle_power_w=60.0,
    launch_overhead_s=3e-6, noc_latency_s=1e-9,
    energy_per_flop_j=0.45e-12, energy_per_dram_byte_j=7e-12,
    energy_per_noc_byte_j=2e-12, energy_per_sram_byte_j=0.15e-12,
    eth_bw=50e9, eth_links=4, eth_latency_s=1.0e-6,   # ICI 2-D torus
    energy_per_link_byte_j=10e-12,
)

# Xeon Platinum 8160: the paper's CPU baseline — 24 cores @ 2.1 GHz base,
# AVX-512 (2x FMA/core), 6-channel DDR4-2666 (~128 GB/s), 1 MB L2/core.
# `published` holds the paper's measured FFTW wall time and package power.
XEON_8160 = Arch(
    name="xeon_8160", kind="cpu", cores=24, clock_hz=2.1e9,
    peak_flops_f32=3.2e12, peak_flops_bf16=3.2e12,
    dram_bw=128e9, noc_bw=96e9, link_bw=20.8e9,
    l1_bytes=1 * MIB, l1_bw=100e9, dram_bytes=int(192e9),
    power_w=160.0, idle_power_w=55.0,
    launch_overhead_s=0.5e-6, noc_latency_s=40e-9,
    energy_per_flop_j=20e-12, energy_per_dram_byte_j=25e-12,
    energy_per_noc_byte_j=4e-12, energy_per_sram_byte_j=1.5e-12,
    noc_grid=(4, 6),
    eth_bw=20.8e9, eth_links=3, eth_latency_s=0.5e-6,  # UPI
    energy_per_link_byte_j=20e-12,
    published={
        "workload": "fft2d_f32",
        "source": "paper §6 (24-core Xeon Platinum, FFTW)",
        "time_ms": {256: 0.11, 512: 0.48, 1024: 2.1},
        "power_w": 160.0,
    },
)


ARCHS: Dict[str, Arch] = {a.name: a for a in
                          (WORMHOLE_N300, GRAYSKULL_E150, TPU_V5E, XEON_8160)}

_ALIASES = {
    "wormhole": "wormhole_n300", "n300": "wormhole_n300",
    "grayskull": "grayskull_e150", "e150": "grayskull_e150",
    "tpu": "tpu_v5e", "v5e": "tpu_v5e",
    "xeon": "xeon_8160", "cpu": "xeon_8160",
}


def get_arch(name) -> Arch:
    """Look up an entry by name or alias; Arch instances pass through."""
    if isinstance(name, Arch):
        return name
    key = _ALIASES.get(str(name).lower(), str(name).lower())
    try:
        return ARCHS[key]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)} "
                       f"(aliases: {sorted(_ALIASES)})") from None


def register_arch(arch: Arch, *aliases: str) -> Arch:
    """Add a custom entry (and optional aliases) to the table."""
    ARCHS[arch.name] = arch
    for a in aliases:
        _ALIASES[a.lower()] = arch.name
    return arch


# ---------------------------------------------------------------------------
# Multi-chip hop table
# ---------------------------------------------------------------------------
# How `devices` chips are wired for the dist.pencil exchanges: the canonical
# near-square meshes (an n300 board is 2 chips; a TT "nebula" rack 2x4; a
# galaxy 4x8; TPU ICI slices are 2-D tori).  :func:`chip_grid` answers for
# any count, falling back to the most-square factorisation, and
# :func:`repro.tt.noc.eth_hops` turns the grid into a mean hop count.

MULTICHIP_GRIDS: Dict[int, Tuple[int, int]] = {
    1: (1, 1), 2: (1, 2), 4: (2, 2), 8: (2, 4),
    16: (4, 4), 32: (4, 8), 64: (8, 8),
}


def chip_grid(devices: int) -> Tuple[int, int]:
    """The (rows, cols) chip mesh `devices` chips are arranged in."""
    devices = int(devices)
    assert devices >= 1, devices
    if devices in MULTICHIP_GRIDS:
        return MULTICHIP_GRIDS[devices]
    r = int(devices ** 0.5)
    while devices % r:
        r -= 1
    return (r, devices // r)


def hw_table(name="tpu_v5e") -> dict:
    """The legacy ``repro.analysis.roofline.HW`` dict shape, for any arch.

    Kept as the single bridge so the roofline keeps its public key names
    while the numbers live here.
    """
    a = get_arch(name)
    return {
        "peak_flops_bf16": a.peak_flops_bf16,
        "peak_flops_f32": a.peak_flops_f32,
        "hbm_bw": a.dram_bw,
        "ici_bw": a.link_bw,
        "hbm_per_chip": float(a.dram_bytes),
        "chip_power_w": a.power_w,
    }
