"""Single-device 2-D / 3-D FFTs (the paper's Section 5 workload, one chip).

Two execution paths behind the plan registry's ``backend`` switch:

- ``backend="jnp"`` — row-column decomposition: FFT the last axis, global
  transpose, FFT again.  The explicit transpose mirrors the paper's global
  transpose between the two 1-D passes; XLA lowers it to an in-HBM relayout.
- ``backend="pallas"`` — the GEMM-formulated fused kernel
  (:mod:`repro.kernels.fft2d_gemm`, ``algo="fused"``): both 1-D passes run
  as four-step DFT matmuls inside one kernel, the column pass as left-side
  contractions, so the global transpose never materialises anywhere — not
  in HBM, not even in VMEM.  ``algo="fused_stockham"`` keeps the previous
  Stockham-stage fused kernel (:mod:`repro.kernels.fft2d_fused`) as the
  explicit-algo oracle, and ``algo="row_col"`` the transpose-based
  two-kernel pipeline as the measured baseline.

``fft2`` and ``fft3`` with ``algo="auto"`` route through
:func:`repro.core.plan.get_plan` so the (shape, dtype, direction, backend)
decision — and any autotune result — is resolved once and reused; 3-D
pallas keys resolve to the fused pencil-in-VMEM kernel
(:mod:`repro.kernels.fft3d_fused`).  The distributed version (all_to_all
pencil transpose) lives in :mod:`repro.dist.pencil`.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import complexmath as cm
from .complexmath import SplitComplex
from . import fft1d


def _swap(x: SplitComplex, a: int, b: int) -> SplitComplex:
    return SplitComplex(jnp.swapaxes(x.re, a, b), jnp.swapaxes(x.im, a, b))


def _fft2_direct(x: SplitComplex, *, inverse: bool = False,
                 algo: str = "auto", backend: str = "jnp",
                 block_batch: int = None,
                 variant: str = "plain") -> SplitComplex:
    """Execute a resolved 2-D plan config (no registry lookup).

    ``block_batch`` means images-per-tile for the fused kernels and the 1-D
    kernel's row tile for the row_col baseline (defaults 1 and 8);
    ``variant`` selects the GEMM kernel's precision path ("plain" or the
    bf16 "compensated" one).
    """
    if backend == "pallas":
        from repro.kernels import ops as kops
        if algo not in ("auto", "fused", "fused_stockham", "row_col"):
            raise ValueError(f'algo={algo!r} has no pallas 2-D path; use '
                             '"fused", "fused_stockham" or "row_col" '
                             '(or backend="jnp")')
        if algo in ("auto", "fused"):
            return kops.fft2d_gemm(x, inverse=inverse,
                                   block_batch=block_batch or 1,
                                   variant=variant)
        if algo == "fused_stockham":
            # the explicit-algo oracle: the pre-GEMM Stockham fused kernel
            return kops.fft2d_fused(x, inverse=inverse,
                                    block_batch=block_batch or 1)
        # transpose-based baseline on the same backend: two 1-D kernel
        # passes with an explicit global (HBM) transpose between them
        bb = block_batch or 8
        y = kops.fft_stockham(x, inverse=inverse, block_batch=bb)
        y = _swap(y, -1, -2)
        y = kops.fft_stockham(y, inverse=inverse, block_batch=bb)
        return _swap(y, -1, -2)
    if algo in ("fused", "fused_stockham"):
        raise ValueError(f'algo={algo!r} requires backend="pallas" '
                         '(the fused kernels have no jnp equivalent)')
    row_algo = "auto" if algo in ("auto", "row_col") else algo
    y = fft1d.fft(x, inverse=inverse, algo=row_algo)   # FFT each row
    y = _swap(y, -1, -2)                               # global transpose
    y = fft1d.fft(y, inverse=inverse, algo=row_algo)   # FFT each column
    return _swap(y, -1, -2)


def fft2(x: SplitComplex, *, inverse: bool = False, algo: str = "auto",
         backend: str = "jnp") -> SplitComplex:
    """2-D FFT over the last two axes, routed through the plan registry."""
    if len(x.shape) < 2:
        raise ValueError(f"fft2 needs at least 2 axes, got shape {x.shape}")
    if algo == "auto":
        from . import plan as _plan
        return _plan.get_plan(x.shape[-2:], dtype=x.dtype, inverse=inverse,
                              backend=backend)(x)
    return _fft2_direct(x, inverse=inverse, algo=algo, backend=backend)


def _fft3_direct(x: SplitComplex, *, inverse: bool = False,
                 algo: str = "auto", backend: str = "jnp",
                 block_batch: int = None,
                 variant: str = "plain") -> SplitComplex:
    """Execute a resolved 3-D plan config (no registry lookup)."""
    if backend == "pallas":
        from repro.kernels import ops as kops
        if algo not in ("auto", "fused", "row_col"):
            raise ValueError(f'algo={algo!r} has no pallas 3-D path; use '
                             '"fused" or "row_col" (or backend="jnp")')
        if algo in ("auto", "fused"):
            return kops.fft3d_fused(x, inverse=inverse,
                                    block_batch=block_batch or 1,
                                    variant=variant)
        # transpose-based baseline: three 1-D kernel passes with explicit
        # global (HBM) relayouts between them
        bb = block_batch or 8
        y = kops.fft_stockham(x, inverse=inverse, block_batch=bb)
        y = _swap(y, -1, -2)
        y = kops.fft_stockham(y, inverse=inverse, block_batch=bb)
        y = _swap(y, -1, -2)
        y = _swap(y, -1, -3)
        y = kops.fft_stockham(y, inverse=inverse, block_batch=bb)
        return _swap(y, -1, -3)
    if algo == "fused":
        raise ValueError('algo="fused" requires backend="pallas" '
                         '(the fused 3-D kernel has no jnp equivalent)')
    pass_algo = "auto" if algo in ("auto", "row_col") else algo
    y = fft1d.fft(x, inverse=inverse, algo=pass_algo)
    y = _swap(y, -1, -2)
    y = fft1d.fft(y, inverse=inverse, algo=pass_algo)
    y = _swap(y, -1, -2)
    y = _swap(y, -1, -3)
    y = fft1d.fft(y, inverse=inverse, algo=pass_algo)
    return _swap(y, -1, -3)


def fft3(x: SplitComplex, *, inverse: bool = False, algo: str = "auto",
         backend: str = "jnp") -> SplitComplex:
    """3-D FFT over the last three axes, routed through the plan registry.

    ``algo="auto"`` resolves the (d, h, w) key once per shape — pallas
    keys select the fused pencil-in-VMEM kernel
    (:mod:`repro.kernels.fft3d_fused`) and demote to jnp with a
    registry-visible reason when the shape has no kernel path — exactly
    the plumbing :func:`fft2` has always had (previously ``fft3`` took no
    ``backend`` and bypassed the registry entirely, so no 3-D caller
    could reach a kernel or see a demote reason).
    """
    if len(x.shape) < 3:
        raise ValueError(f"fft3 needs at least 3 axes, got shape {x.shape}")
    if algo == "auto":
        from . import plan as _plan
        return _plan.get_plan(x.shape[-3:], dtype=x.dtype, inverse=inverse,
                              backend=backend)(x)
    return _fft3_direct(x, inverse=inverse, algo=algo, backend=backend)


def rfft2(x: jnp.ndarray, *, algo: str = "auto",
          backend: str = "jnp") -> SplitComplex:
    """Real-input 2-D FFT: rfft rows (half spectrum), full FFT columns.

    Beyond-paper: halves the row-pass FLOPs and — in the distributed
    version — the transpose all_to_all bytes.  ``algo="auto"`` routes
    through the registry's rfft-kind (h, w) key: on ``backend="jnp"`` the
    row-pass inner algo is resolved once per shape and the column pass
    composes with the (h,)-key c2c plan; ``backend="pallas"`` selects the
    fused real-input kernel (:mod:`repro.kernels.rfft2d_fused`) — one
    kernel, half the complex fused kernel's HBM traffic — demoting to jnp
    with a registry-visible reason when the shape has no kernel path.
    """
    if algo == "auto":
        from . import plan as _plan
        return _plan.get_plan(x.shape[-2:], dtype=x.dtype, kind="rfft",
                              backend=backend)(x)
    if algo == "fused":
        if backend != "pallas":
            raise ValueError('algo="fused" requires backend="pallas" '
                             '(the fused rfft kernel has no jnp equivalent)')
        from repro.kernels import ops as kops
        return kops.rfft2d_fused(x)
    return _rfft2_direct(x, row_algo=algo, col_algo=algo, backend=backend)


def _rfft2_direct(x: jnp.ndarray, *, row_algo: str, col_algo: str = "auto",
                  backend: str = "jnp") -> SplitComplex:
    """Execute a resolved rfft2 config.  ``row_algo`` is the inner complex
    algo of the packed row rfft (explicit, never "auto"); the column pass
    is an ordinary c2c transform that may route through its own plan key.
    ``backend="pallas"`` runs both passes on the 1-D kernels where the
    algo has one (:func:`repro.core.fft1d._fft_inner`).
    """
    y = fft1d._rfft_direct(x, algo=row_algo,
                           backend=backend)            # (..., H, W/2+1)
    y = _swap(y, -1, -2)
    y = fft1d._fft_inner(y, algo=col_algo, backend=backend)
    return _swap(y, -1, -2)


def irfft2(xf: SplitComplex, s=None, *, algo: str = "auto",
           backend: str = "jnp") -> jnp.ndarray:
    """Inverse real 2-D FFT from the (..., H, W/2+1) half spectrum.

    ``s=(h, w)`` follows ``numpy.fft.irfft2``: the spectrum is truncated or
    trailing-zero-padded to h rows and w//2+1 bins, then transformed with
    an output width of ``w``.  Odd widths follow numpy's odd-``s``
    semantics on the direct (jnp) path — the registry's rfft keys and the
    fused kernel cover even widths.  The fit happens before plan dispatch,
    so every path sees the same spectrum.
    """
    if s is not None:
        h, w = (int(d) for d in s)
        if h < 1 or w < 1:
            raise ValueError(f"irfft2 output shape must be positive, "
                             f"got s={s}")
        xf = _fit_spectrum2(xf, h, w)
    else:
        w = 2 * (xf.shape[-1] - 1)
    h = xf.shape[-2]
    if w % 2:                     # odd width: numpy semantics, direct path
        if algo == "fused":
            raise ValueError(f"the fused rfft kernel needs an even output "
                             f"width, got s={s}")
        return _irfft2_direct(xf, row_algo=algo, col_algo=algo, w=w,
                              backend=backend)
    if algo == "fused":
        if backend != "pallas":
            raise ValueError('algo="fused" requires backend="pallas" '
                             '(the fused rfft kernel has no jnp equivalent)')
        from repro.kernels import ops as kops
        return kops.irfft2d_fused(xf)
    if algo == "auto":
        from . import plan as _plan
        return _plan.get_plan((h, w), dtype=xf.dtype, inverse=True,
                              kind="rfft", backend=backend)(xf)
    return _irfft2_direct(xf, row_algo=algo, col_algo=algo, w=w,
                          backend=backend)


def _fit_spectrum2(xf: SplitComplex, h: int, w: int) -> SplitComplex:
    """Truncate / zero-pad a 2-D half spectrum to (h, w//2+1) — numpy's
    ``ifft(a, n=h)`` trailing-fit on axis -2, then the 1-D half-spectrum
    fit on the last axis."""
    rows = xf.shape[-2]
    if rows > h:
        xf = SplitComplex(xf.re[..., :h, :], xf.im[..., :h, :])
    elif rows < h:
        pad = [(0, 0)] * (xf.re.ndim - 2) + [(0, h - rows), (0, 0)]
        xf = SplitComplex(jnp.pad(xf.re, pad), jnp.pad(xf.im, pad))
    return fft1d._fit_half_spectrum(xf, w)


def _irfft2_direct(xf: SplitComplex, *, row_algo: str,
                   col_algo: str = "auto", w: int = None,
                   backend: str = "jnp") -> jnp.ndarray:
    y = _swap(xf, -1, -2)
    y = fft1d._fft_inner(y, inverse=True, algo=col_algo, backend=backend)
    y = _swap(y, -1, -2)
    n = w if w is not None else 2 * (xf.shape[-1] - 1)
    return fft1d._irfft_direct(y, n, algo=row_algo, backend=backend)
