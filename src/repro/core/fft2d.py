"""Single-device 2-D / 3-D FFTs (the paper's Section 5 workload, one chip).

Row-column decomposition: FFT the last axis, transpose, FFT again.  The
explicit transpose mirrors the paper's global transpose between the two 1-D
passes; on one device XLA lowers it to an in-HBM relayout.  The distributed
version (all_to_all pencil transpose) lives in :mod:`repro.dist.pencil`.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import complexmath as cm
from .complexmath import SplitComplex
from . import fft1d


def _swap(x: SplitComplex, a: int, b: int) -> SplitComplex:
    return SplitComplex(jnp.swapaxes(x.re, a, b), jnp.swapaxes(x.im, a, b))


def fft2(x: SplitComplex, *, inverse: bool = False,
         algo: str = "auto") -> SplitComplex:
    """2-D FFT over the last two axes: rows, transpose, rows, transpose."""
    y = fft1d.fft(x, inverse=inverse, algo=algo)       # FFT each row
    y = _swap(y, -1, -2)                               # global transpose
    y = fft1d.fft(y, inverse=inverse, algo=algo)       # FFT each column
    return _swap(y, -1, -2)


def fft3(x: SplitComplex, *, inverse: bool = False,
         algo: str = "auto") -> SplitComplex:
    """3-D FFT over the last three axes."""
    y = fft1d.fft(x, inverse=inverse, algo=algo)
    y = _swap(y, -1, -2)
    y = fft1d.fft(y, inverse=inverse, algo=algo)
    y = _swap(y, -1, -2)
    y = _swap(y, -1, -3)
    y = fft1d.fft(y, inverse=inverse, algo=algo)
    return _swap(y, -1, -3)


def rfft2(x: jnp.ndarray, *, algo: str = "auto") -> SplitComplex:
    """Real-input 2-D FFT: rfft rows (half spectrum), full FFT columns.

    Beyond-paper: halves the row-pass FLOPs and — in the distributed
    version — the transpose all_to_all bytes.
    """
    y = fft1d.rfft(x, algo=algo)                       # (..., H, W/2+1)
    y = _swap(y, -1, -2)
    y = fft1d.fft(y, algo=algo)
    return _swap(y, -1, -2)


def irfft2(xf: SplitComplex, *, algo: str = "auto") -> jnp.ndarray:
    y = _swap(xf, -1, -2)
    y = fft1d.ifft(y, algo=algo)
    y = _swap(y, -1, -2)
    return fft1d.irfft(y, algo=algo)
