"""FFT-based long convolution (O(L log L)) on real signals.

The LM-integration point of the paper's technique (DESIGN.md §4): SSM/hybrid
mixers evaluate their long-convolution view through the FFT library instead
of a direct O(L*K) conv.  Built entirely from :mod:`repro.core.fft1d`; with
``algo="auto"`` every rfft/irfft below routes through the plan registry
(the packed half-size complex transform of length m/2 is the cached key),
so repeated convolutions at one length reuse a single resolved plan.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import complexmath as cm
from . import fft1d


def _next_pow2(n: int) -> int:
    return 1 << int(np.ceil(np.log2(max(n, 1))))


def fft_conv(x: jnp.ndarray, k: jnp.ndarray, *, causal: bool = True,
             algo: str = "auto") -> jnp.ndarray:
    """Convolve signal x (..., L) with kernel k (..., K) via rfft.

    causal=True returns y[t] = sum_{s<=t} x[s] k[t-s] truncated to length L
    (the long-conv form used by SSM token mixers).
    """
    L = x.shape[-1]
    K = k.shape[-1]
    m = _next_pow2(L + K - 1)
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, m - L)])
    kp = jnp.pad(k, [(0, 0)] * (k.ndim - 1) + [(0, m - K)])
    xf = fft1d.rfft(xp, algo=algo)
    kf = fft1d.rfft(kp, algo=algo)
    yf = cm.mul(xf, kf)
    y = fft1d.irfft(yf, m, algo=algo)
    if causal:
        return y[..., :L]
    return y[..., : L + K - 1]


def circular_conv(x: jnp.ndarray, k: jnp.ndarray, *,
                  algo: str = "auto") -> jnp.ndarray:
    """Circular convolution of equal-length real signals."""
    assert x.shape[-1] == k.shape[-1]
    xf = fft1d.rfft(x, algo=algo)
    kf = fft1d.rfft(k, algo=algo)
    return fft1d.irfft(cm.mul(xf, kf), x.shape[-1], algo=algo)
