"""FFT-based long convolution (O(L log L)) on real signals.

The LM-integration point of the paper's technique (DESIGN.md §4): SSM/hybrid
mixers evaluate their long-convolution view through the FFT library instead
of a direct O(L*K) conv.

With ``algo="auto"`` every convolution routes through a **conv-kind plan**
(:mod:`repro.core.plan`, ``kind="conv_causal"`` / ``"conv_circular"``),
keyed on the padded FFT length, dtype, backend and mode.  On
``backend="pallas"`` the plan runs the fused spectral-convolution kernel
(:mod:`repro.kernels.fftconv_fused`): forward rfft, pointwise multiply and
inverse irfft in ONE VMEM-resident pass — the spectrum never touches HBM,
versus the six half/full planes the unfused rfft -> ``cm.mul`` -> irfft
composition ships per call.  Lengths with no kernel path (non-power-of-two
circular lengths, tiny m) demote to the registry-composed unfused schedule
with a registry-visible ``demote_reason``.

The **filter half spectrum is cached per plan key**: repeated eager calls
at one length with the same (static) filter object — exactly the
SSM/Hyena serving pattern — skip the kernel-side rfft entirely
(``SPECTRUM_STATS`` counts computes vs hits).  Traced filters (jit-time
parameters, which change value every training step) bypass the cache; the
spectrum compute is then part of the traced graph, paid once per
compilation, and recomputing it per step is semantically required.

An explicit ``algo=`` (e.g. ``"stockham"``) keeps the historical direct
path: rfft/irfft with that inner algo, no conv plan, no caching.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import complexmath as cm
from . import fft1d


def _next_pow2(n: int) -> int:
    return 1 << int(np.ceil(np.log2(max(n, 1))))


# -- per-plan filter-spectrum cache -----------------------------------------

_SPECTRUM_CACHE = {}   # spectrum key -> (filter array, its half spectrum)
SPECTRUM_STATS = {}    # spectrum key -> {"computes": int, "hits": int}


def _spectrum_key(plan):
    return (plan.shape, plan.dtype, plan.kind, plan.backend, plan.algo)


def clear_spectrum_cache() -> None:
    """Drop every cached filter spectrum (called by
    :func:`repro.core.plan.clear_plan_cache` — spectra key on plans) and
    the fused kernel's packed-domain filter cache (packed operands derive
    from spectra)."""
    _SPECTRUM_CACHE.clear()
    SPECTRUM_STATS.clear()
    from repro.kernels import fftconv_fused as _fconv
    _fconv.clear_pack_cache()


def _compute_kf(k, m: int) -> cm.SplitComplex:
    kp = jnp.pad(k, [(0, 0)] * (k.ndim - 1) + [(0, m - k.shape[-1])])
    return fft1d.rfft(kp)              # jnp registry key: one-time cost


def _filter_spectrum(plan, k, m: int) -> cm.SplitComplex:
    """The filter's half spectrum at the plan's padded length, cached per
    plan key for static (eager) filters.  The hit test is object identity:
    callers holding one filter array across calls — the serving pattern —
    hit; a fresh array recomputes and replaces the entry (never staler
    than the filter actually passed)."""
    if isinstance(k, jax.core.Tracer):
        return _compute_kf(k, m)       # traced params change every step
    key = _spectrum_key(plan)
    stats = SPECTRUM_STATS.setdefault(key, {"computes": 0, "hits": 0})
    ent = _SPECTRUM_CACHE.get(key)
    if ent is not None and ent[0] is k:
        stats["hits"] += 1
        return ent[1]
    kf = _compute_kf(k, m)
    _SPECTRUM_CACHE[key] = (k, kf)
    stats["computes"] += 1
    return kf


# -- public entry points -----------------------------------------------------

def _conv_plan(x, k, *, m: int, out_len: int, kind: str, backend: str):
    from . import plan as _plan        # deferred: plan imports fftconv
    plan = _plan.get_plan((m,), dtype=x.dtype, kind=kind, backend=backend)
    kf = _filter_spectrum(plan, k, m)
    L = x.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, m - L)]) if m > L else x
    y = plan(xp, kf)
    return y[..., :out_len]


def _conv_direct(x, k, *, m: int, out_len: int, algo: str, backend: str):
    """The historical explicit-algo path: rfft -> mul -> irfft with the
    requested inner algo, no conv plan, no spectrum caching."""
    L, K = x.shape[-1], k.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, m - L)])
    kp = jnp.pad(k, [(0, 0)] * (k.ndim - 1) + [(0, m - K)])
    xf = fft1d.rfft(xp, algo=algo, backend=backend)
    kf = fft1d.rfft(kp, algo=algo, backend=backend)
    y = fft1d.irfft(cm.mul(xf, kf), m, algo=algo, backend=backend)
    return y[..., :out_len]


def fft_conv(x: jnp.ndarray, k: jnp.ndarray, *, causal: bool = True,
             algo: str = "auto", backend: str = "jnp") -> jnp.ndarray:
    """Convolve signal x (..., L) with kernel k (..., K) via rfft.

    causal=True returns y[t] = sum_{s<=t} x[s] k[t-s] truncated to length L
    (the long-conv form used by SSM token mixers).  ``backend="pallas"``
    routes the ``kind="conv_causal"`` plan to the fused VMEM-resident
    kernel; demotions keep a registry-visible ``demote_reason``.
    """
    L = x.shape[-1]
    K = k.shape[-1]
    m = _next_pow2(L + K - 1)
    out_len = L if causal else L + K - 1
    if algo != "auto":
        return _conv_direct(x, k, m=m, out_len=out_len, algo=algo,
                            backend=backend)
    return _conv_plan(x, k, m=m, out_len=out_len, kind="conv_causal",
                      backend=backend)


def circular_conv(x: jnp.ndarray, k: jnp.ndarray, *, algo: str = "auto",
                  backend: str = "jnp") -> jnp.ndarray:
    """Circular convolution of equal-length real signals.  The FFT length
    is the signal length itself, so non-power-of-two lengths demote the
    pallas request to the unfused jnp schedule (registry-visible)."""
    assert x.shape[-1] == k.shape[-1]
    m = x.shape[-1]
    if algo != "auto":
        return _conv_direct(x, k, m=m, out_len=m, algo=algo,
                            backend=backend)
    return _conv_plan(x, k, m=m, out_len=m, kind="conv_circular",
                      backend=backend)
