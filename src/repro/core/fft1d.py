"""1-D FFT algorithms on split-complex data, batched over leading axes.

Algorithm inventory (paper §4 mapped to TPU, see DESIGN.md §2):

- :func:`dft_naive`          O(N^2) dense DFT matmul.  The test oracle and the
                             MXU leaf operator of the four-step path.
- :func:`fft_cooley_tukey`   Paper-faithful iterative radix-2 with an explicit
                             gather ("read reorder") and scatter ("write
                             reorder") per stage — the paper's *Initial*
                             design (Fig. 3/4).  ``variant="one_reorder"``
                             composes stage s's scatter with stage s+1's
                             gather into a single permutation — the paper's
                             *Single data copy* optimisation (Fig. 5).
- :func:`fft_stockham`       Autosort FFT: the permutation is absorbed into
                             the butterfly write pattern; no gathers at all,
                             every access is a contiguous block slice.  This
                             is the TPU-idiomatic end-point of the paper's
                             reorder-elimination ladder.
- :func:`fft_four_step`      Bailey four-step: FFT as DFT-matrix matmuls +
                             pointwise twiddle.  Moves ~all FLOPs to the MXU
                             (beyond-paper; on the Wormhole FPU==SFPU, on TPU
                             MXU >> VPU).
- :func:`fft_bluestein`      Chirp-z for arbitrary N (pads to a power of two).
- :func:`fft` / :func:`ifft` / :func:`rfft` / :func:`irfft`  dispatching API.

All functions transform the last axis and are jit/vmap/shard_map friendly
(pure, shape-static).  Twiddle tables are host-precomputed constants
(:mod:`repro.core.twiddle`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import complexmath as cm
from .complexmath import SplitComplex
from . import twiddle as tw


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _log2(n: int) -> int:
    return int(n).bit_length() - 1


# ---------------------------------------------------------------------------
# Naive dense DFT (oracle + MXU leaf)
# ---------------------------------------------------------------------------

def dft_naive(x: SplitComplex, *, inverse: bool = False,
              precision=None) -> SplitComplex:
    """X = W_N x as a complex matmul: (..., N) @ (N, N)."""
    n = x.shape[-1]
    w = tw.dft_matrix(n, inverse=inverse, dtype=x.dtype)
    # x (..., N) -> treat as row vectors: X[.., k] = sum_n x[.., n] W[n, k]
    dot = lambda p, q: jnp.matmul(p, q, precision=precision,
                                  preferred_element_type=x.dtype)
    re = dot(x.re, w.re) - dot(x.im, w.im)
    im = dot(x.re, w.im) + dot(x.im, w.re)
    out = SplitComplex(re, im)
    return cm.scale(out, 1.0 / n) if inverse else out


# ---------------------------------------------------------------------------
# Paper-faithful iterative radix-2 Cooley-Tukey
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _ct_stage_indices(n: int):
    """Host-side index plan for every radix-2 stage of a DIT FFT.

    Returns (rev, stages) where each stage is (idx0, idx1, tw_idx, inv_perm):
      idx0/idx1   natural-order indices of the butterfly pair elements
                  ("read reorder" gather),
      tw_idx      index into the size-n twiddle table for each pair,
      inv_perm    permutation scattering concat(out0, out1) back to natural
                  order ("write reorder").
    """
    rev = tw.bit_reverse_indices(n)
    half_n = n // 2
    stages = []
    for s in range(_log2(n)):
        half = 1 << s
        block = half << 1
        pair = np.arange(half_n, dtype=np.int64)
        idx0 = (pair // half) * block + (pair % half)
        idx1 = idx0 + half
        tw_idx = (pair % half) * (n // block)
        perm = np.concatenate([idx0, idx1])         # z -> natural position
        inv_perm = np.argsort(perm)                 # natural -> z position
        stages.append((idx0, idx1, tw_idx, inv_perm))
    return rev, tuple(stages)


@functools.lru_cache(maxsize=64)
def _ct_fused_indices(n: int):
    """Index plan for the *one-reorder-per-step* variant (paper Fig. 5).

    Instead of scattering back to natural order after every stage, the data
    stays in the stage's paired layout and a single composed permutation
    carries it to the *next* stage's layout.
    """
    rev, stages = _ct_stage_indices(n)
    g0 = np.concatenate([stages[0][0], stages[0][1]])
    initial = rev[g0]                                # x -> z_0 (incl. bitrev)
    hops = []
    for s in range(len(stages) - 1):
        _, _, _, inv_perm_s = stages[s]
        idx0n, idx1n, _, _ = stages[s + 1]
        g_next = np.concatenate([idx0n, idx1n])
        hops.append(inv_perm_s[g_next])              # z_s out -> z_{s+1}
    final = stages[-1][3]                            # z_last out -> natural
    tw_idx = tuple(st[2] for st in stages)
    return initial, tuple(hops), final, tw_idx


def _take(x: SplitComplex, idx) -> SplitComplex:
    idx = jnp.asarray(idx)
    return SplitComplex(jnp.take(x.re, idx, axis=-1),
                        jnp.take(x.im, idx, axis=-1))


def fft_cooley_tukey(x: SplitComplex, *, inverse: bool = False,
                     variant: str = "two_reorder") -> SplitComplex:
    """Iterative radix-2 Cooley-Tukey, faithful to the paper's structure.

    variant="two_reorder": gather pairs into contiguous LHS/RHS tiles, run
    the butterfly, scatter back to natural order — twice-per-step movement,
    the paper's *Initial* design (Table 1 row 2, Fig. 4).

    variant="one_reorder": stay in the paired layout and apply one composed
    permutation per stage — the paper's *Single data copy* (Table 1 row 6,
    Fig. 5).  Identical arithmetic, half the data movement.
    """
    n = x.shape[-1]
    assert _is_pow2(n), f"radix-2 CT needs power-of-two length, got {n}"
    if n == 1:
        return x
    w_table = tw.twiddles(n, inverse=inverse, dtype=x.dtype)
    half_n = n // 2

    if variant == "two_reorder":
        rev, stages = _ct_stage_indices(n)
        z = _take(x, rev)                         # initial bit-reversal read
        for (idx0, idx1, tw_idx, inv_perm) in stages:
            lhs = _take(z, idx0)                  # read reorder (gather)
            rhs = _take(z, idx1)
            w = _take(w_table, tw_idx)
            f = cm.mul(rhs, w)                    # f0/f1 of Listing 1.1
            out0 = cm.add(lhs, f)
            out1 = cm.sub(lhs, f)
            cat = SplitComplex(jnp.concatenate([out0.re, out1.re], axis=-1),
                               jnp.concatenate([out0.im, out1.im], axis=-1))
            z = _take(cat, inv_perm)              # write reorder (scatter)
    elif variant == "one_reorder":
        initial, hops, final, tw_idx = _ct_fused_indices(n)
        z = _take(x, initial)                     # single fused read reorder
        n_stages = len(tw_idx)
        for s in range(n_stages):
            lhs = SplitComplex(z.re[..., :half_n], z.im[..., :half_n])
            rhs = SplitComplex(z.re[..., half_n:], z.im[..., half_n:])
            w = _take(w_table, tw_idx[s])
            f = cm.mul(rhs, w)
            out0 = cm.add(lhs, f)
            out1 = cm.sub(lhs, f)
            cat = SplitComplex(jnp.concatenate([out0.re, out1.re], axis=-1),
                               jnp.concatenate([out0.im, out1.im], axis=-1))
            z = _take(cat, hops[s] if s < n_stages - 1 else final)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    return cm.scale(z, 1.0 / n) if inverse else z


# ---------------------------------------------------------------------------
# Stockham autosort
# ---------------------------------------------------------------------------

def stockham_stages(re, im, wr, wi, n: int, radices, *, inverse: bool = False):
    """Run every mixed-radix Stockham stage on (..., n) planes; returns (re, im).

    The workhorse shared by the jnp path (:func:`fft_stockham`), the 1-D
    Pallas kernel (:mod:`repro.kernels.fft_stockham`) and the fused 2-D
    kernel (:mod:`repro.kernels.fft2d_fused`) — inside a kernel the planes
    are VMEM-resident values, here they are ordinary arrays; the arithmetic
    is identical.

    Stage invariant: the length-n axis viewed as (n_cur, stride) is row-major
    contiguous, so the radix-r sub-sequences p, p+m, .. are r contiguous flat
    slices of constant length n/r, and the stride-broadcast packed twiddles
    (``wr``/``wi`` of shape (s4, 3, n//4), see
    :func:`repro.core.twiddle.packed_radix4_twiddles_np`) line up
    element-wise.  Writes interleave as (m, r, stride) — the autosort store.
    The radix-2 tail runs last (m == 1), where its twiddle is identically 1.
    """
    batch = re.shape[:-1]
    q = n // 4
    s4 = 0
    for radix in radices:
        if radix == 4:
            a0r, a1r = re[..., 0 * q:1 * q], re[..., 1 * q:2 * q]
            a2r, a3r = re[..., 2 * q:3 * q], re[..., 3 * q:4 * q]
            a0i, a1i = im[..., 0 * q:1 * q], im[..., 1 * q:2 * q]
            a2i, a3i = im[..., 2 * q:3 * q], im[..., 3 * q:4 * q]
            # radix-4 butterfly: y0..y3 with the +-1/+-i combination matrix
            e0r, e0i = a0r + a2r, a0i + a2i            # a0 + a2
            d0r, d0i = a0r - a2r, a0i - a2i            # a0 - a2
            e1r, e1i = a1r + a3r, a1i + a3i            # a1 + a3
            d1r, d1i = a1r - a3r, a1i - a3i            # a1 - a3
            y0r, y0i = e0r + e1r, e0i + e1i
            y2r, y2i = e0r - e1r, e0i - e1i
            if inverse:                                # +i (a1 - a3)
                y1r, y1i = d0r - d1i, d0i + d1r
                y3r, y3i = d0r + d1i, d0i - d1r
            else:                                      # -i (a1 - a3)
                y1r, y1i = d0r + d1i, d0i - d1r
                y3r, y3i = d0r - d1i, d0i + d1r
            w1r, w1i = wr[s4, 0], wi[s4, 0]
            w2r, w2i = wr[s4, 1], wi[s4, 1]
            w3r, w3i = wr[s4, 2], wi[s4, 2]
            b1r = y1r * w1r - y1i * w1i
            b1i = y1r * w1i + y1i * w1r
            b2r = y2r * w2r - y2i * w2i
            b2i = y2r * w2i + y2i * w2r
            b3r = y3r * w3r - y3i * w3i
            b3i = y3r * w3i + y3i * w3r
            stride = 4 ** s4                           # n_cur = n / 4^s4
            m = q // stride                            # m * stride == n // 4
            re = jnp.stack([y0r.reshape(*batch, m, stride),
                            b1r.reshape(*batch, m, stride),
                            b2r.reshape(*batch, m, stride),
                            b3r.reshape(*batch, m, stride)],
                           axis=-2).reshape(*batch, n)
            im = jnp.stack([y0i.reshape(*batch, m, stride),
                            b1i.reshape(*batch, m, stride),
                            b2i.reshape(*batch, m, stride),
                            b3i.reshape(*batch, m, stride)],
                           axis=-2).reshape(*batch, n)
            s4 += 1
        else:                                          # radix-2 tail, m == 1
            h = n // 2
            ar, ai = re[..., :h], im[..., :h]
            br, bi = re[..., h:], im[..., h:]
            re = jnp.stack([ar + br, ar - br], axis=-2).reshape(*batch, n)
            im = jnp.stack([ai + bi, ai - bi], axis=-2).reshape(*batch, n)
    return re, im


def fft_stockham(x: SplitComplex, *, inverse: bool = False) -> SplitComplex:
    """Mixed radix-4/radix-2 DIF Stockham: autosorting, gather-free.

    Radix-4 stages (radix-2 tail for odd log2 N) halve the stage count and
    inter-stage traffic versus the radix-2 version; the permutation the paper
    pays two explicit copies for stays absorbed into the write pattern, and
    every access remains a contiguous block slice.  Twiddles come from the
    packed (s4, 3, N/4) table shared with the Pallas kernels — one host
    build per (N, direction), no per-stage table requests.
    """
    n = x.shape[-1]
    assert _is_pow2(n), f"Stockham needs power-of-two length, got {n}"
    if n == 1:
        return x
    wr_np, wi_np = tw.packed_radix4_twiddles_np(n, inverse)
    wr = jnp.asarray(wr_np, x.dtype)
    wi = jnp.asarray(wi_np, x.dtype)
    re, im = stockham_stages(x.re, x.im, wr, wi, n,
                             tw.stockham_radices(n), inverse=inverse)
    out = SplitComplex(re, im)
    return cm.scale(out, 1.0 / n) if inverse else out


def stockham_radix2_stages(re, im, wr, wi, n: int):
    """Run every pure radix-2 Stockham stage on (..., n) planes.

    The radix-2 twin of :func:`stockham_stages`, shared by
    :func:`fft_stockham_radix2` and the kernel's ``radix=2`` path so the
    oracle arithmetic is maintained in exactly one place.  ``wr``/``wi`` is
    the packed (stages, n/2) table from
    :func:`repro.core.twiddle.packed_radix2_twiddles_np`.
    """
    batch = re.shape[:-1]
    h = n // 2
    for s in range(_log2(n)):
        stride = 1 << s
        m = n >> (s + 1)
        ar, ai = re[..., :h], im[..., :h]          # contiguous halves
        br, bi = re[..., h:], im[..., h:]
        sr, si = ar - br, ai - bi                  # a - b
        tr = sr * wr[s] - si * wi[s]               # (a-b) * w
        ti = sr * wi[s] + si * wr[s]
        re = jnp.stack([(ar + br).reshape(*batch, m, stride),
                        tr.reshape(*batch, m, stride)],
                       axis=-2).reshape(*batch, n)
        im = jnp.stack([(ai + bi).reshape(*batch, m, stride),
                        ti.reshape(*batch, m, stride)],
                       axis=-2).reshape(*batch, n)
    return re, im


def fft_stockham_radix2(x: SplitComplex, *,
                        inverse: bool = False) -> SplitComplex:
    """Pure radix-2 DIF Stockham — kept as the bit-identical-shape oracle for
    the radix-4 path and as an autotune candidate (``algo="stockham2"``).
    Uses the same packed-table scheme as the kernels (one host build per
    (N, direction)) instead of a fresh ``twiddles(n_cur)`` request per stage.
    """
    n = x.shape[-1]
    assert _is_pow2(n), f"Stockham needs power-of-two length, got {n}"
    if n == 1:
        return x
    wr_np, wi_np = tw.packed_radix2_twiddles_np(n, inverse)
    re, im = stockham_radix2_stages(x.re, x.im,
                                    jnp.asarray(wr_np, x.dtype),
                                    jnp.asarray(wi_np, x.dtype), n)
    out = SplitComplex(re, im)
    return cm.scale(out, 1.0 / n) if inverse else out


# ---------------------------------------------------------------------------
# Bailey four-step (MXU formulation)
# ---------------------------------------------------------------------------

def _best_split(n: int) -> int:
    """Pick n1 | n so that n1 and n/n1 are as close to sqrt(n) as possible,
    preferring MXU-aligned (multiple of 128) or lane-friendly factors."""
    best = 1
    for n1 in range(1, int(np.sqrt(n)) + 1):
        if n % n1 == 0:
            best = n1
    return best


def fft_four_step(x: SplitComplex, *, inverse: bool = False,
                  n1: Optional[int] = None, leaf: int = 256,
                  precision=None) -> SplitComplex:
    """Four-step FFT: N = n1*n2; column DFTs (matmul), twiddle, row DFTs
    (matmul), transpose.  All compute is complex matmul + one pointwise
    multiply, i.e. MXU-dominated.

    Factors larger than ``leaf`` recurse; leaves use the dense DFT matrix.
    """
    n = x.shape[-1]
    if n <= leaf:
        return dft_naive(x, inverse=inverse, precision=precision)
    if n1 is None:
        n1 = _best_split(n)
    if n1 == 1 or n1 == n:           # prime beyond leaf: fall back
        return fft_bluestein(x, inverse=inverse)
    n2 = n // n1

    a = SplitComplex(x.re.reshape(*x.shape[:-1], n1, n2),
                     x.im.reshape(*x.shape[:-1], n1, n2))

    # (1) DFT over the n1 axis: move it last, transform, move back.
    a_t = SplitComplex(jnp.swapaxes(a.re, -1, -2), jnp.swapaxes(a.im, -1, -2))
    b_t = _fft_len(a_t, n1, inverse=inverse, leaf=leaf, precision=precision)
    b = SplitComplex(jnp.swapaxes(b_t.re, -1, -2), jnp.swapaxes(b_t.im, -1, -2))
    if inverse:                       # recursion already divided by n1; undo
        b = cm.scale(b, float(n1))

    # (2) pointwise twiddle T[k1, n2]
    t = tw.fourstep_twiddle(n1, n2, inverse=inverse, dtype=x.dtype)
    c = cm.mul(b, SplitComplex(t.re, t.im))

    # (3) DFT over the n2 axis (already last)
    d = _fft_len(c, n2, inverse=inverse, leaf=leaf, precision=precision)
    if inverse:
        d = cm.scale(d, float(n2))

    # (4) output transpose: X[k2*n1 + k1] = D[k1, k2]
    out = SplitComplex(
        jnp.swapaxes(d.re, -1, -2).reshape(*x.shape[:-1], n),
        jnp.swapaxes(d.im, -1, -2).reshape(*x.shape[:-1], n))
    return cm.scale(out, 1.0 / n) if inverse else out


def _fft_len(x: SplitComplex, n: int, *, inverse: bool, leaf: int,
             precision) -> SplitComplex:
    if n <= leaf:
        return dft_naive(x, inverse=inverse, precision=precision)
    return fft_four_step(x, inverse=inverse, leaf=leaf, precision=precision)


# ---------------------------------------------------------------------------
# Bluestein chirp-z (arbitrary N)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _bluestein_tables_np(n: int, m: int, sign: float):
    k = np.arange(n, dtype=np.float64)
    # n^2 mod 2n keeps the angle argument small (precision guard)
    ang = sign * np.pi * ((k * k) % (2 * n)) / n
    a_c, a_s = np.cos(ang), np.sin(ang)
    b = np.zeros(m, dtype=np.complex128)
    chirp = np.exp(-1j * ang)                        # conj of a (sign folded)
    b[:n] = chirp
    b[m - n + 1:] = chirp[1:][::-1]
    bf = np.fft.fft(b)
    return a_c, a_s, bf.real, bf.imag


def fft_bluestein(x: SplitComplex, *, inverse: bool = False) -> SplitComplex:
    """Chirp-z transform: arbitrary-N DFT via one power-of-two convolution."""
    n = x.shape[-1]
    m = 1 << int(np.ceil(np.log2(2 * n - 1)))
    sign = 1.0 if inverse else -1.0
    a_c, a_s, bf_r, bf_i = _bluestein_tables_np(n, m, sign)
    a = SplitComplex(jnp.asarray(a_c, x.dtype), jnp.asarray(a_s, x.dtype))
    bf = SplitComplex(jnp.asarray(bf_r, x.dtype), jnp.asarray(bf_i, x.dtype))

    xa = cm.mul(x, a)
    pad = [(0, 0)] * (x.re.ndim - 1) + [(0, m - n)]
    xa_p = SplitComplex(jnp.pad(xa.re, pad), jnp.pad(xa.im, pad))
    xf = fft_stockham(xa_p)
    prod = cm.mul(xf, bf)
    conv = fft_stockham(prod, inverse=True)
    out = cm.mul(SplitComplex(conv.re[..., :n], conv.im[..., :n]), a)
    return cm.scale(out, 1.0 / n) if inverse else out


# ---------------------------------------------------------------------------
# Dispatch API
# ---------------------------------------------------------------------------

_ALGOS = {
    "naive": dft_naive,
    "cooley_tukey": functools.partial(fft_cooley_tukey, variant="two_reorder"),
    "cooley_tukey_fused": functools.partial(fft_cooley_tukey,
                                            variant="one_reorder"),
    "stockham": fft_stockham,
    "stockham2": fft_stockham_radix2,
    "four_step": fft_four_step,
    "bluestein": fft_bluestein,
}


def resolve_algo(n: int) -> str:
    """The single auto-dispatch size table: dense matmul for tiny N,
    four-step (MXU) for power-of-two N up to 2^20, Stockham beyond,
    Bluestein for non-pow2.  Shared by :func:`fft` and
    :meth:`repro.core.plan.FFTPlan.create` (previously two drifting copies).
    """
    if not _is_pow2(n):
        return "naive" if n <= 512 else "bluestein"
    if n <= 256:
        return "naive"
    if n <= (1 << 20):
        return "four_step"
    return "stockham"


def fft(x: SplitComplex, *, inverse: bool = False,
        algo: str = "auto") -> SplitComplex:
    """Forward/inverse DFT along the last axis.

    algo="auto" routes through the plan registry (:mod:`repro.core.plan`):
    the (shape, dtype, direction, backend="jnp") key resolves — and possibly
    autotunes — once, then every later call reuses the cached plan.  An
    explicit algo bypasses the registry and dispatches directly.
    """
    if algo == "auto":
        from . import plan as _plan            # deferred: plan imports fft1d
        return _plan.get_plan((x.shape[-1],), dtype=x.dtype,
                              inverse=inverse, backend="jnp")(x)
    return _ALGOS[algo](x, inverse=inverse)


def ifft(x: SplitComplex, *, algo: str = "auto") -> SplitComplex:
    return fft(x, inverse=True, algo=algo)


def fft_axis(x: SplitComplex, axis: int, *, inverse: bool = False,
             algo: str = "auto") -> SplitComplex:
    """Transform an arbitrary axis by moving it last and back."""
    re = jnp.moveaxis(x.re, axis, -1)
    im = jnp.moveaxis(x.im, axis, -1)
    y = fft(SplitComplex(re, im), inverse=inverse, algo=algo)
    return SplitComplex(jnp.moveaxis(y.re, -1, axis),
                        jnp.moveaxis(y.im, -1, axis))


# ---------------------------------------------------------------------------
# Real-input transforms
# ---------------------------------------------------------------------------

# the 1-D algos with a Pallas kernel path: _fft_inner dispatches these to
# repro.kernels.ops, and the plan registry demotes pallas rfft requests
# whose inner algo is not in this set (single source of truth — extend it
# when a new kernel lands)
KERNEL_INNER_ALGOS = ("stockham", "stockham2", "four_step")


def _fft_inner(z: SplitComplex, *, inverse: bool = False, algo: str,
               backend: str = "jnp", radix: int = 4,
               block_batch: int = 8) -> SplitComplex:
    """The inner complex transform of the real-input paths.  On
    ``backend="pallas"`` the kernel-backed algos (:data:`KERNEL_INNER_ALGOS`)
    dispatch to :mod:`repro.kernels.ops` (the plan registry only hands out
    pallas rfft plans whose inner algo has a kernel); everything else runs
    the jnp algorithms."""
    if backend == "pallas" and algo in KERNEL_INNER_ALGOS:
        from repro.kernels import ops as kops
        if algo == "four_step":
            return kops.fft_fourstep(z, inverse=inverse,
                                     block_batch=min(4, block_batch))
        return kops.fft_stockham(z, inverse=inverse,
                                 radix=2 if algo == "stockham2" else radix,
                                 block_batch=block_batch)
    return fft(z, inverse=inverse, algo=algo)


def rfft(x: jnp.ndarray, *, algo: str = "auto",
         backend: str = "jnp") -> SplitComplex:
    """Real-input FFT via the packed half-size complex transform.

    Packs even/odd samples into one complex sequence of length N/2 — halves
    both FLOPs and data movement versus a zero-imaginary full FFT
    (beyond-paper: the paper always carries a full imaginary plane).
    Returns the (..., N/2+1) half spectrum.

    ``algo="auto"`` routes through the plan registry under an rfft-kind
    key, so the inner complex algo (length N/2) is resolved once per
    (shape, dtype) and the decision is shared with every later call.
    ``backend="pallas"`` runs the inner transform on the Pallas kernels
    (demoting with a registry-visible reason when no kernel path exists).
    """
    if algo == "auto":
        from . import plan as _plan
        return _plan.get_plan((x.shape[-1],), dtype=x.dtype,
                              kind="rfft", backend=backend)(x)
    return _rfft_direct(x, algo=algo, backend=backend)


def _rfft_direct(x: jnp.ndarray, *, algo: str, backend: str = "jnp",
                 radix: int = 4, block_batch: int = 8) -> SplitComplex:
    """rfft body with an explicitly resolved inner algo (no registry)."""
    n = x.shape[-1]
    assert n % 2 == 0, "rfft requires even length"
    h = n // 2
    z = SplitComplex(x[..., 0::2], x[..., 1::2])
    zf = _fft_inner(z, algo=algo, backend=backend, radix=radix,
                    block_batch=block_batch)          # (..., h)
    # untangle: Xe[k] = (Z[k] + conj(Z[h-k]))/2 ; Xo[k] = -i(Z[k]-conj(Z[h-k]))/2
    idx = (-jnp.arange(h)) % h                        # Z[h-k] with wrap
    zr_f = jnp.take(zf.re, idx, axis=-1)
    zi_f = jnp.take(zf.im, idx, axis=-1)
    xe = SplitComplex((zf.re + zr_f) * 0.5, (zf.im - zi_f) * 0.5)
    xo = SplitComplex((zf.im + zi_f) * 0.5, (zr_f - zf.re) * 0.5)
    w = tw.twiddles(n, dtype=x.dtype)                 # e^{-2pi i k/N}
    wh = SplitComplex(w.re[:h], w.im[:h])
    xo_t = cm.mul(xo, wh)
    full = cm.add(xe, xo_t)                           # k = 0..h-1
    # k = h term: X[h] = Xe[0] - Xo[0]  (twiddle at k=h is -1)
    last = SplitComplex(xe.re[..., :1] - xo.re[..., :1],
                        xe.im[..., :1] - xo.im[..., :1])
    return SplitComplex(jnp.concatenate([full.re, last.re], axis=-1),
                        jnp.concatenate([full.im, last.im], axis=-1))


def irfft(xf: SplitComplex, n: Optional[int] = None, *,
          algo: str = "auto", backend: str = "jnp") -> jnp.ndarray:
    """Inverse real FFT from the (..., N/2+1) half spectrum.

    An explicit ``n`` truncates or zero-pads the spectrum to n//2+1 bins
    first (numpy semantics; odd ``n`` is served by the direct Hermitian
    extension — the registry's rfft keys cover even lengths only).
    ``algo="auto"`` routes through the registry's rfft-kind inverse key
    (the resolved algo is the full-length inner complex ifft)."""
    if n is None:
        n = 2 * (xf.shape[-1] - 1)
    xf = _fit_half_spectrum(xf, n)
    if n % 2 or algo != "auto":
        return _irfft_direct(xf, n, algo=algo, backend=backend)
    from . import plan as _plan
    return _plan.get_plan((n,), dtype=xf.dtype, inverse=True,
                          kind="rfft", backend=backend)(xf)


def _fit_half_spectrum(xf: SplitComplex, n: int) -> SplitComplex:
    """Truncate/zero-pad a half spectrum to the n/2+1 bins of length n."""
    h = n // 2 + 1
    bins = xf.shape[-1]
    if bins == h:
        return xf
    if bins > h:
        return SplitComplex(xf.re[..., :h], xf.im[..., :h])
    pad = [(0, 0)] * (xf.re.ndim - 1) + [(0, h - bins)]
    return SplitComplex(jnp.pad(xf.re, pad), jnp.pad(xf.im, pad))


def _irfft_direct(xf: SplitComplex, n: int, *, algo: str,
                  backend: str = "jnp", radix: int = 4,
                  block_batch: int = 8) -> jnp.ndarray:
    # Hermitian-extend then complex ifft; take the real plane.  For even n
    # the Nyquist bin (last) is excluded from the mirrored body; odd n has
    # no Nyquist bin, so the body is every bin past DC (numpy semantics).
    body_r = xf.re[..., 1:(n + 1) // 2]
    body_i = xf.im[..., 1:(n + 1) // 2]
    full = SplitComplex(
        jnp.concatenate([xf.re, body_r[..., ::-1]], axis=-1),
        jnp.concatenate([xf.im, -body_i[..., ::-1]], axis=-1))
    out = _fft_inner(full, inverse=True, algo=algo, backend=backend,
                     radix=radix, block_batch=block_batch)
    return out.re
