"""1-D FFT algorithms on split-complex data, batched over leading axes.

Algorithm inventory (paper §4 mapped to TPU, see DESIGN.md §2):

- :func:`dft_naive`          O(N^2) dense DFT matmul.  The test oracle and the
                             MXU leaf operator of the four-step path.
- :func:`fft_cooley_tukey`   Paper-faithful iterative radix-2 with an explicit
                             gather ("read reorder") and scatter ("write
                             reorder") per stage — the paper's *Initial*
                             design (Fig. 3/4).  ``variant="one_reorder"``
                             composes stage s's scatter with stage s+1's
                             gather into a single permutation — the paper's
                             *Single data copy* optimisation (Fig. 5).
- :func:`fft_stockham`       Autosort FFT: the permutation is absorbed into
                             the butterfly write pattern; no gathers at all,
                             every access is a contiguous block slice.  This
                             is the TPU-idiomatic end-point of the paper's
                             reorder-elimination ladder.
- :func:`fft_four_step`      Bailey four-step: FFT as DFT-matrix matmuls +
                             pointwise twiddle.  Moves ~all FLOPs to the MXU
                             (beyond-paper; on the Wormhole FPU==SFPU, on TPU
                             MXU >> VPU).
- :func:`fft_bluestein`      Chirp-z for arbitrary N (pads to a power of two).
- :func:`fft` / :func:`ifft` / :func:`rfft` / :func:`irfft`  dispatching API.

All functions transform the last axis and are jit/vmap/shard_map friendly
(pure, shape-static).  Twiddle tables are host-precomputed constants
(:mod:`repro.core.twiddle`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import complexmath as cm
from .complexmath import SplitComplex
from . import twiddle as tw


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _log2(n: int) -> int:
    return int(n).bit_length() - 1


# ---------------------------------------------------------------------------
# Naive dense DFT (oracle + MXU leaf)
# ---------------------------------------------------------------------------

def dft_naive(x: SplitComplex, *, inverse: bool = False,
              precision=None) -> SplitComplex:
    """X = W_N x as a complex matmul: (..., N) @ (N, N)."""
    n = x.shape[-1]
    w = tw.dft_matrix(n, inverse=inverse, dtype=x.dtype)
    # x (..., N) -> treat as row vectors: X[.., k] = sum_n x[.., n] W[n, k]
    dot = lambda p, q: jnp.matmul(p, q, precision=precision,
                                  preferred_element_type=x.dtype)
    re = dot(x.re, w.re) - dot(x.im, w.im)
    im = dot(x.re, w.im) + dot(x.im, w.re)
    out = SplitComplex(re, im)
    return cm.scale(out, 1.0 / n) if inverse else out


# ---------------------------------------------------------------------------
# Paper-faithful iterative radix-2 Cooley-Tukey
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _ct_stage_indices(n: int):
    """Host-side index plan for every radix-2 stage of a DIT FFT.

    Returns (rev, stages) where each stage is (idx0, idx1, tw_idx, inv_perm):
      idx0/idx1   natural-order indices of the butterfly pair elements
                  ("read reorder" gather),
      tw_idx      index into the size-n twiddle table for each pair,
      inv_perm    permutation scattering concat(out0, out1) back to natural
                  order ("write reorder").
    """
    rev = tw.bit_reverse_indices(n)
    half_n = n // 2
    stages = []
    for s in range(_log2(n)):
        half = 1 << s
        block = half << 1
        pair = np.arange(half_n, dtype=np.int64)
        idx0 = (pair // half) * block + (pair % half)
        idx1 = idx0 + half
        tw_idx = (pair % half) * (n // block)
        perm = np.concatenate([idx0, idx1])         # z -> natural position
        inv_perm = np.argsort(perm)                 # natural -> z position
        stages.append((idx0, idx1, tw_idx, inv_perm))
    return rev, tuple(stages)


@functools.lru_cache(maxsize=64)
def _ct_fused_indices(n: int):
    """Index plan for the *one-reorder-per-step* variant (paper Fig. 5).

    Instead of scattering back to natural order after every stage, the data
    stays in the stage's paired layout and a single composed permutation
    carries it to the *next* stage's layout.
    """
    rev, stages = _ct_stage_indices(n)
    g0 = np.concatenate([stages[0][0], stages[0][1]])
    initial = rev[g0]                                # x -> z_0 (incl. bitrev)
    hops = []
    for s in range(len(stages) - 1):
        _, _, _, inv_perm_s = stages[s]
        idx0n, idx1n, _, _ = stages[s + 1]
        g_next = np.concatenate([idx0n, idx1n])
        hops.append(inv_perm_s[g_next])              # z_s out -> z_{s+1}
    final = stages[-1][3]                            # z_last out -> natural
    tw_idx = tuple(st[2] for st in stages)
    return initial, tuple(hops), final, tw_idx


def _take(x: SplitComplex, idx) -> SplitComplex:
    idx = jnp.asarray(idx)
    return SplitComplex(jnp.take(x.re, idx, axis=-1),
                        jnp.take(x.im, idx, axis=-1))


def fft_cooley_tukey(x: SplitComplex, *, inverse: bool = False,
                     variant: str = "two_reorder") -> SplitComplex:
    """Iterative radix-2 Cooley-Tukey, faithful to the paper's structure.

    variant="two_reorder": gather pairs into contiguous LHS/RHS tiles, run
    the butterfly, scatter back to natural order — twice-per-step movement,
    the paper's *Initial* design (Table 1 row 2, Fig. 4).

    variant="one_reorder": stay in the paired layout and apply one composed
    permutation per stage — the paper's *Single data copy* (Table 1 row 6,
    Fig. 5).  Identical arithmetic, half the data movement.
    """
    n = x.shape[-1]
    assert _is_pow2(n), f"radix-2 CT needs power-of-two length, got {n}"
    if n == 1:
        return x
    w_table = tw.twiddles(n, inverse=inverse, dtype=x.dtype)
    half_n = n // 2

    if variant == "two_reorder":
        rev, stages = _ct_stage_indices(n)
        z = _take(x, rev)                         # initial bit-reversal read
        for (idx0, idx1, tw_idx, inv_perm) in stages:
            lhs = _take(z, idx0)                  # read reorder (gather)
            rhs = _take(z, idx1)
            w = _take(w_table, tw_idx)
            f = cm.mul(rhs, w)                    # f0/f1 of Listing 1.1
            out0 = cm.add(lhs, f)
            out1 = cm.sub(lhs, f)
            cat = SplitComplex(jnp.concatenate([out0.re, out1.re], axis=-1),
                               jnp.concatenate([out0.im, out1.im], axis=-1))
            z = _take(cat, inv_perm)              # write reorder (scatter)
    elif variant == "one_reorder":
        initial, hops, final, tw_idx = _ct_fused_indices(n)
        z = _take(x, initial)                     # single fused read reorder
        n_stages = len(tw_idx)
        for s in range(n_stages):
            lhs = SplitComplex(z.re[..., :half_n], z.im[..., :half_n])
            rhs = SplitComplex(z.re[..., half_n:], z.im[..., half_n:])
            w = _take(w_table, tw_idx[s])
            f = cm.mul(rhs, w)
            out0 = cm.add(lhs, f)
            out1 = cm.sub(lhs, f)
            cat = SplitComplex(jnp.concatenate([out0.re, out1.re], axis=-1),
                               jnp.concatenate([out0.im, out1.im], axis=-1))
            z = _take(cat, hops[s] if s < n_stages - 1 else final)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    return cm.scale(z, 1.0 / n) if inverse else z


# ---------------------------------------------------------------------------
# Stockham autosort
# ---------------------------------------------------------------------------

def fft_stockham(x: SplitComplex, *, inverse: bool = False) -> SplitComplex:
    """Radix-2 DIF Stockham: autosorting, gather-free, contiguous accesses.

    Stage invariant: view the length-N axis as (p, q) of shape
    (n_cur, stride); butterflies combine the contiguous halves p < m and
    p >= m (m = n_cur/2) and write interleaved — the permutation the paper
    pays two explicit copies for is absorbed into the write pattern, and
    (unlike the paper's fused variant, §4) every access stays contiguous.
    """
    n = x.shape[-1]
    assert _is_pow2(n), f"Stockham needs power-of-two length, got {n}"
    if n == 1:
        return x
    batch = x.shape[:-1]
    re, im = x.re, x.im
    n_cur, stride = n, 1
    while n_cur > 1:
        m = n_cur // 2
        re2 = re.reshape(*batch, n_cur, stride)
        im2 = im.reshape(*batch, n_cur, stride)
        ar, ai = re2[..., :m, :], im2[..., :m, :]
        br, bi = re2[..., m:, :], im2[..., m:, :]
        w = tw.twiddles(n_cur, inverse=inverse, dtype=x.dtype)
        wr = w.re[:m, None]
        wi = w.im[:m, None]
        sr, si = ar - br, ai - bi                  # a - b
        tr = sr * wr - si * wi                     # (a-b) * w
        ti = sr * wi + si * wr
        re = jnp.stack([ar + br, tr], axis=-2).reshape(*batch, n)
        im = jnp.stack([ai + bi, ti], axis=-2).reshape(*batch, n)
        n_cur, stride = m, stride * 2
    out = SplitComplex(re, im)
    return cm.scale(out, 1.0 / n) if inverse else out


# ---------------------------------------------------------------------------
# Bailey four-step (MXU formulation)
# ---------------------------------------------------------------------------

def _best_split(n: int) -> int:
    """Pick n1 | n so that n1 and n/n1 are as close to sqrt(n) as possible,
    preferring MXU-aligned (multiple of 128) or lane-friendly factors."""
    best = 1
    for n1 in range(1, int(np.sqrt(n)) + 1):
        if n % n1 == 0:
            best = n1
    return best


def fft_four_step(x: SplitComplex, *, inverse: bool = False,
                  n1: Optional[int] = None, leaf: int = 256,
                  precision=None) -> SplitComplex:
    """Four-step FFT: N = n1*n2; column DFTs (matmul), twiddle, row DFTs
    (matmul), transpose.  All compute is complex matmul + one pointwise
    multiply, i.e. MXU-dominated.

    Factors larger than ``leaf`` recurse; leaves use the dense DFT matrix.
    """
    n = x.shape[-1]
    if n <= leaf:
        return dft_naive(x, inverse=inverse, precision=precision)
    if n1 is None:
        n1 = _best_split(n)
    if n1 == 1 or n1 == n:           # prime beyond leaf: fall back
        return fft_bluestein(x, inverse=inverse)
    n2 = n // n1

    a = SplitComplex(x.re.reshape(*x.shape[:-1], n1, n2),
                     x.im.reshape(*x.shape[:-1], n1, n2))

    # (1) DFT over the n1 axis: move it last, transform, move back.
    a_t = SplitComplex(jnp.swapaxes(a.re, -1, -2), jnp.swapaxes(a.im, -1, -2))
    b_t = _fft_len(a_t, n1, inverse=inverse, leaf=leaf, precision=precision)
    b = SplitComplex(jnp.swapaxes(b_t.re, -1, -2), jnp.swapaxes(b_t.im, -1, -2))
    if inverse:                       # recursion already divided by n1; undo
        b = cm.scale(b, float(n1))

    # (2) pointwise twiddle T[k1, n2]
    t = tw.fourstep_twiddle(n1, n2, inverse=inverse, dtype=x.dtype)
    c = cm.mul(b, SplitComplex(t.re, t.im))

    # (3) DFT over the n2 axis (already last)
    d = _fft_len(c, n2, inverse=inverse, leaf=leaf, precision=precision)
    if inverse:
        d = cm.scale(d, float(n2))

    # (4) output transpose: X[k2*n1 + k1] = D[k1, k2]
    out = SplitComplex(
        jnp.swapaxes(d.re, -1, -2).reshape(*x.shape[:-1], n),
        jnp.swapaxes(d.im, -1, -2).reshape(*x.shape[:-1], n))
    return cm.scale(out, 1.0 / n) if inverse else out


def _fft_len(x: SplitComplex, n: int, *, inverse: bool, leaf: int,
             precision) -> SplitComplex:
    if n <= leaf:
        return dft_naive(x, inverse=inverse, precision=precision)
    return fft_four_step(x, inverse=inverse, leaf=leaf, precision=precision)


# ---------------------------------------------------------------------------
# Bluestein chirp-z (arbitrary N)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _bluestein_tables_np(n: int, m: int, sign: float):
    k = np.arange(n, dtype=np.float64)
    # n^2 mod 2n keeps the angle argument small (precision guard)
    ang = sign * np.pi * ((k * k) % (2 * n)) / n
    a_c, a_s = np.cos(ang), np.sin(ang)
    b = np.zeros(m, dtype=np.complex128)
    chirp = np.exp(-1j * ang)                        # conj of a (sign folded)
    b[:n] = chirp
    b[m - n + 1:] = chirp[1:][::-1]
    bf = np.fft.fft(b)
    return a_c, a_s, bf.real, bf.imag


def fft_bluestein(x: SplitComplex, *, inverse: bool = False) -> SplitComplex:
    """Chirp-z transform: arbitrary-N DFT via one power-of-two convolution."""
    n = x.shape[-1]
    m = 1 << int(np.ceil(np.log2(2 * n - 1)))
    sign = 1.0 if inverse else -1.0
    a_c, a_s, bf_r, bf_i = _bluestein_tables_np(n, m, sign)
    a = SplitComplex(jnp.asarray(a_c, x.dtype), jnp.asarray(a_s, x.dtype))
    bf = SplitComplex(jnp.asarray(bf_r, x.dtype), jnp.asarray(bf_i, x.dtype))

    xa = cm.mul(x, a)
    pad = [(0, 0)] * (x.re.ndim - 1) + [(0, m - n)]
    xa_p = SplitComplex(jnp.pad(xa.re, pad), jnp.pad(xa.im, pad))
    xf = fft_stockham(xa_p)
    prod = cm.mul(xf, bf)
    conv = fft_stockham(prod, inverse=True)
    out = cm.mul(SplitComplex(conv.re[..., :n], conv.im[..., :n]), a)
    return cm.scale(out, 1.0 / n) if inverse else out


# ---------------------------------------------------------------------------
# Dispatch API
# ---------------------------------------------------------------------------

_ALGOS = {
    "naive": dft_naive,
    "cooley_tukey": functools.partial(fft_cooley_tukey, variant="two_reorder"),
    "cooley_tukey_fused": functools.partial(fft_cooley_tukey,
                                            variant="one_reorder"),
    "stockham": fft_stockham,
    "four_step": fft_four_step,
    "bluestein": fft_bluestein,
}


def fft(x: SplitComplex, *, inverse: bool = False,
        algo: str = "auto") -> SplitComplex:
    """Forward/inverse DFT along the last axis.

    algo="auto" picks: dense matmul for tiny N, four-step (MXU) for
    power-of-two N up to 2^20, Stockham beyond, Bluestein for non-pow2.
    """
    n = x.shape[-1]
    if algo == "auto":
        if not _is_pow2(n):
            algo = "naive" if n <= 512 else "bluestein"
        elif n <= 256:
            algo = "naive"
        elif n <= (1 << 20):
            algo = "four_step"
        else:
            algo = "stockham"
    return _ALGOS[algo](x, inverse=inverse)


def ifft(x: SplitComplex, *, algo: str = "auto") -> SplitComplex:
    return fft(x, inverse=True, algo=algo)


def fft_axis(x: SplitComplex, axis: int, *, inverse: bool = False,
             algo: str = "auto") -> SplitComplex:
    """Transform an arbitrary axis by moving it last and back."""
    re = jnp.moveaxis(x.re, axis, -1)
    im = jnp.moveaxis(x.im, axis, -1)
    y = fft(SplitComplex(re, im), inverse=inverse, algo=algo)
    return SplitComplex(jnp.moveaxis(y.re, -1, axis),
                        jnp.moveaxis(y.im, -1, axis))


# ---------------------------------------------------------------------------
# Real-input transforms
# ---------------------------------------------------------------------------

def rfft(x: jnp.ndarray, *, algo: str = "auto") -> SplitComplex:
    """Real-input FFT via the packed half-size complex transform.

    Packs even/odd samples into one complex sequence of length N/2 — halves
    both FLOPs and data movement versus a zero-imaginary full FFT
    (beyond-paper: the paper always carries a full imaginary plane).
    Returns the (..., N/2+1) half spectrum.
    """
    n = x.shape[-1]
    assert n % 2 == 0, "rfft requires even length"
    h = n // 2
    z = SplitComplex(x[..., 0::2], x[..., 1::2])
    zf = fft(z, algo=algo)                            # (..., h)
    # untangle: Xe[k] = (Z[k] + conj(Z[h-k]))/2 ; Xo[k] = -i(Z[k]-conj(Z[h-k]))/2
    idx = (-jnp.arange(h)) % h                        # Z[h-k] with wrap
    zr_f = jnp.take(zf.re, idx, axis=-1)
    zi_f = jnp.take(zf.im, idx, axis=-1)
    xe = SplitComplex((zf.re + zr_f) * 0.5, (zf.im - zi_f) * 0.5)
    xo = SplitComplex((zf.im + zi_f) * 0.5, (zr_f - zf.re) * 0.5)
    w = tw.twiddles(n, dtype=x.dtype)                 # e^{-2pi i k/N}
    wh = SplitComplex(w.re[:h], w.im[:h])
    xo_t = cm.mul(xo, wh)
    full = cm.add(xe, xo_t)                           # k = 0..h-1
    # k = h term: X[h] = Xe[0] - Xo[0]  (twiddle at k=h is -1)
    last = SplitComplex(xe.re[..., :1] - xo.re[..., :1],
                        xe.im[..., :1] - xo.im[..., :1])
    return SplitComplex(jnp.concatenate([full.re, last.re], axis=-1),
                        jnp.concatenate([full.im, last.im], axis=-1))


def irfft(xf: SplitComplex, n: Optional[int] = None, *,
          algo: str = "auto") -> jnp.ndarray:
    """Inverse real FFT from the (..., N/2+1) half spectrum."""
    if n is None:
        n = 2 * (xf.shape[-1] - 1)
    # Hermitian-extend then complex ifft; take the real plane.
    body_r = xf.re[..., 1:-1]
    body_i = xf.im[..., 1:-1]
    full = SplitComplex(
        jnp.concatenate([xf.re, body_r[..., ::-1]], axis=-1),
        jnp.concatenate([xf.im, -body_i[..., ::-1]], axis=-1))
    out = fft(full, inverse=True, algo=algo)
    return out.re
