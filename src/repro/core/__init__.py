"""repro.core — the paper's contribution as composable JAX modules.

Public API:
  SplitComplex, from_complex, to_complex, from_real
  fft, ifft, rfft, irfft, fft2, fft3, rfft2, irfft2
  fft_conv, circular_conv, fourier_mix
  plan_fft, plan_ifft, FFTPlan
"""
from .complexmath import (SplitComplex, from_complex, to_complex, from_real,
                          add, sub, mul, conj, scale)
from .fft1d import (fft, ifft, rfft, irfft, fft_axis, dft_naive,
                    fft_cooley_tukey, fft_stockham, fft_stockham_radix2,
                    fft_four_step, fft_bluestein, resolve_algo)
from .fft2d import fft2, fft3, rfft2, irfft2
from .fftconv import fft_conv, circular_conv
from .spectral import fourier_mix
from .plan import (FFTPlan, plan_fft, plan_ifft, plan_fft2, plan_ifft2,
                   get_plan, clear_plan_cache, autotune_count,
                   plan_cache_size, save_wisdom, load_wisdom, warm,
                   WarmResult)
