"""FFTW-style plans: choose an algorithm/kernel once, apply many times.

A :class:`FFTPlan` captures (length, dtype, direction, backend) and exposes a
jit-friendly ``__call__``.  ``backend="jnp"`` uses the pure-JAX algorithms in
:mod:`repro.core.fft1d`; ``backend="pallas"`` dispatches to the TPU kernels in
:mod:`repro.kernels.ops` (interpret-mode on CPU).  Mirrors how the paper bakes
per-size decisions (chunking, reorder plan, twiddles) at initialisation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .complexmath import SplitComplex
from . import fft1d


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    n: int
    inverse: bool = False
    algo: str = "auto"            # resolved at construction
    backend: str = "jnp"          # "jnp" | "pallas"

    @staticmethod
    def create(n: int, *, inverse: bool = False, algo: str = "auto",
               backend: str = "jnp") -> "FFTPlan":
        if algo == "auto":
            if not _is_pow2(n):
                algo = "naive" if n <= 512 else "bluestein"
            elif n <= 256:
                algo = "naive"
            elif n <= (1 << 20):
                algo = "four_step"
            else:
                algo = "stockham"
        if backend == "pallas" and algo in ("naive", "bluestein"):
            backend = "jnp"       # no kernel for these paths
        return FFTPlan(n=n, inverse=inverse, algo=algo, backend=backend)

    def __call__(self, x: SplitComplex) -> SplitComplex:
        assert x.shape[-1] == self.n, (x.shape, self.n)
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            if self.algo == "four_step":
                return kops.fft_fourstep(x, inverse=self.inverse)
            return kops.fft_stockham(x, inverse=self.inverse)
        return fft1d.fft(x, inverse=self.inverse, algo=self.algo)


def plan_fft(n: int, **kw) -> FFTPlan:
    return FFTPlan.create(n, **kw)


def plan_ifft(n: int, **kw) -> FFTPlan:
    return FFTPlan.create(n, inverse=True, **kw)
