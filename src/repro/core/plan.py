"""FFTW-style plan registry: resolve/tune once, apply many times.

A :class:`FFTPlan` captures a transform (shape, dtype, direction, backend)
plus the resolved execution config (algo, radix, block_batch) and exposes a
jit-friendly ``__call__``.  Plans are interned in a process-wide registry —
two requests with the same (shape, dtype, direction, backend) return the
*same object* — so auto-dispatch decisions (and autotune measurements) are
paid once per key, mirroring how the paper bakes per-size decisions
(chunking, reorder plan, twiddles) at initialisation and how FFTW separates
``plan`` from ``execute``.

``backend="jnp"`` uses the pure-JAX algorithms in :mod:`repro.core.fft1d`;
``backend="pallas"`` dispatches to the TPU kernels in
:mod:`repro.kernels.ops` (interpret-mode on CPU).  1-D shapes are ``(n,)``;
2-D shapes ``(h, w)`` cover :func:`repro.core.fft2d.fft2`, where the pallas
backend runs the GEMM-formulated fused kernel
(:mod:`repro.kernels.fft2d_gemm`, ``algo="fused"``; the previous
Stockham-stage kernel stays reachable as the explicit-algo oracle
``algo="fused_stockham"``); 3-D shapes ``(d, h, w)`` cover
:func:`repro.core.fft2d.fft3`, where the pallas backend runs the fused
pencil-in-VMEM kernel (:mod:`repro.kernels.fft3d_fused`).

GEMM-fused plans additionally carry a ``variant``: ``"plain"`` casts the
four-step operand tables straight to the working dtype, while
``"compensated"`` (the auto default for sub-fp32 dtypes) stores them as
split hi/lo pairs and accumulates in fp32 — the precision-compensated
bf16 path that halves the VMEM working set (the 1024x1024 capacity
question) without paying the full bf16 arithmetic error.

``tune=True`` runs an opt-in FFTW-style measuring autotuner: every candidate
(algo, radix, block_batch) config is timed on synthetic data and the winner
is recorded in the registry, so the measurement also happens at most once
per key.  ``prune="model"`` first ranks the candidates with the analytical
Wormhole/Tensix cost model (:func:`repro.tt.trace.predict_cost`) and only
measures the top-k — cheaper tuning, same measured winner when the model
ranks sanely (the heuristic default is always kept in the measured set).

Plans with ``kind="rfft"`` cover the real-input transforms: the key
includes the kind, so ``rfft``/``irfft``/``rfft2``/``irfft2`` resolve their
inner complex algo once per shape instead of re-deriving it per call.
Real-input plans have a kernel path too: 2-D rfft keys on
``backend="pallas"`` resolve to the fused real-input kernel
(:mod:`repro.kernels.rfft2d_fused`, ``algo="fused"``), 1-D rfft keys run
their inner complex transform on the 1-D kernels, and shapes with no
kernel path demote to jnp with the reason recorded on
``FFTPlan.demote_reason``.  rfft-kind autotuning measures the
(algo, backend, block_batch) grid — the jnp schedule is always a
candidate, so tuning can cross backends.

Tuned winners persist across processes FFTW-"wisdom" style:
:func:`save_wisdom` / :func:`load_wisdom` round-trip the registry's tuned
(algo, radix, block_batch, backend, variant) entries as versioned,
key-hashed JSON.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .complexmath import SplitComplex
from . import fft1d
from .fft1d import KERNEL_INNER_ALGOS, resolve_algo


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


PlanKey = Tuple[Tuple[int, ...], str, bool, str, str]

_PLAN_CACHE: Dict[PlanKey, "FFTPlan"] = {}      # algo="auto" plans
_OVERRIDE_CACHE: Dict[tuple, "FFTPlan"] = {}    # (key, algo, radix) overrides
_AUTOTUNE_RUNS: Dict[tuple, int] = {}

# conv-kind plans fuse rfft -> pointwise multiply -> irfft over one padded
# FFT length; the causal/circular mode is part of the kind (and therefore
# the key), because the two modes pad — and therefore cache — different
# filter spectra at the same length
CONV_KINDS = ("conv_causal", "conv_circular")
PLAN_KINDS = ("c2c", "rfft") + CONV_KINDS


def _plan_key(shape, dtype, inverse, backend, kind="c2c") -> PlanKey:
    return (tuple(int(d) for d in shape), str(jnp.dtype(dtype)),
            bool(inverse), backend, kind)


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    shape: Tuple[int, ...]            # transform shape: (n,) or (h, w)
    dtype: str = "float32"
    inverse: bool = False
    algo: str = "auto"                # resolved at construction, never "auto"
    backend: str = "jnp"              # "jnp" | "pallas"
    radix: int = 4                    # Stockham radix (4 = mixed 4/2, 2 = oracle)
    block_batch: int = 8              # pallas batch tile
    kind: str = "c2c"                 # "c2c" | "rfft" (real input/output)
    variant: str = "plain"            # GEMM kernels: "plain" | "compensated"
    tuned: bool = False
    tune_report: Optional[dict] = None   # {candidate label: us} when tuned
    demote_reason: Optional[str] = None  # why a pallas request fell to jnp

    # -- introspection -------------------------------------------------------

    @property
    def n(self) -> int:
        return self.shape[-1]

    @property
    def ndim(self) -> int:
        return len(self.shape)

    # -- construction --------------------------------------------------------

    @staticmethod
    def create(n: int, *, inverse: bool = False, algo: str = "auto",
               backend: str = "jnp", dtype=jnp.float32,
               tune: bool = False) -> "FFTPlan":
        """1-D plan through the registry (kept as the historical entry point)."""
        return get_plan((n,), dtype=dtype, inverse=inverse, algo=algo,
                        backend=backend, tune=tune)

    # -- execution -----------------------------------------------------------

    def __call__(self, x, *args) -> SplitComplex:
        """Execute through the guarded executor
        (:mod:`repro.resilience.executor`): eager kernel executions are
        integrity-checked and fall back to the jnp schedule on failure
        (repeated failures open the key's circuit breaker and demote the
        registry entry with ``demote_reason="runtime_circuit_open"``);
        traced calls — and disabled resilience — take the raw path
        unchanged.  conv-kind plans take the filter half spectrum as a
        second operand: ``plan(x, kf)``."""
        from repro.resilience import executor as _rexec
        return _rexec.execute(self, x, *args)

    def _execute(self, x, *args) -> SplitComplex:
        """The raw execution path (no guards, no fallback)."""
        if self.kind in CONV_KINDS:
            return self._call_conv(x, *args)
        assert not args, "only conv-kind plans take extra operands"
        if self.kind == "rfft":
            return self._call_rfft(x)
        assert x.shape[-self.ndim:] == self.shape, (x.shape, self.shape)
        if self.ndim == 2:
            from . import fft2d
            return fft2d._fft2_direct(x, inverse=self.inverse, algo=self.algo,
                                      backend=self.backend,
                                      block_batch=self.block_batch,
                                      variant=self.variant)
        if self.ndim == 3:
            from . import fft2d
            return fft2d._fft3_direct(x, inverse=self.inverse, algo=self.algo,
                                      backend=self.backend,
                                      block_batch=self.block_batch,
                                      variant=self.variant)
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            if self.algo == "four_step":
                return kops.fft_fourstep(x, inverse=self.inverse,
                                         block_batch=self.block_batch)
            return kops.fft_stockham(x, inverse=self.inverse,
                                     radix=self.radix,
                                     block_batch=self.block_batch)
        algo = "stockham2" if (self.algo == "stockham" and self.radix == 2) \
            else self.algo
        return fft1d.fft(x, inverse=self.inverse, algo=algo)

    def _call_rfft(self, x):
        """Execute a real-input plan.  On ``backend="jnp"`` the resolved
        ``algo`` is the *inner* complex transform of the rfft/irfft axis,
        passed explicitly so the dispatch decision baked into this plan is
        never re-derived, and the 2-D column pass is a c2c transform routed
        through its own registry key (``algo="auto"``), FFTW-style plan
        composition.  On ``backend="pallas"`` 2-D plans run the fused
        real-input kernel (``algo="fused"``) and 1-D plans run their inner
        complex transform on the 1-D kernels.
        """
        if self.ndim == 1:
            kw = dict(algo=self.algo, backend=self.backend,
                      radix=self.radix, block_batch=self.block_batch)
            if self.inverse:            # input: (..., n/2+1) half spectrum
                assert x.shape[-1] == self.n // 2 + 1, (x.shape, self.shape)
                return fft1d._irfft_direct(x, self.n, **kw)
            assert x.shape[-1] == self.n, (x.shape, self.shape)
            return fft1d._rfft_direct(x, **kw)
        h, w = self.shape
        from . import fft2d
        if self.backend == "pallas" and self.algo == "fused":
            from repro.kernels import ops as kops
            if self.inverse:
                assert x.shape[-2:] == (h, w // 2 + 1), (x.shape, self.shape)
                return kops.irfft2d_fused(x, block_batch=self.block_batch)
            assert x.shape[-2:] == (h, w), (x.shape, self.shape)
            return kops.rfft2d_fused(x, block_batch=self.block_batch)
        # jnp plans run the row-column schedule with jnp passes; a pallas
        # plan with an explicit non-fused algo runs the SAME schedule with
        # kernel 1-D passes — identical to the direct rfft2()/irfft2()
        # path for the same (algo, backend) request
        col = self.algo if self.backend == "pallas" else "auto"
        if self.inverse:
            assert x.shape[-2:] == (h, w // 2 + 1), (x.shape, self.shape)
            return fft2d._irfft2_direct(x, row_algo=self.algo, col_algo=col,
                                        backend=self.backend)
        assert x.shape[-2:] == (h, w), (x.shape, self.shape)
        return fft2d._rfft2_direct(x, row_algo=self.algo, col_algo=col,
                                   backend=self.backend)

    def _call_conv(self, x, kf):
        """Execute a conv plan: circularly convolve real signals x (..., m)
        with the filter half spectra kf (..., m//2+1) over the plan's
        padded FFT length m.  ``algo="fused"`` runs the VMEM-resident
        pallas kernel (:mod:`repro.kernels.fftconv_fused`) — spectrum
        never touches HBM; ``algo="unfused"`` is the registry-composed
        rfft -> mul -> irfft baseline (the demotion and runtime-fallback
        target).  Causal padding/truncation happens upstream in
        :func:`repro.core.fftconv.fft_conv`."""
        m = self.n
        assert x.shape[-1] == m, (x.shape, self.shape)
        if self.algo == "fused":
            from repro.kernels import ops as kops
            return kops.fftconv_fused(x, kf, block_batch=self.block_batch)
        from . import complexmath as cm
        xf = fft1d.rfft(x, backend=self.backend)
        return fft1d.irfft(cm.mul(xf, kf), m, backend=self.backend)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def get_plan(shape, *, dtype=jnp.float32, inverse: bool = False,
             algo: str = "auto", backend: str = "jnp", kind: str = "c2c",
             variant: str = "auto",
             tune: bool = False, tune_batch: int = 8,
             prune: str = "none", prune_k: Optional[int] = None,
             model_arch: str = "tpu_v5e",
             measure_timeout_s: Optional[float] = "config") -> FFTPlan:
    """The registry entry point: return the interned plan for this key,
    resolving (or autotuning) it on first request.

    Keys are (shape, dtype, direction, backend-after-demotion, kind);
    requests with an explicit ``algo`` are interned separately under
    (key, algo) and never replace — or inherit — the auto-resolved plan.
    The autotuner runs at most once per cache entry; explicit-algo tuning
    measures only that algo's radix/block_batch variants.  ``tune_batch``
    sets the synthetic batch the tuner measures on — pass your workload's
    batch, since the best (algo, radix, block_batch) config is
    batch-dependent.

    ``kind="rfft"`` interns a real-input plan: ``shape`` is the *real*
    shape.  On ``backend="jnp"`` the resolved algo is the inner complex
    transform of the rfft/irfft axis (length n/2 forward, n inverse); on
    ``backend="pallas"`` 2-D shapes resolve to the fused real-input kernel
    (``algo="fused"``) and 1-D shapes run the inner transform on the 1-D
    kernels.  Shapes with no kernel path demote to jnp and record why in
    ``FFTPlan.demote_reason``.

    ``prune="model"`` makes the autotuner rank candidates with the
    :mod:`repro.tt.trace` cost model on ``model_arch`` and measure only the
    ``prune_k`` most promising (default: half, min 2 — the heuristic
    default config is always measured).

    ``measure_timeout_s`` is the per-candidate measurement watchdog (one
    retry, then the candidate is excluded — a hung config cannot hang
    tuning); the default defers to the resilience config
    (``resilience.config.get("measure_timeout_s")``), ``None`` disables it.

    ``variant`` selects the GEMM kernels' precision path: ``"auto"``
    resolves to ``"compensated"`` for sub-fp32 GEMM-fused plans (split
    hi/lo operand tables + fp32 accumulation) and ``"plain"`` otherwise;
    an explicit variant is interned separately like an explicit algo.
    """
    shape = tuple(int(d) for d in shape)
    assert len(shape) in (1, 2, 3), f"1-D/2-D/3-D plans only, got {shape}"
    assert kind in PLAN_KINDS, f"kind must be one of {PLAN_KINDS}, got {kind}"
    assert prune in ("none", "model"), prune
    assert variant in ("auto", "plain", "compensated"), variant
    if kind == "rfft" and len(shape) == 3:
        raise ValueError("rfft plans are 1-D or 2-D; 3-D real transforms "
                         "compose rfft2 with a c2c depth pass")
    if kind in CONV_KINDS:
        if len(shape) != 1:
            raise ValueError("conv plans are 1-D (keyed on the padded FFT "
                             f"length), got {shape}")
        if inverse:
            raise ValueError("conv plans have no inverse direction (the "
                             "irfft is fused inside the plan)")
    # the kernels need power-of-two tile dims of at least 2 (a unit dim
    # would underflow the tile asserts) — anything else demotes to jnp
    kernel_ok = all(_is_pow2(d) and d >= 2 for d in shape)
    radix = 4
    fixed_radix = False
    demote = None

    if kind in CONV_KINDS:
        m = shape[0]
        if backend == "pallas" and not (_is_pow2(m) and m >= 4):
            demote = ("fused conv kernel needs a power-of-two FFT length "
                      f">= 4, got {m}")
            if algo == "fused":
                algo = "auto"         # fused demotes with its backend
            backend = "jnp"
        if algo == "auto":
            resolved = "fused" if backend == "pallas" else "unfused"
        else:
            resolved = algo
        if backend == "jnp" and resolved == "fused":
            raise ValueError('algo="fused" requires backend="pallas" (the '
                             'fused conv kernel has no jnp equivalent)')
        if resolved not in ("fused", "unfused"):
            raise ValueError(f'algo={resolved!r} is not a conv plan algo; '
                             'use "fused", "unfused" or "auto"')
        block_batch = 1 if resolved == "fused" else 8
    elif kind == "rfft":
        n = shape[-1]
        if n % 2:
            raise ValueError(f"rfft plans need an even last dim, "
                             f"got {shape}")
        inner = n if inverse else n // 2
        if len(shape) == 1:
            # 1-D: the pack/untangle stays jnp; the inner complex
            # transform runs on the 1-D kernels when one exists
            resolved = resolve_algo(inner) if algo == "auto" else algo
            if backend == "pallas" and (
                    resolved not in KERNEL_INNER_ALGOS
                    or not (_is_pow2(inner) and inner >= 2)):
                demote = (f"inner algo {resolved!r} at inner length "
                          f"{inner} has no kernel path")
                backend = "jnp"
            block_batch = 8
        else:
            # 2-D: the fused real-input kernel (rfft2d_fused)
            if backend == "pallas" and not kernel_ok:
                demote = ("fused rfft kernel needs power-of-two dims "
                          f">= 2, got {shape}")
                if algo == "fused":
                    algo = "auto"
                backend = "jnp"
            if algo == "auto":
                resolved = "fused" if backend == "pallas" \
                    else resolve_algo(inner)
            else:
                resolved = algo
            if backend == "pallas" and resolved != "fused" and (
                    resolved not in KERNEL_INNER_ALGOS
                    or not (_is_pow2(inner) and inner >= 2)):
                # an explicit non-fused algo runs the row-column schedule
                # with kernel 1-D passes (same as the direct rfft2 path);
                # algos outside _fft_inner's kernel set demote visibly
                demote = (f"explicit inner algo {resolved!r} at inner "
                          f"length {inner} has no kernel path")
                backend = "jnp"
            if backend == "jnp" and resolved == "fused":
                raise ValueError('algo="fused" requires backend="pallas" '
                                 '(the fused rfft kernel has no jnp '
                                 'equivalent)')
            block_batch = 1 if resolved == "fused" else 8
    elif len(shape) == 1:
        resolved = resolve_algo(shape[0]) if algo == "auto" else algo
        if resolved == "stockham2":   # radix-2 oracle: a stockham radix config
            resolved, radix, fixed_radix = "stockham", 2, True
        if backend == "pallas" and (resolved in ("naive", "bluestein")
                                    or not kernel_ok):
            demote = f"algo {resolved!r} at {shape} has no kernel path"
            backend = "jnp"
        block_batch = 8
    else:
        fused_algos = ("fused", "fused_stockham") if len(shape) == 2 \
            else ("fused",)           # no 3-D Stockham oracle
        if backend == "pallas" and not kernel_ok:
            demote = ("kernels need power-of-two tile dims >= 2, "
                      f"got {shape}")
            if algo in fused_algos:
                algo = "auto"         # fused demotes with its backend
            backend = "jnp"
        if algo == "auto":
            resolved = "fused" if backend == "pallas" else "row_col"
        else:
            resolved = algo
        if backend == "jnp" and resolved in fused_algos:
            raise ValueError(f'algo={resolved!r} requires backend="pallas" '
                             '(the fused kernels have no jnp equivalent)')
        if resolved not in fused_algos + ("row_col",):
            raise ValueError(
                f'algo={resolved!r} is not a {len(shape)}-D plan algo; '
                f'use one of {fused_algos + ("row_col",)} or "auto"')
        # fused: one (h, w) image / (d, h, w) brick per VMEM tile; row_col:
        # the 1-D kernel's row-tile default (what the direct path executes)
        block_batch = 1 if resolved in fused_algos else 8

    # the GEMM kernels (complex fused 2-D/3-D) are the only variant-aware
    # paths; "auto" picks the compensated tables for sub-fp32 dtypes so a
    # bf16 plan gets the split-twiddle precision fix by default
    gemm_path = (kind == "c2c" and len(shape) >= 2 and backend == "pallas"
                 and resolved == "fused")
    if variant == "auto":
        res_variant = "compensated" if gemm_path and \
            jnp.dtype(dtype).itemsize < 4 else "plain"
    elif variant == "compensated" and not gemm_path:
        if demote is None:
            raise ValueError('variant="compensated" requires a GEMM fused '
                             'plan (2-D/3-D c2c, backend="pallas", '
                             'algo="fused")')
        res_variant = "plain"         # the kernel path demoted away
    else:
        res_variant = variant

    key = _plan_key(shape, dtype, inverse, backend, kind)
    explicit = algo != "auto" or variant != "auto"
    cache_key = key if not explicit else key + (resolved, radix, res_variant)
    cache = _PLAN_CACHE if not explicit else _OVERRIDE_CACHE
    plan = cache.get(cache_key)
    if plan is None:
        plan = FFTPlan(shape=shape, dtype=key[1], inverse=inverse,
                       algo=resolved, radix=radix, backend=backend,
                       block_batch=block_batch, kind=kind,
                       variant=res_variant, demote_reason=demote)
        cache[cache_key] = plan
    if tune and not plan.tuned:
        plan = _autotune(cache_key, plan, batch=tune_batch,
                         fixed_algo=algo != "auto", fixed_radix=fixed_radix,
                         fixed_variant=variant != "auto",
                         prune=prune, prune_k=prune_k, model_arch=model_arch,
                         measure_timeout_s=measure_timeout_s)
        cache[cache_key] = plan
    return plan


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _OVERRIDE_CACHE.clear()
    _AUTOTUNE_RUNS.clear()
    from . import fftconv as _fftconv   # deferred: fftconv imports plan
    _fftconv.clear_spectrum_cache()     # per-plan filter spectra key on plans


# -- runtime demotion (driven by the resilience circuit breaker) ------------

def _runtime_demote(key: PlanKey, reason: str = "runtime_circuit_open"):
    """Swap the auto registry entry for ``key`` (a pallas key) with its jnp
    schedule, carrying a registry-visible ``demote_reason``.  Anyone calling
    :func:`get_plan` for this key now receives the demoted plan; holders of
    the old object still route through the same circuit breaker.  Returns
    the entry that was displaced (None if the key was never interned)."""
    shape, dtype, inverse, _backend, kind = key
    orig = _PLAN_CACHE.get(key)
    twin = get_plan(shape, dtype=dtype, inverse=inverse, kind=kind,
                    backend="jnp")
    _PLAN_CACHE[key] = dataclasses.replace(twin, demote_reason=reason)
    return orig


def _runtime_restore(key: PlanKey, plan: "FFTPlan") -> None:
    """Undo :func:`_runtime_demote`: re-promote the healthy plan."""
    if plan is None:
        _PLAN_CACHE.pop(key, None)
    else:
        _PLAN_CACHE[key] = plan


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


# -- bulk pre-warm -----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WarmResult:
    """One key's outcome from :func:`warm`: the plan that will serve it
    (possibly the jnp twin), whether resolution degraded, and why."""
    plan: "FFTPlan"
    requested_backend: str
    degraded: bool = False
    reason: Optional[str] = None


def warm(keys, *, backend: str = "pallas", tune: bool = False,
         tune_batch: int = 8, fault_site: Optional[str] = "serve.prewarm",
         on_error: str = "degrade"):
    """Bulk-resolve (and optionally tune) N plan keys in one call — the
    single "compile these plans now or degrade" path shared by the serving
    pre-warm (:mod:`repro.serve.spectral.prewarm`) and
    :class:`repro.serve.engine.Engine`.

    ``keys`` is an iterable of shape tuples or dicts
    ``{"shape": (h, w), "dtype": ..., "kind": "c2c"|"rfft",
    "inverse": bool, "backend": ...}`` (dict fields beyond ``shape`` are
    optional; a per-key ``backend`` overrides the call-wide one).  Each key
    is consulted at ``fault_site`` (:func:`repro.resilience.faults.check`,
    tagged ``kind/shape``) before resolution, so injected pre-warm faults
    exercise the degrade path deterministically.

    A key whose resolution raises — kernel compile failure, injected
    fault — never takes the others down: with ``on_error="degrade"``
    (default) it falls back to the always-available jnp schedule and the
    :class:`WarmResult` records ``degraded=True`` plus the reason;
    ``on_error="raise"`` propagates instead.  Results come back in input
    order.
    """
    assert on_error in ("degrade", "raise"), on_error
    from repro.resilience import faults as _faults
    out = []
    for spec in keys:
        if not isinstance(spec, dict):
            spec = {"shape": spec}
        shape = tuple(int(d) for d in spec["shape"])
        kw = dict(dtype=spec.get("dtype", jnp.float32),
                  inverse=bool(spec.get("inverse", False)),
                  kind=spec.get("kind", "c2c"))
        bk = spec.get("backend", backend)
        tag = f"{kw['kind']}/{'x'.join(map(str, shape))}"
        try:
            if fault_site:
                _faults.check(fault_site, tag=tag)
            plan = get_plan(shape, backend=bk, tune=tune,
                            tune_batch=spec.get("tune_batch", tune_batch),
                            **kw)
            out.append(WarmResult(plan=plan, requested_backend=bk))
        except Exception as e:      # noqa: BLE001 — degrade, never crash
            if on_error == "raise":
                raise
            plan = get_plan(shape, backend="jnp", **kw)
            out.append(WarmResult(plan=plan, requested_backend=bk,
                                  degraded=True,
                                  reason=f"{type(e).__name__}: {e}"))
    return out


def autotune_count(shape, *, dtype=jnp.float32, inverse: bool = False,
                   backend: str = "jnp", kind: str = "c2c") -> int:
    """How many times the measuring autotuner ran for this key, counting
    both the auto plan and any explicit-algo override tunes under it.
    ``backend`` is the post-demotion backend (a pallas request that fell
    back to jnp is counted under "jnp")."""
    base = _plan_key(shape, dtype, inverse, backend, kind)
    return sum(v for k, v in _AUTOTUNE_RUNS.items() if k[:5] == base)


# ---------------------------------------------------------------------------
# Wisdom (FFTW-style persisted plans)
# ---------------------------------------------------------------------------

# v3: entries carry the tuned *variant* (GEMM-fused keys autotune over
# the plain/compensated precision variants since the GEMM core landed);
# a v2 file has no variant field, so loading one would silently install
# bf16 GEMM winners with the wrong (plain) tables — the version guard
# rejects v2 outright, like v2 rejected the backend-less v1 files (which
# were written when rfft keys were hard-pinned to backend="jnp").
WISDOM_VERSION = 3


def _wisdom_key_str(key: PlanKey) -> str:
    shape, dtype, inverse, backend, kind = key
    return (f"shape={'x'.join(map(str, shape))};dtype={dtype};"
            f"inverse={int(inverse)};backend={backend};kind={kind}")


def _wisdom_key_parse(s: str) -> PlanKey:
    parts = dict(p.split("=", 1) for p in s.split(";"))
    return (tuple(int(d) for d in parts["shape"].split("x")), parts["dtype"],
            bool(int(parts["inverse"])), parts["backend"], parts["kind"])


def _wisdom_hash(key_str: str, algo, radix, block_batch, backend,
                 variant) -> str:
    """Guard hash over the version, the key AND the tuned values, so a
    stale or hand-edited entry (wrong algo for the shape, typo'd radix,
    swapped backend or precision variant) cannot install a bogus tuned
    plan."""
    payload = (f"v{WISDOM_VERSION}:{key_str}:{algo}:{radix}:{block_batch}"
               f":{backend}:{variant}")
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def save_wisdom(path: str) -> int:
    """Persist every *tuned* auto-keyed plan to ``path`` as JSON, FFTW
    "wisdom" style.  Each entry carries a hash of its (version, key) so a
    stale or hand-edited file cannot silently poison the registry.
    Returns the number of entries written.

    The write is **atomic**: the payload lands in a same-directory temp
    file that is ``os.replace``-d over ``path``, so a crash mid-write (or
    a concurrent writer losing the race) can never leave a torn wisdom
    file — readers see either the old complete file or the new one.  A
    crash leaves only a stale ``.tmp.<pid>`` sibling behind.
    """
    entries = []
    for key, plan in sorted(_PLAN_CACHE.items(), key=lambda kv: repr(kv[0])):
        if not plan.tuned:
            continue
        ks = _wisdom_key_str(key)
        entries.append({
            "key": ks,
            "key_hash": _wisdom_hash(ks, plan.algo, plan.radix,
                                     plan.block_batch, plan.backend,
                                     plan.variant),
            "algo": plan.algo, "radix": plan.radix,
            "block_batch": plan.block_batch,
            # the *tuned* backend: a pallas key's winner may be the jnp
            # schedule (and the key records the requested backend)
            "backend": plan.backend,
            # the tuned precision variant (GEMM-fused keys; "plain"
            # everywhere else)
            "variant": plan.variant,
            "tune_report": plan.tune_report,
        })
    payload = json.dumps({"version": WISDOM_VERSION, "entries": entries},
                         indent=2) + "\n"
    tmp = f"{path}.tmp.{os.getpid()}"
    from repro.resilience import faults as _faults
    with open(tmp, "w") as fh:
        fh.write(payload[:len(payload) // 2])
        # crash-simulation point: a "wisdom.save" error fault aborts here,
        # after a partial write but before the atomic rename — exactly the
        # torn state the temp-file protocol exists to keep out of ``path``
        _faults.check("wisdom.save", tag=path)
        fh.write(payload[len(payload) // 2:])
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    return len(entries)


def load_wisdom(path: str, *, strict: bool = False) -> int:
    """Load wisdom saved by :func:`save_wisdom` into the registry.

    Version-mismatched files and hash-mismatched entries are skipped
    (raised with ``strict=True``); an in-process plan that is *already
    tuned* is never overwritten — live measurements outrank stored ones.
    Loaded plans arrive ``tuned=True``, so a later ``tune=True`` request
    for the same key skips the measuring autotuner entirely.  Returns the
    number of entries installed.
    """
    with open(path) as fh:
        data = json.load(fh)
    if data.get("version") != WISDOM_VERSION:
        if strict:
            raise ValueError(f"wisdom version {data.get('version')!r} != "
                             f"{WISDOM_VERSION} in {path}")
        return 0
    loaded = 0
    for e in data.get("entries", ()):
        try:
            ks = e["key"]
            algo = e["algo"]
            radix = int(e["radix"])
            block_batch = int(e["block_batch"])
            backend = e["backend"]
            variant = e["variant"]
            if _wisdom_hash(ks, algo, radix, block_batch, backend,
                            variant) != e["key_hash"]:
                raise ValueError(f"wisdom key-hash mismatch for {ks!r}")
            key = _wisdom_key_parse(ks)
        except (KeyError, ValueError, TypeError) as ex:
            if strict:
                raise ValueError(f"malformed wisdom entry {e!r}: hash or "
                                 f"field error ({ex})") from ex
            continue
        live = _PLAN_CACHE.get(key)
        if live is not None and live.tuned:
            continue
        report = dict(e.get("tune_report") or {})
        report.setdefault("winner", "wisdom")
        report["source"] = "wisdom"
        _PLAN_CACHE[key] = FFTPlan(
            shape=key[0], dtype=key[1], inverse=key[2], backend=backend,
            kind=key[4], algo=algo, radix=radix,
            block_batch=block_batch, variant=variant,
            tuned=True, tune_report=report)
        loaded += 1
    return loaded


WISDOM_ENV = "REPRO_FFT_WISDOM"


_WISDOM_WARNED = False


def _warn_wisdom_once(msg: str) -> None:
    """One-shot observability for a bad ``$REPRO_FFT_WISDOM`` file: warn
    exactly once per process (imports can re-enter) and never raise."""
    global _WISDOM_WARNED
    if _WISDOM_WARNED:
        return
    _WISDOM_WARNED = True
    import warnings
    warnings.warn(f"{WISDOM_ENV}: {msg}; starting with a cold plan registry",
                  RuntimeWarning, stacklevel=3)


def _autoload_wisdom() -> int:
    """Load wisdom from ``$REPRO_FFT_WISDOM`` at import, FFTW style.

    Best-effort by design: an unset/empty variable is a no-op and a
    missing or corrupt file must never break ``import repro`` — bad
    entries are already skipped non-strictly by :func:`load_wisdom`.  But
    best-effort is not *silent*: an unreadable, non-JSON, wrong-shape or
    version-mismatched file emits a one-shot :class:`RuntimeWarning`
    naming the path and the error class, so a corrupted wisdom deployment
    is observable instead of just mysteriously slow.  Returns the number
    of entries installed (kept in ``WISDOM_AUTOLOADED`` for
    introspection).
    """
    path = os.environ.get(WISDOM_ENV, "").strip()
    if not path:
        return 0
    try:
        loaded = load_wisdom(path)
    except (OSError, ValueError, TypeError, AttributeError, KeyError,
            json.JSONDecodeError) as e:
        # unreadable, not JSON, or JSON of the wrong shape entirely
        _warn_wisdom_once(f"failed to load wisdom from {path!r}: "
                          f"{type(e).__name__}: {e}")
        return 0
    if loaded == 0:
        # loaded-but-empty is legitimate (a fresh save with no tuned
        # plans); a version mismatch is not — name it
        try:
            with open(path) as fh:
                version = json.load(fh).get("version")
        except Exception:  # noqa: BLE001 — diagnosis only, already loaded=0
            version = WISDOM_VERSION
        if version != WISDOM_VERSION:
            _warn_wisdom_once(f"wisdom file {path!r} has version "
                              f"{version!r}, expected {WISDOM_VERSION} "
                              "(all entries skipped)")
    return loaded


WISDOM_AUTOLOADED = _autoload_wisdom()


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

class CandidateTimeout(RuntimeError):
    """An autotune candidate measurement exceeded the watchdog timeout."""


def _watchdog_call(work, timeout_s: Optional[float]):
    """Run ``work()`` with a timeout: the call executes on a daemon thread
    and :class:`CandidateTimeout` is raised if it does not return in time
    (the stuck thread is abandoned — a daemon can never block exit)."""
    if timeout_s is None:
        return work()
    import threading
    out, err = [], []

    def runner():
        try:
            out.append(work())
        except BaseException as e:  # noqa: BLE001 — reraised on the caller
            err.append(e)

    th = threading.Thread(target=runner, daemon=True,
                          name="repro-autotune-measure")
    th.start()
    th.join(timeout_s)
    if th.is_alive():
        raise CandidateTimeout(f"measurement exceeded {timeout_s:g}s")
    if err:
        raise err[0]
    return out[0]


def _time_candidates(plans, x: SplitComplex, *, warmup: int = 1,
                     iters: int = 5, labels=None,
                     timeout_s: Optional[float] = None, extra=()):
    """Best-of-iters wall time (us) per candidate, measured round-robin so
    machine-load drift hits every candidate equally instead of whichever
    happened to run during a busy stretch.

    Every measurement runs under a per-candidate watchdog (``timeout_s``,
    None = off): a candidate that hangs gets ONE retry during warmup and is
    then excluded (time = +inf) instead of hanging the whole tuning run —
    one bad config must never cost the registry its autotuner.  Returns
    ``(times_us, timed_out_labels)``.
    """
    from repro.resilience import faults as _faults
    labels = labels if labels is not None else [str(i) for i in
                                                range(len(plans))]
    fns = [jax.jit(lambda q, p=p: p(q, *extra)) for p in plans]
    best = [float("inf")] * len(fns)
    dead = [False] * len(fns)
    timed_out = []

    def measure(i):
        def work():
            _faults.check("autotune.measure", tag=labels[i])
            t0 = time.perf_counter()
            jax.block_until_ready(fns[i](x))
            return time.perf_counter() - t0
        return _watchdog_call(work, timeout_s)

    for i in range(len(fns)):
        for attempt in range(1 + warmup):        # warmup + one retry
            try:
                measure(i)
                break
            except CandidateTimeout:
                if attempt == warmup:            # retries exhausted
                    dead[i] = True
                    timed_out.append(labels[i])
    for _ in range(iters):
        for i in range(len(fns)):
            if dead[i]:
                continue
            try:
                best[i] = min(best[i], measure(i))
            except CandidateTimeout:
                dead[i] = True
                best[i] = float("inf")           # a hanger can never win
                if labels[i] not in timed_out:
                    timed_out.append(labels[i])
    return [b * 1e6 for b in best], timed_out


def _candidates(plan: FFTPlan, *, fixed_algo: bool = False,
                fixed_radix: bool = False, fixed_variant: bool = False,
                batch: int = 8):
    """(label, plan) candidate configs for this key — the (algo, radix,
    block_batch) grid, kept small so measuring stays cheap.  The heuristic
    default is always candidate 0, so tuning can never pick a config that
    measured worse than what the registry would have used anyway.  With
    ``fixed_algo`` (caller requested a specific algo) only that algo's
    radix/block_batch variants are measured.  block_batch candidates are
    clamped to ``batch`` — padding the measured batch up to a larger tile
    would time a strictly larger workload."""
    base = dataclasses.replace
    out = [("default", plan)]
    if plan.kind in CONV_KINDS:
        if plan.backend != "pallas":
            # unfused jnp conv composes rfft/irfft keys that tune
            # independently; nothing plan-level to vary here
            return out
        for bb in sorted({min(b, batch) for b in (1, 2)}):
            out.append((f"fused/bb{bb}",
                        base(plan, algo="fused", block_batch=bb)))
        # the registry-composed unfused path as the cross-backend baseline
        out.append(("unfused/jnp", base(plan, backend="jnp", algo="unfused",
                                        block_batch=8)))
        if fixed_algo:
            out = [(lbl, c) for lbl, c in out if c.algo == plan.algo]
        seen, uniq = set(), []
        for lbl, c in out:
            cfg = (c.algo, c.radix, c.block_batch, c.backend)
            if cfg not in seen:
                seen.add(cfg)
                uniq.append((lbl, c))
        return uniq
    if plan.kind == "rfft":
        if plan.backend != "pallas":
            # jnp rfft wraps an inner c2c transform whose own key is tuned
            # independently; nothing plan-level to vary here
            return out
        # pallas rfft keys tune over (algo, backend, block_batch): the
        # kernel variants plus the jnp schedule as the cross-backend
        # baseline — tuning may conclude the kernel does not pay here
        inner = plan.n if plan.inverse else plan.n // 2
        if plan.ndim == 2:
            for bb in sorted({min(b, batch) for b in (1, 2)}):
                out.append((f"fused/bb{bb}",
                            base(plan, algo="fused", block_batch=bb)))
        else:
            for bb in sorted({min(b, batch) for b in (4, 8, 16)}):
                out.append((f"stockham/r4/bb{bb}",
                            base(plan, algo="stockham", radix=4,
                                 block_batch=bb)))
            bb4s = min(4, batch)
            out.append((f"four_step/bb{bb4s}",
                        base(plan, algo="four_step", block_batch=bb4s)))
        out.append(("jnp", base(plan, backend="jnp",
                                algo=resolve_algo(inner), block_batch=8)))
        if fixed_algo:
            out = [(lbl, c) for lbl, c in out if c.algo == plan.algo]
        seen, uniq = set(), []
        for lbl, c in out:
            cfg = (c.algo, c.radix, c.block_batch, c.backend)
            if cfg not in seen:
                seen.add(cfg)
                uniq.append((lbl, c))
        return uniq
    if plan.ndim == 1:
        n = plan.n
        if not _is_pow2(n):
            return out                       # naive/bluestein: nothing to tune
        if plan.backend == "pallas":
            for bb in sorted({min(b, batch) for b in (4, 8, 16)}):
                out.append((f"stockham/r4/bb{bb}",
                            base(plan, algo="stockham", radix=4,
                                 block_batch=bb)))
            bb2 = min(8, batch)
            out.append((f"stockham/r2/bb{bb2}",
                        base(plan, algo="stockham", radix=2,
                             block_batch=bb2)))
            bb4s = min(4, batch)
            out.append((f"four_step/bb{bb4s}",
                        base(plan, algo="four_step", block_batch=bb4s)))
        else:
            out.append(("stockham/r4", base(plan, algo="stockham", radix=4)))
            out.append(("stockham/r2", base(plan, algo="stockham", radix=2)))
            out.append(("four_step", base(plan, algo="four_step")))
            if n <= 2048:
                out.append(("naive", base(plan, algo="naive")))
    else:
        if plan.backend == "pallas":
            for bb in sorted({min(b, batch) for b in (1, 2)}):
                out.append((f"fused/bb{bb}",
                            base(plan, algo="fused", block_batch=bb)))
            if jnp.dtype(plan.dtype).itemsize < 4 and not fixed_variant:
                # sub-fp32 GEMM keys also measure the *other* precision
                # variant: compensated pays 2x table flops for ~2x less
                # error, and which side wins is a measurement question
                other = "plain" if plan.variant == "compensated" \
                    else "compensated"
                out.append((f"fused/{other}/bb1",
                            base(plan, algo="fused", block_batch=1,
                                 variant=other)))
            if plan.ndim == 2:
                out.append(("fused_stockham/bb1",
                            base(plan, algo="fused_stockham", block_batch=1,
                                 variant="plain")))
            out.append(("row_col", base(plan, algo="row_col",
                                        variant="plain")))
        else:
            out.append(("row_col", base(plan, algo="row_col")))
    if fixed_algo:
        out = [(lbl, c) for lbl, c in out if c.algo == plan.algo]
    if fixed_radix:                   # e.g. the "stockham2" radix-2 oracle
        out = [(lbl, c) for lbl, c in out if c.radix == plan.radix]
    seen, uniq = set(), []
    for lbl, c in out:                # drop configs identical to the default
        cfg = (c.algo, c.radix, c.block_batch, c.variant)
        if cfg not in seen:
            seen.add(cfg)
            uniq.append((lbl, c))
    return uniq


def _model_prune(cands, *, batch: int, prune_k: Optional[int],
                 model_arch: str):
    """Rank candidates with the analytic cost model and keep the top-k.

    The heuristic default (candidate 0) is always kept, so pruning can
    only *add* model-favoured configs to the measured set, never remove
    the config the registry would have used untuned.  Candidates on a
    *different backend* than the default are also always kept: the model
    is an intra-backend ranker, and the cross-backend wall-clock question
    (interpret-mode overhead vs XLA batch amortisation) is exactly what
    it cannot see — pruning the jnp schedule from an rfft pallas key
    would install a measurably slower winner at small sizes.  Candidates
    whose working set busts the arch's SRAM budget rank last
    (predict_cost is +inf for them).  Returns (kept, pruned_labels).
    """
    if len(cands) <= 2:
        return cands, []
    from repro.tt.trace import predict_cost
    k = prune_k if prune_k is not None else max(2, (len(cands) + 1) // 2)
    k = max(2, min(k, len(cands)))
    if k >= len(cands):
        return cands, []
    base_backend = cands[0][1].backend
    forced = [i for i in range(1, len(cands))
              if cands[i][1].backend != base_backend]
    costs = [predict_cost(c, arch=model_arch, batch=batch)
             for _, c in cands]
    rest = sorted((i for i in range(1, len(cands)) if i not in forced),
                  key=costs.__getitem__)
    keep_idx = sorted(set([0] + forced + rest[:max(0, k - 1 - len(forced))]))
    kept = [cands[i] for i in keep_idx]
    pruned = [cands[i][0] for i in range(len(cands)) if i not in keep_idx]
    return kept, pruned


def _autotune(key, plan: FFTPlan, *, batch: int = 8,
              fixed_algo: bool = False, fixed_radix: bool = False,
              fixed_variant: bool = False,
              prune: str = "none", prune_k: Optional[int] = None,
              model_arch: str = "tpu_v5e",
              measure_timeout_s: Optional[float] = "config") -> FFTPlan:
    """Measure every candidate config (or, with ``prune="model"``, the
    model-ranked top-k) and return the winner (tuned=True).  Candidates
    that exceed the per-measurement watchdog are excluded (one retry
    first) and named in ``tune_report["timeouts"]``; if *every* candidate
    times out the heuristic default is kept untouched (winner
    ``"default/untimed"``)."""
    _AUTOTUNE_RUNS[key] = _AUTOTUNE_RUNS.get(key, 0) + 1
    if measure_timeout_s == "config":
        from repro.resilience import config as _rcfg
        measure_timeout_s = _rcfg.get("measure_timeout_s")
    rng = np.random.default_rng(0)
    shp = (batch,) + plan.shape
    dt = jnp.dtype(plan.dtype)
    extra = ()
    if plan.kind in CONV_KINDS:
        # real signals (batch rows of the padded length) convolved against
        # one shared synthetic filter half spectrum — the second operand
        x = jnp.asarray(rng.standard_normal(shp), dt)
        hshp = (plan.n // 2 + 1,)
        extra = (SplitComplex(jnp.asarray(rng.standard_normal(hshp), dt),
                              jnp.asarray(rng.standard_normal(hshp), dt)),)
    elif plan.kind == "rfft":
        x = jnp.asarray(rng.standard_normal(shp), dt)
        if plan.inverse:                       # half-spectrum input
            hshp = shp[:-1] + (plan.shape[-1] // 2 + 1,)
            x = SplitComplex(jnp.asarray(rng.standard_normal(hshp), dt),
                             jnp.asarray(rng.standard_normal(hshp), dt))
    else:
        x = SplitComplex(jnp.asarray(rng.standard_normal(shp), dt),
                         jnp.asarray(rng.standard_normal(shp), dt))
    cands = _candidates(plan, fixed_algo=fixed_algo, fixed_radix=fixed_radix,
                        fixed_variant=fixed_variant, batch=batch)
    n_all = len(cands)
    pruned_labels = []
    if prune == "model":
        cands, pruned_labels = _model_prune(cands, batch=batch,
                                            prune_k=prune_k,
                                            model_arch=model_arch)
    times, timed_out = _time_candidates(
        [c for _, c in cands], x, labels=[lbl for lbl, _ in cands],
        timeout_s=measure_timeout_s, extra=extra)
    report = {label: (round(us, 1) if us != float("inf") else "timeout")
              for (label, _), us in zip(cands, times)}
    report["n_candidates"] = n_all
    report["n_measured"] = len(cands)
    if pruned_labels:
        report["model_pruned"] = "|".join(pruned_labels)
    if timed_out:
        report["timeouts"] = "|".join(timed_out)
    if all(t == float("inf") for t in times):
        # every candidate hung: keep the heuristic default, but mark the
        # key tuned so the pathological measurement is not re-run per call
        report["winner"] = "default/untimed"
        return dataclasses.replace(plan, tuned=True, tune_report=report)
    best = min(range(len(cands)), key=times.__getitem__)
    report["winner"] = cands[best][0]
    return dataclasses.replace(cands[best][1], tuned=True, tune_report=report)


# ---------------------------------------------------------------------------
# Convenience constructors
# ---------------------------------------------------------------------------

def plan_fft(n: int, **kw) -> FFTPlan:
    return FFTPlan.create(n, **kw)


def plan_ifft(n: int, **kw) -> FFTPlan:
    return FFTPlan.create(n, inverse=True, **kw)


def plan_fft2(h: int, w: int, **kw) -> FFTPlan:
    return get_plan((h, w), **kw)


def plan_ifft2(h: int, w: int, **kw) -> FFTPlan:
    return get_plan((h, w), inverse=True, **kw)
