"""Split-complex arithmetic on (re, im) pairs.

The paper works with separate real and imaginary planes because the Tensix
compute engine has no complex type (Section 4).  The same choice is right on
TPU: Pallas/Mosaic have no complex registers, and split planes keep the
(8, 128) lane layout dense for both the VPU and the MXU.  Every FFT in this
repo therefore operates on a ``SplitComplex`` pair of same-shape float arrays.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class SplitComplex(NamedTuple):
    """A complex tensor stored as two same-shape real tensors."""

    re: jnp.ndarray
    im: jnp.ndarray

    @property
    def shape(self):
        return self.re.shape

    @property
    def dtype(self):
        return self.re.dtype

    def astype(self, dtype) -> "SplitComplex":
        return SplitComplex(self.re.astype(dtype), self.im.astype(dtype))


def from_complex(z) -> SplitComplex:
    z = jnp.asarray(z)
    return SplitComplex(jnp.real(z), jnp.imag(z))


def to_complex(z: SplitComplex):
    return z.re + 1j * z.im


def from_real(x) -> SplitComplex:
    x = jnp.asarray(x)
    return SplitComplex(x, jnp.zeros_like(x))


def add(a: SplitComplex, b: SplitComplex) -> SplitComplex:
    return SplitComplex(a.re + b.re, a.im + b.im)


def sub(a: SplitComplex, b: SplitComplex) -> SplitComplex:
    return SplitComplex(a.re - b.re, a.im - b.im)


def mul(a: SplitComplex, b: SplitComplex) -> SplitComplex:
    """4-multiply complex product (paper's Listing 1.1 f0/f1 structure)."""
    return SplitComplex(a.re * b.re - a.im * b.im, a.re * b.im + a.im * b.re)


def mul3(a: SplitComplex, b: SplitComplex) -> SplitComplex:
    """Karatsuba 3-multiply complex product.

    Beyond-paper micro-optimisation: one fewer multiply per element at the
    cost of two extra adds — a win when multiplier throughput, not adder
    throughput, limits the VPU.
    """
    k1 = a.re * (b.re + b.im)
    k2 = b.im * (a.re + a.im)
    k3 = b.re * (a.im - a.re)
    return SplitComplex(k1 - k2, k1 + k3)


def conj(a: SplitComplex) -> SplitComplex:
    return SplitComplex(a.re, -a.im)


def scale(a: SplitComplex, s) -> SplitComplex:
    return SplitComplex(a.re * s, a.im * s)


def matmul(w: SplitComplex, x: SplitComplex, *, precision=None,
           preferred_element_type=jnp.float32) -> SplitComplex:
    """Complex matmul via four real matmuls (MXU path).

    ``w @ x`` with w: (..., M, K), x: (..., K, N).  Four real matmuls keep
    every FLOP on the MXU; a 3-matmul Karatsuba variant exists
    (:func:`matmul3`) but the 4-matmul form has a friendlier fusion shape.
    """
    dot = lambda p, q: jnp.matmul(p, q, precision=precision,
                                  preferred_element_type=preferred_element_type)
    return SplitComplex(dot(w.re, x.re) - dot(w.im, x.im),
                        dot(w.re, x.im) + dot(w.im, x.re))


def matmul3(w: SplitComplex, x: SplitComplex, *, precision=None,
            preferred_element_type=jnp.float32) -> SplitComplex:
    """Complex matmul via three real matmuls (Karatsuba).

    25% fewer MXU FLOPs than :func:`matmul`; trades them for three extra
    elementwise adds on the VPU.  Used by the compute-bound four-step path.
    """
    dot = lambda p, q: jnp.matmul(p, q, precision=precision,
                                  preferred_element_type=preferred_element_type)
    k1 = dot(w.re, x.re + x.im)
    k2 = dot(w.re + w.im, x.im)
    k3 = dot(w.im - w.re, x.re)
    # re = wr*xr - wi*xi = k1 - k2 - ... check: k1 = wr@xr + wr@xi ; k2 = wr@xi + wi@xi
    # k1 - k2 = wr@xr - wi@xi  (re)  ;  k1 + k3 = wr@xi + wi@xr  (im)
    return SplitComplex(k1 - k2, k1 + k3)


def allclose(a: SplitComplex, b: SplitComplex, **kw) -> bool:
    return bool(np.allclose(a.re, b.re, **kw) and np.allclose(a.im, b.im, **kw))
