"""FNet-style Fourier token mixing — the transformer integration point.

``fourier_mix`` replaces self-attention with Re(FFT_seq(FFT_model(x))): a
parameter-free O(S log S) token mixer (Lee-Thorp et al., FNet) built on this
repo's FFT core.  Any transformer config can select it via
``token_mixing="fourier"`` (DESIGN.md §4); the ``fnet_demo`` example config
uses it end-to-end.

With ``algo="auto"`` both 1-D transforms route through the plan registry,
so the (d_model,) and (seq,) dispatch decisions are resolved once per
shape/dtype/backend and shared with every other caller —
:class:`repro.serve.engine.Engine` pre-warms the (d_model,) key.
``backend="pallas"`` requests the kernel path for both axis transforms;
sizes with no kernel schedule demote to jnp with a registry-visible
``demote_reason`` (the usual registry contract), so the model still runs.
"""
from __future__ import annotations

import jax.numpy as jnp

from .complexmath import SplitComplex, from_real
from . import fft1d


def _fft_last(z: SplitComplex, *, algo: str, backend: str) -> SplitComplex:
    """Last-axis forward FFT honouring ``backend`` — registry-routed for
    ``algo="auto"`` (the only path with a backend notion), direct otherwise."""
    if algo == "auto":
        from . import plan as _plan            # deferred: plan imports spectral's deps
        return _plan.get_plan((z.shape[-1],), dtype=z.dtype,
                              backend=backend)(z)
    return fft1d.fft(z, algo=algo)


def fourier_mix(x: jnp.ndarray, *, algo: str = "auto",
                backend: str = "jnp") -> jnp.ndarray:
    """x: (..., seq, d_model) -> Re(FFT over d_model then over seq)."""
    z = from_real(x)
    z = _fft_last(z, algo=algo, backend=backend)    # over d_model (last axis)
    zr = jnp.swapaxes(z.re, -1, -2)
    zi = jnp.swapaxes(z.im, -1, -2)
    z = _fft_last(SplitComplex(zr, zi), algo=algo, backend=backend)  # over seq
    return jnp.swapaxes(z.re, -1, -2)
