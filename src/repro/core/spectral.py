"""FNet-style Fourier token mixing — the transformer integration point.

``fourier_mix`` replaces self-attention with Re(FFT_seq(FFT_model(x))): a
parameter-free O(S log S) token mixer (Lee-Thorp et al., FNet) built on this
repo's FFT core.  Any transformer config can select it via
``token_mixing="fourier"`` (DESIGN.md §4); the ``fnet_demo`` example config
uses it end-to-end.

With ``algo="auto"`` both 1-D transforms route through the plan registry
inside :func:`repro.core.fft1d.fft`, so the (d_model,) and (seq,) dispatch
decisions are resolved once per shape/dtype and shared with every other
caller — :class:`repro.serve.engine.Engine` pre-warms the (d_model,) key.
"""
from __future__ import annotations

import jax.numpy as jnp

from .complexmath import SplitComplex, from_real
from . import fft1d


def fourier_mix(x: jnp.ndarray, *, algo: str = "auto") -> jnp.ndarray:
    """x: (..., seq, d_model) -> Re(FFT over d_model then over seq)."""
    z = from_real(x)
    z = fft1d.fft(z, algo=algo)                    # over d_model (last axis)
    zr = jnp.swapaxes(z.re, -1, -2)
    zi = jnp.swapaxes(z.im, -1, -2)
    z = fft1d.fft(SplitComplex(zr, zi), algo=algo)  # over seq
    return jnp.swapaxes(z.re, -1, -2)
