"""Twiddle-factor tables.

The paper computes twiddles once at initialisation on the compute engine and
keeps them resident in SRAM (Section 4).  We do the same: tables are built in
float64 on the host (numpy) for accuracy, cast to the working dtype, and
treated as constants by XLA (hoisted out of the step, resident in HBM/VMEM).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .complexmath import SplitComplex


@functools.lru_cache(maxsize=128)
def _twiddle_np(n: int, sign: float) -> tuple:
    k = np.arange(n, dtype=np.float64)
    ang = sign * 2.0 * np.pi * k / n
    return np.cos(ang), np.sin(ang)


def twiddles(n: int, *, inverse: bool = False, dtype=jnp.float32) -> SplitComplex:
    """``exp(sign * 2*pi*i * k / n)`` for k in [0, n): the stage-n table."""
    sign = 1.0 if inverse else -1.0
    c, s = _twiddle_np(n, sign)
    return SplitComplex(jnp.asarray(c, dtype=dtype), jnp.asarray(s, dtype=dtype))


@functools.lru_cache(maxsize=64)
def _dft_matrix_np(n: int, sign: float) -> tuple:
    jk = np.outer(np.arange(n, dtype=np.float64), np.arange(n, dtype=np.float64))
    ang = sign * 2.0 * np.pi * jk / n
    return np.cos(ang), np.sin(ang)


def dft_matrix(n: int, *, inverse: bool = False, dtype=jnp.float32) -> SplitComplex:
    """Dense DFT matrix W[j, k] = exp(sign*2*pi*i*j*k/n).

    The MXU leaf operator for the four-step path.  W is symmetric, so row
    and column transforms use the same table.
    """
    sign = 1.0 if inverse else -1.0
    c, s = _dft_matrix_np(n, sign)
    return SplitComplex(jnp.asarray(c, dtype=dtype), jnp.asarray(s, dtype=dtype))


@functools.lru_cache(maxsize=64)
def _fourstep_twiddle_np(n1: int, n2: int, sign: float) -> tuple:
    k1 = np.arange(n1, dtype=np.float64)[:, None]
    n2r = np.arange(n2, dtype=np.float64)[None, :]
    ang = sign * 2.0 * np.pi * (k1 * n2r) / (n1 * n2)
    return np.cos(ang), np.sin(ang)


def fourstep_twiddle(n1: int, n2: int, *, inverse: bool = False,
                     dtype=jnp.float32) -> SplitComplex:
    """Inter-factor twiddle T[k1, n2] = exp(sign*2*pi*i*k1*n2/(n1*n2))."""
    sign = 1.0 if inverse else -1.0
    c, s = _fourstep_twiddle_np(n1, n2, sign)
    return SplitComplex(jnp.asarray(c, dtype=dtype), jnp.asarray(s, dtype=dtype))


# ---------------------------------------------------------------------------
# Packed Stockham stage tables (mixed radix-4 / radix-2)
# ---------------------------------------------------------------------------

def stockham_radices(n: int) -> tuple:
    """Stage plan for a mixed-radix Stockham FFT of power-of-two length n.

    Radix-4 stages while 4 | n_cur, then one radix-2 tail when a factor of 2
    remains.  Because the tail runs *last* (n_cur == 2, m == 1) its twiddle is
    identically 1, so only the radix-4 stages need tables.
    """
    assert n > 0 and (n & (n - 1)) == 0, f"power-of-two n required, got {n}"
    radices = []
    n_cur = n
    while n_cur >= 4:
        radices.append(4)
        n_cur //= 4
    if n_cur == 2:
        radices.append(2)
    return tuple(radices)


@functools.lru_cache(maxsize=64)
def packed_radix4_twiddles_np(n: int, inverse: bool) -> tuple:
    """(s4, 3, n//4) twiddle planes for every radix-4 Stockham stage.

    Row s holds (w, w^2, w^3) for stage s with w[p] = exp(sign*2*pi*i*p/n_cur)
    pre-broadcast over the stride axis, so within a stage each plane is read
    as one contiguous row of length n//4 (== m * stride at every radix-4
    stage).  The radix-2 tail needs no table (see :func:`stockham_radices`).
    Built in float64; callers cast to the working dtype.  For n < 4 a single
    zero row of width max(n//4, 1) is returned so kernel operands stay
    non-empty.
    """
    s4 = sum(1 for r in stockham_radices(n) if r == 4)
    width = max(n // 4, 1)
    wr = np.zeros((max(s4, 1), 3, width), dtype=np.float64)
    wi = np.zeros((max(s4, 1), 3, width), dtype=np.float64)
    sign = 1.0 if inverse else -1.0
    n_cur, stride = n, 1
    for s in range(s4):
        m = n_cur // 4
        p = np.arange(m, dtype=np.float64)
        ang = sign * 2.0 * np.pi * p / n_cur
        w1 = np.cos(ang) + 1j * np.sin(ang)
        for j, w in enumerate((w1, w1 * w1, w1 * w1 * w1)):
            wr[s, j] = np.repeat(w.real, stride)
            wi[s, j] = np.repeat(w.imag, stride)
        n_cur, stride = m, stride * 4
    return wr, wi


@functools.lru_cache(maxsize=64)
def packed_radix2_twiddles_np(n: int, inverse: bool) -> tuple:
    """(stages, n//2) per-stage, stride-broadcast radix-2 twiddle planes.

    The packed table of the original radix-2 Stockham kernel; kept as the
    radix-2 oracle path and re-exported by :mod:`repro.kernels.fft_stockham`.
    """
    stages = int(n).bit_length() - 1
    sign = 1.0 if inverse else -1.0
    wr = np.empty((stages, n // 2), dtype=np.float64)
    wi = np.empty((stages, n // 2), dtype=np.float64)
    for s in range(stages):
        n_cur = n >> s
        stride = 1 << s
        m = n_cur // 2
        p = np.arange(m, dtype=np.float64)
        ang = sign * 2.0 * np.pi * p / n_cur
        wr[s] = np.repeat(np.cos(ang), stride)
        wi[s] = np.repeat(np.sin(ang), stride)
    return wr, wi


def bit_reverse_indices(n: int) -> np.ndarray:
    """Bit-reversal permutation for power-of-two n (host-side constant)."""
    bits = int(n).bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    rev = np.zeros_like(idx)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev
