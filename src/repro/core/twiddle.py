"""Twiddle-factor tables.

The paper computes twiddles once at initialisation on the compute engine and
keeps them resident in SRAM (Section 4).  We do the same: tables are built in
float64 on the host (numpy) for accuracy, cast to the working dtype, and
treated as constants by XLA (hoisted out of the step, resident in HBM/VMEM).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .complexmath import SplitComplex


@functools.lru_cache(maxsize=128)
def _twiddle_np(n: int, sign: float) -> tuple:
    k = np.arange(n, dtype=np.float64)
    ang = sign * 2.0 * np.pi * k / n
    return np.cos(ang), np.sin(ang)


def twiddles(n: int, *, inverse: bool = False, dtype=jnp.float32) -> SplitComplex:
    """``exp(sign * 2*pi*i * k / n)`` for k in [0, n): the stage-n table."""
    sign = 1.0 if inverse else -1.0
    c, s = _twiddle_np(n, sign)
    return SplitComplex(jnp.asarray(c, dtype=dtype), jnp.asarray(s, dtype=dtype))


@functools.lru_cache(maxsize=64)
def _dft_matrix_np(n: int, sign: float) -> tuple:
    jk = np.outer(np.arange(n, dtype=np.float64), np.arange(n, dtype=np.float64))
    ang = sign * 2.0 * np.pi * jk / n
    return np.cos(ang), np.sin(ang)


def dft_matrix(n: int, *, inverse: bool = False, dtype=jnp.float32) -> SplitComplex:
    """Dense DFT matrix W[j, k] = exp(sign*2*pi*i*j*k/n).

    The MXU leaf operator for the four-step path.  W is symmetric, so row
    and column transforms use the same table.
    """
    sign = 1.0 if inverse else -1.0
    c, s = _dft_matrix_np(n, sign)
    return SplitComplex(jnp.asarray(c, dtype=dtype), jnp.asarray(s, dtype=dtype))


@functools.lru_cache(maxsize=64)
def _fourstep_twiddle_np(n1: int, n2: int, sign: float) -> tuple:
    k1 = np.arange(n1, dtype=np.float64)[:, None]
    n2r = np.arange(n2, dtype=np.float64)[None, :]
    ang = sign * 2.0 * np.pi * (k1 * n2r) / (n1 * n2)
    return np.cos(ang), np.sin(ang)


def fourstep_twiddle(n1: int, n2: int, *, inverse: bool = False,
                     dtype=jnp.float32) -> SplitComplex:
    """Inter-factor twiddle T[k1, n2] = exp(sign*2*pi*i*k1*n2/(n1*n2))."""
    sign = 1.0 if inverse else -1.0
    c, s = _fourstep_twiddle_np(n1, n2, sign)
    return SplitComplex(jnp.asarray(c, dtype=dtype), jnp.asarray(s, dtype=dtype))


def bit_reverse_indices(n: int) -> np.ndarray:
    """Bit-reversal permutation for power-of-two n (host-side constant)."""
    bits = int(n).bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    rev = np.zeros_like(idx)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev
