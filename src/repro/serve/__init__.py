"""repro.serve — serving layer.

- :mod:`repro.serve.engine`: the LM prefill/decode engine with a batched
  slot scheduler.
- :mod:`repro.serve.spectral`: continuous-batching spectral serving —
  shape-bucket scheduling over the plan registry, async host<->device
  pipelining, startup pre-warm, per-bucket metrics, and a load generator.
"""
from .engine import ServeConfig, Engine
from . import spectral
