"""repro.serve — prefill/decode engine with a batched request scheduler."""
from .engine import ServeConfig, Engine
