"""Serving engine: jit'd prefill + decode steps and a slot-based batched
request scheduler (continuous-batching-lite).

The engine keeps a fixed batch of B slots.  Requests prefill into a free
slot's cache region; every engine tick decodes one token for all active
slots; finished slots (EOS or max tokens) are recycled.  Sampling is greedy
or temperature-based with a deterministic per-slot PRNG.

``decode_fn`` is exactly what the `decode_32k` / `long_500k` dry-run cells
lower: one new token against a seq_len-deep cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as fftplan
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.resilience import faults as _faults


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 8
    max_len: int = 1024
    temperature: float = 0.0         # 0 => greedy
    eos_id: Optional[int] = None
    seed: int = 0
    fft_backend: str = "jnp"         # the backend pre-warm requests


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request_id: int = -1
    position: int = 0
    generated: Optional[list] = None
    deadline: Optional[float] = None  # absolute clock time, None = no limit


class Engine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.params = params
        self.cache = M.init_cache(cfg, scfg.batch_size, scfg.max_len,
                                  jnp.dtype(cfg.dtype))
        self.slots: List[_Slot] = [_Slot() for _ in range(scfg.batch_size)]
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos))
        self._key = jax.random.PRNGKey(scfg.seed)
        self.finished: dict = {}
        self.timed_out: set = set()   # request ids cut off by their deadline
        self.degraded = False         # pre-warm fell back to jnp plans
        self.degrade_reason: Optional[str] = None
        self._clock = clock if clock is not None else time.monotonic
        self._warm_fft_plans()

    def _warm_fft_plans(self) -> None:
        """Resolve the (d_model,) FFT plan fourier mixers request on every
        call, once at engine construction (FFTW plan-then-execute) — the
        plan lives in the process-wide registry, not on the engine.  The
        seq-axis key depends on the runtime sequence length (1 per decode
        step, prompt length at prefill), so it resolves lazily on first use.

        The compile-or-degrade semantics live in
        :func:`repro.core.plan.warm` (shared with the spectral server's
        bucket pre-warm): a raising plan resolution (kernel compile
        failure, injected ``serve.prewarm`` fault) degrades the engine to
        the always-available jnp schedule — ``self.degraded`` flips and
        ``self.degrade_reason`` says why — and serving proceeds at reduced
        throughput instead of crashing."""
        cfg = self.cfg
        uses_fourier = (cfg.token_mixing == "fourier"
                        or any("fourier" in b for b in cfg.block_pattern))
        if not uses_fourier:
            return
        res = fftplan.warm([{"shape": (cfg.d_model,),
                             "dtype": jnp.dtype(cfg.dtype)}],
                           backend=self.scfg.fft_backend)[0]
        if res.degraded:
            self.degraded = True
            self.degrade_reason = res.reason

    # -- request lifecycle ---------------------------------------------------

    def add_request(self, request_id: int, prompt: np.ndarray,
                    deadline_s: Optional[float] = None) -> bool:
        """Prefill `prompt` into a free slot; False if engine is full.

        ``deadline_s`` is a per-request latency budget in seconds (measured
        on the engine clock from admission): a request past its deadline is
        finished with whatever it generated so far and its id recorded in
        ``self.timed_out`` — the engine never burns decode steps on a
        response nobody is waiting for."""
        try:
            slot_idx = next(i for i, s in enumerate(self.slots)
                            if not s.active)
        except StopIteration:
            return False
        # token-by-token prefill into this slot (batch-1 slice of the cache):
        # simple and always correct; bulk prefill is used by the examples
        # when the whole batch starts together.
        for t, tok in enumerate(prompt[:-1]):
            toks = np.zeros((self.scfg.batch_size,), np.int32)
            toks[slot_idx] = tok
            pos = np.full((self.scfg.batch_size,), -1_000_000, np.int32)
            pos[slot_idx] = t
            _, self.cache = self._decode(self.params, jnp.asarray(toks),
                                         self.cache, jnp.asarray(pos))
        s = self.slots[slot_idx]
        s.active = True
        s.request_id = request_id
        s.position = len(prompt) - 1
        s.generated = [int(prompt[-1])]
        s.deadline = (None if deadline_s is None
                      else self._clock() + deadline_s)
        return True

    # -- engine tick -----------------------------------------------------

    def step(self, max_new: int):
        _faults.check("serve.step", tag="tick")
        toks = np.zeros((self.scfg.batch_size,), np.int32)
        pos = np.full((self.scfg.batch_size,), -1_000_000, np.int32)
        for i, s in enumerate(self.slots):
            if s.active:
                toks[i] = s.generated[-1]
                pos[i] = s.position
        logits, self.cache = self._decode(self.params, jnp.asarray(toks),
                                          self.cache, jnp.asarray(pos))
        if self.scfg.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            nxt = jax.random.categorical(
                sub, logits / self.scfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = np.asarray(nxt)
        now = self._clock()
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            s.generated.append(int(nxt[i]))
            s.position += 1
            expired = s.deadline is not None and now >= s.deadline
            done = (expired
                    or len(s.generated) - 1 >= max_new
                    or (self.scfg.eos_id is not None
                        and nxt[i] == self.scfg.eos_id)
                    or s.position >= self.scfg.max_len - 1)
            if done:
                if expired:
                    self.timed_out.add(s.request_id)
                self.finished[s.request_id] = list(s.generated)
                s.active = False
                s.generated = None
                s.deadline = None

    def run(self, requests, max_new: int = 32):
        """Serve a list of (id, prompt ndarray[, deadline_s]); returns
        {id: tokens}.  Ids in ``self.timed_out`` were cut short by their
        deadline (their entry holds the partial generation)."""
        pending = list(requests)
        while pending or any(s.active for s in self.slots):
            while pending and self.add_request(*pending[0]):
                pending.pop(0)
            if any(s.active for s in self.slots):
                self.step(max_new)
        return self.finished


def decode_fn(cfg: ModelConfig):
    """(params, tokens, cache, position) -> (logits, cache') — the function
    the decode dry-run cells lower."""
    def fn(params, tokens, cache, position):
        return M.decode_step(params, cfg, tokens, cache, position)
    return fn


def prefill_fn(cfg: ModelConfig):
    def fn(params, batch, cache):
        return M.prefill(params, cfg, tokens=batch.get("tokens"),
                         embeds=batch.get("embeds"), cache=cache)
    return fn
