"""repro.serve.spectral — production serving for FFT requests.

Continuous batching of ragged transform requests into plan-registry shape
buckets (:mod:`scheduler`), async host↔device pipelining with bounded
queues (:mod:`executor`), startup wisdom pre-warm with degrade-to-jnp
(:mod:`prewarm`), per-bucket latency/occupancy metrics with a JSON
snapshot endpoint (:mod:`metrics`), and open/closed-loop load generation
(:mod:`loadgen`).  :class:`SpectralServer` composes the pieces.
"""
from .scheduler import (BucketConfig, NoBucketError, Request,
                        ShapeBucketScheduler)
from .metrics import LatencyHistogram, Metrics
from .server import RequestRecord, SpectralServer
from .loadgen import MixItem, closed_loop, open_loop
from .prewarm import PrewarmReport
