"""Per-bucket serving metrics: counters, latency histograms, gauges, and a
JSON snapshot endpoint.

Latencies land in fixed log-spaced histograms (10 buckets per decade from
10us to 2min) so p50/p99 come from bucket edges without storing samples —
bounded memory at any request rate.  Three histograms per bucket: ``queue``
(admission → dispatch), ``service`` (dispatch → results on host) and
``e2e`` (admission → terminal).  Gauges (queue depth at admission, batch
occupancy at dispatch) keep count/sum/max running stats.

The snapshot is a plain JSON-able dict; :func:`start_http` serves it at
``GET /metrics`` from a daemon thread (port 0 = ephemeral) so a load
generator — or a human — can watch a running server without touching its
dispatch path.  Kernel-path health comes from
:func:`repro.resilience.executor.stats`: the server folds each pallas
bucket's attempt/failure/fallback counters into its snapshot section.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, Optional

HIST_NAMES = ("queue", "service", "e2e")

COUNTERS = ("admitted", "rejected_nobucket", "rejected_backpressure",
            "padded_up", "completed", "timed_out_queued",
            "timed_out_inflight", "fallback_served", "batches",
            "batch_items", "batch_pad_slots")


class LatencyHistogram:
    """Fixed log-spaced latency histogram with edge-quantile estimation."""

    def __init__(self, lo_s: float = 1e-5, hi_s: float = 120.0,
                 per_decade: int = 10):
        decades = math.log10(hi_s / lo_s)
        n = int(round(decades * per_decade))
        self.edges = [lo_s * 10 ** (i / per_decade) for i in range(n + 1)]
        self.counts = [0] * (n + 2)          # +underflow, +overflow
        self.total = 0
        self.sum_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        s = max(0.0, float(seconds))
        lo = 0
        hi = len(self.edges)
        while lo < hi:                       # first edge > s
            mid = (lo + hi) // 2
            if self.edges[mid] > s:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.total += 1
        self.sum_s += s
        self.max_s = max(self.max_s, s)

    def percentile(self, p: float) -> float:
        """Upper edge of the bucket holding the p-quantile (seconds),
        capped at the exact observed max so p100 is truthful."""
        if self.total == 0:
            return 0.0
        want = max(1, math.ceil(p / 100.0 * self.total))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= want:
                upper = self.edges[i] if i < len(self.edges) else self.max_s
                return min(upper, self.max_s)
        return self.max_s

    def snapshot(self) -> dict:
        return {"count": self.total,
                "mean_ms": (self.sum_s / self.total * 1e3 if self.total
                            else 0.0),
                "p50_ms": self.percentile(50) * 1e3,
                "p99_ms": self.percentile(99) * 1e3,
                "max_ms": self.max_s * 1e3}


class _Gauge:
    __slots__ = ("count", "sum", "max")

    def __init__(self):
        self.count, self.sum, self.max = 0, 0.0, 0.0

    def sample(self, v: float) -> None:
        self.count += 1
        self.sum += v
        self.max = max(self.max, v)

    def snapshot(self) -> dict:
        return {"samples": self.count,
                "mean": self.sum / self.count if self.count else 0.0,
                "max": self.max}


class Metrics:
    """Thread-safe per-bucket counters + histograms + gauges."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Dict[str, int]] = {}
        self._hists: Dict[str, Dict[str, LatencyHistogram]] = {}
        self._gauges: Dict[str, Dict[str, _Gauge]] = {}
        self._extra: Dict[str, dict] = {}     # per-bucket static info

    def _bucket(self, label: str):
        if label not in self._counters:
            self._counters[label] = {name: 0 for name in COUNTERS}
            self._hists[label] = {n: LatencyHistogram() for n in HIST_NAMES}
            self._gauges[label] = {"queue_depth": _Gauge(),
                                   "batch_occupancy": _Gauge()}

    def inc(self, label: str, name: str, n: int = 1) -> None:
        with self._lock:
            self._bucket(label)
            self._counters[label][name] = \
                self._counters[label].get(name, 0) + n

    def observe(self, label: str, hist: str, seconds: float) -> None:
        with self._lock:
            self._bucket(label)
            self._hists[label][hist].record(seconds)

    def sample(self, label: str, gauge: str, value: float) -> None:
        with self._lock:
            self._bucket(label)
            self._gauges[label][gauge].sample(value)

    def annotate(self, label: str, **info) -> None:
        """Attach static per-bucket facts (plan config, degrade state)."""
        with self._lock:
            self._bucket(label)
            self._extra.setdefault(label, {}).update(info)

    def counter(self, label: str, name: str) -> int:
        with self._lock:
            return self._counters.get(label, {}).get(name, 0)

    def snapshot(self) -> dict:
        with self._lock:
            out = {"buckets": {}, "totals": {n: 0 for n in COUNTERS}}
            for lbl in self._counters:
                sec = {"counters": dict(self._counters[lbl]),
                       "latency": {n: h.snapshot()
                                   for n, h in self._hists[lbl].items()},
                       "gauges": {n: g.snapshot()
                                  for n, g in self._gauges[lbl].items()}}
                sec.update(self._extra.get(lbl, {}))
                out["buckets"][lbl] = sec
                for n in COUNTERS:
                    out["totals"][n] += self._counters[lbl].get(n, 0)
            return out

    def to_json(self, **extra) -> str:
        snap = self.snapshot()
        snap.update(extra)
        return json.dumps(snap, indent=2, sort_keys=True)


def start_http(metrics: Metrics, port: int = 0, host: str = "127.0.0.1"):
    """Serve ``GET /metrics`` (JSON snapshot) from a daemon thread.

    Returns ``(httpd, port)``; ``httpd.shutdown()`` stops it.  Port 0
    binds an ephemeral port (tests)."""
    from http.server import BaseHTTPRequestHandler, HTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):               # noqa: N802 — stdlib API
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            body = metrics.to_json().encode()
            self.send_response(200)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):      # keep the server's stdout clean
            pass

    httpd = HTTPServer((host, port), Handler)
    th = threading.Thread(target=httpd.serve_forever, daemon=True,
                          name="repro-serve-metrics")
    th.start()
    return httpd, httpd.server_address[1]
