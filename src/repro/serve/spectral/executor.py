"""Async host↔device pipelining: staging → dispatch → drain.

Three stages, double-buffered through bounded queues, mirroring the
paper's decoupling of data movement from compute on the Tensix — device
dispatch never waits on host-side batch assembly:

1. **Staging** (host): pull a batch from the scheduler, stack/pad payloads
   into the bucket's fixed ``(max_batch, *shape)`` geometry, and
   ``device_put`` the planes.  Runs on the :class:`repro.data.Prefetcher`
   thread — the same bounded prefetch primitive the training data pipeline
   uses — with ``depth`` in-flight batches (2 = double buffering), so
   backpressure propagates from the device up to admission.
2. **Dispatch**: consult the ``serve.step`` fault site, then call the
   bucket's jitted plan.  JAX dispatch is async, so this thread hands the
   in-flight computation straight to the drain queue.
3. **Drain** (host): ``block_until_ready``, pull results back as numpy,
   check in-flight deadlines, and complete each request.

Every batch is padded to the bucket's ``max_batch`` so each bucket
compiles exactly one XLA program — batch-size churn can never trigger
recompiles on the hot path (occupancy is visible in the
``batch_occupancy`` gauge instead).  A dispatch failure degrades the
bucket to its jnp twin plan (once) and retries, mirroring the pre-warm
degrade semantics; the requests still complete.

``threaded=False`` runs the identical stage functions inline through
:meth:`PipelinedExecutor.step` — fully deterministic for the scheduler
edge-case tests (injectable clocks, fault sites) with zero thread
scheduling in the loop.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core.complexmath import SplitComplex
from repro.data.pipeline import Prefetcher
from repro.resilience import faults as _faults

from .scheduler import BucketConfig, Request, ShapeBucketScheduler


@dataclasses.dataclass
class BucketState:
    """A bucket plus its resolved plan and compiled dispatch function."""
    cfg: BucketConfig                  # max_batch resolved (never None)
    plan: plan_lib.FFTPlan
    requested_backend: str
    fn: Optional[Callable] = None      # jitted; built at pre-warm/first use
    degraded: bool = False
    reason: Optional[str] = None

    @property
    def label(self) -> str:
        return self.cfg.label


def derive_max_batch(cfg: BucketConfig, plan: plan_lib.FFTPlan) -> int:
    """The compiled batch size: the configured ``max_batch``, or at least
    8 rounded up to a multiple of the tuned plan's ``block_batch`` so the
    kernel's own batch tiling never pads internally."""
    if cfg.max_batch is not None:
        return cfg.max_batch
    bb = max(1, plan.block_batch)
    return ((max(8, bb) + bb - 1) // bb) * bb


def make_fn(state: BucketState) -> Callable:
    """The bucket's dispatch function: one jit per bucket, compiled for
    the fixed ``(max_batch, *shape)`` geometry."""
    plan = state.plan
    return jax.jit(lambda x, p=plan: p(x))


def zeros_input(cfg: BucketConfig, max_batch: int):
    """A zero input of the bucket's compiled geometry (pre-warm and
    compile-cache warm-up)."""
    dt = jnp.dtype(cfg.dtype)
    shape = (max_batch,) + cfg.shape
    if cfg.kind == "rfft":
        if cfg.inverse:
            half = shape[:-1] + (cfg.shape[-1] // 2 + 1,)
            return SplitComplex(jnp.zeros(half, dt), jnp.zeros(half, dt))
        return jnp.zeros(shape, dt)
    return SplitComplex(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def _payload_planes(req: Request) -> List[np.ndarray]:
    """The host-side planes of a request payload: [re, im] for complex
    inputs, [x] for real ones."""
    p = req.payload
    if isinstance(p, SplitComplex):
        return [np.asarray(p.re), np.asarray(p.im)]
    arr = np.asarray(p)
    if np.iscomplexobj(arr):
        return [np.ascontiguousarray(arr.real),
                np.ascontiguousarray(arr.imag)]
    return [arr]


def _input_is_complex(cfg: BucketConfig) -> bool:
    return cfg.kind == "c2c" or (cfg.kind == "rfft" and cfg.inverse)


@dataclasses.dataclass
class Assembled:
    """One staged batch: device-resident input planes + its requests."""
    state: BucketState
    requests: List[Request]
    x: object                          # SplitComplex or ndarray (device)
    t_staged: float = 0.0


class PipelinedExecutor:
    """Drive scheduler batches through staging/dispatch/drain.

    ``complete(req, status, value, t_done)`` is the server's completion
    callback (status: "completed" | "timed_out_inflight"); the executor
    never touches result bookkeeping itself.
    """

    def __init__(self, states: Dict[str, BucketState],
                 scheduler: ShapeBucketScheduler, metrics, complete,
                 *, depth: int = 2, threaded: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.states = states
        self.scheduler = scheduler
        self.metrics = metrics
        self._complete = complete
        self._depth = depth
        self._threaded = threaded
        self._clock = clock
        self._stop = False
        self._work = threading.Event()    # pokes the staging loop
        self._threads: List[threading.Thread] = []
        self._prefetch: Optional[Prefetcher] = None
        self._drainq: Optional[queue.Queue] = None

    # -- stage functions (shared by threaded and inline modes) ---------------

    def _assemble(self, bucket: BucketConfig,
                  reqs: List[Request]) -> Assembled:
        state = self.states[bucket.label]
        B = state.cfg.max_batch
        dt = np.dtype(bucket.dtype)
        shape = bucket.shape if not (bucket.kind == "rfft" and bucket.inverse)\
            else bucket.shape[:-1] + (bucket.shape[-1] // 2 + 1,)
        nplanes = 2 if _input_is_complex(bucket) else 1
        planes = [np.zeros((B,) + shape, dt) for _ in range(nplanes)]
        for i, req in enumerate(reqs):
            src = _payload_planes(req)
            if len(src) < nplanes:            # real payload into a c2c slot
                src = src + [np.zeros_like(src[0])]
            for plane, s in zip(planes, src):
                # pad-to-bucket: a padded-up request lands in the leading
                # corner, zeros elsewhere (spectral interpolation)
                region = tuple(slice(0, d) for d in s.shape)
                plane[(i,) + region] = s.astype(dt, copy=False)
        if nplanes == 2:
            x = SplitComplex(jax.device_put(planes[0]),
                             jax.device_put(planes[1]))
        else:
            x = jax.device_put(planes[0])
        occupancy = len(reqs) / B
        self.metrics.inc(bucket.label, "batches")
        self.metrics.inc(bucket.label, "batch_items", len(reqs))
        self.metrics.inc(bucket.label, "batch_pad_slots", B - len(reqs))
        self.metrics.sample(bucket.label, "batch_occupancy", occupancy)
        now = self._clock()
        for req in reqs:
            self.metrics.observe(bucket.label, "queue", now - req.t_submit)
        return Assembled(state=state, requests=reqs, x=x, t_staged=now)

    def _call_with_degrade(self, state: BucketState, x):
        """Dispatch on the bucket's plan; one failure degrades the bucket
        to its jnp twin (registry lookup) and retries — the runtime mirror
        of the pre-warm degrade path."""
        if state.fn is None:
            state.fn = make_fn(state)
        try:
            return state.fn(x)
        except Exception as e:      # noqa: BLE001 — resilience boundary
            if state.plan.backend == "jnp":
                raise               # nothing further to degrade to
            cfg = state.cfg
            state.plan = plan_lib.get_plan(
                cfg.shape, dtype=cfg.dtype, inverse=cfg.inverse,
                kind=cfg.kind, backend="jnp")
            state.degraded = True
            state.reason = f"{type(e).__name__}: {e}"
            state.fn = make_fn(state)
            self.metrics.annotate(state.label, degraded=True,
                                  degrade_reason=state.reason)
            return state.fn(x)

    def _dispatch(self, asm: Assembled):
        _faults.check("serve.step", tag=asm.state.label)
        return self._call_with_degrade(asm.state, asm.x)

    def _drain(self, asm: Assembled, y) -> None:
        jax.block_until_ready(y)
        if isinstance(y, SplitComplex):
            planes = [np.asarray(y.re), np.asarray(y.im)]
            results = [SplitComplex(planes[0][i], planes[1][i])
                       for i in range(len(asm.requests))]
        else:
            host = np.asarray(y)
            results = [host[i] for i in range(len(asm.requests))]
        now = self._clock()
        lbl = asm.state.label
        fallback = asm.state.plan.backend != asm.state.requested_backend
        for req, val in zip(asm.requests, results):
            self.metrics.observe(lbl, "service", now - asm.t_staged)
            self.metrics.observe(lbl, "e2e", now - req.t_submit)
            if req.deadline is not None and now >= req.deadline:
                self.metrics.inc(lbl, "timed_out_inflight")
                self._complete(req, "timed_out_inflight", None, now)
                continue
            self.metrics.inc(lbl, "completed")
            if fallback:
                self.metrics.inc(lbl, "fallback_served")
            self._complete(req, "completed", val, now)

    # -- inline mode ---------------------------------------------------------

    def step(self) -> bool:
        """Run one batch through all three stages inline; False when the
        scheduler had nothing to hand out."""
        sel = self.scheduler.next_batch()
        if sel is None:
            return False
        asm = self._assemble(*sel)
        y = self._dispatch(asm)
        self._drain(asm, y)
        return True

    # -- threaded mode -------------------------------------------------------

    def _staged_batches(self):
        """Generator the staging Prefetcher thread consumes: blocks until
        the scheduler has work, yields assembled (device-resident)
        batches."""
        while not self._stop:
            sel = self.scheduler.next_batch()
            if sel is None:
                self._work.wait(timeout=0.005)
                self._work.clear()
                continue
            bucket, reqs = sel
            try:
                asm = self._assemble(bucket, reqs)
            except Exception as e:  # noqa: BLE001 — resilience boundary
                # assembly failed after the batch left the scheduler: the
                # requests must still terminate (exactly-one-terminal
                # guarantee), and staging must survive to serve the rest
                now = self._clock()
                for req in reqs:
                    self._complete(req, "error", e, now)
                continue
            yield asm

    def _dispatch_loop(self) -> None:
        try:
            for asm in self._prefetch:
                try:
                    y = self._dispatch(asm)
                except BaseException as e:  # noqa: BLE001 — to drain
                    y = e
                self._drainq.put((asm, y))
        except BaseException as e:  # noqa: BLE001 — staging died
            self.metrics.annotate(
                "_pipeline", staging_error=f"{type(e).__name__}: {e}")
        finally:
            # unconditional: a staging error the Prefetcher re-raises at
            # next() must still release the drain loop, or every queued
            # request orphans and shutdown() hangs on the joins
            self._drainq.put(None)

    def _drain_loop(self) -> None:
        while True:
            item = self._drainq.get()
            if item is None:
                return
            asm, y = item
            if isinstance(y, BaseException):
                # dispatch raised even after degrade: requests must still
                # terminate — nobody may wait forever on a crashed batch
                now = self._clock()
                for req in asm.requests:
                    self._complete(req, "error", y, now)
                continue
            self._drain(asm, y)

    def start(self) -> None:
        if not self._threaded or self._threads:
            return
        self._drainq = queue.Queue(maxsize=self._depth)
        self._prefetch = Prefetcher(self._staged_batches(),
                                    depth=self._depth)
        t_disp = threading.Thread(target=self._dispatch_loop, daemon=True,
                                  name="repro-serve-dispatch")
        t_drain = threading.Thread(target=self._drain_loop, daemon=True,
                                   name="repro-serve-drain")
        self._threads = [t_disp, t_drain]
        for t in self._threads:
            t.start()

    def poke(self) -> None:
        """Wake the staging loop (the server calls this on admission)."""
        self._work.set()

    def run_pending(self, outstanding: Callable[[], int],
                    timeout_s: Optional[float] = None) -> bool:
        """Drive until ``outstanding()`` hits zero.  Inline mode pumps
        :meth:`step`; threaded mode waits on the pipeline.  Returns False
        on timeout."""
        deadline = None if timeout_s is None \
            else time.monotonic() + timeout_s
        while outstanding() > 0:
            if deadline is not None and time.monotonic() > deadline:
                return False
            if self._threaded:
                self.poke()
                time.sleep(0.002)
            else:
                if not self.step() and outstanding() > 0:
                    # nothing schedulable but work still outstanding can
                    # only mean a sweep retired it concurrently — re-check
                    if self.scheduler.pending() == 0 and outstanding() > 0:
                        return False
        return True

    def shutdown(self) -> None:
        """Stop the stage threads.  The stop flag ends the staging
        generator, which ends the Prefetcher (DONE), which ends the
        dispatch loop (drain sentinel), which ends the drain loop —
        already-staged batches still flow through and complete, so a
        shutdown can never orphan admitted work."""
        self._stop = True
        self._work.set()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = []
