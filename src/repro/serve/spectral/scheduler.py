"""Shape-bucket continuous batching: admission, queueing, batch selection.

Requests arrive ragged — any (kind, shape, direction) mix — and are
admitted into **buckets**, one per plan-registry key.  A bucket is the
serving-side face of an :class:`repro.core.plan.FFTPlan`: its shape fixes
the compiled batch geometry (one XLA program per bucket, batch padded to
``max_batch``) and its tuned ``block_batch`` sizes the kernel tile, so the
scheduler's admission decision IS the plan-registry dispatch decision.

Admission policy for a request matching no configured bucket:

- ``unmatched="reject"`` (default): raise :class:`NoBucketError` — the
  caller sees the rejection synchronously, nothing is queued.
- ``unmatched="pad_up"``: zero-pad the transform dims up to the smallest
  bucket that fits (forward transforms only — zero-padding is the standard
  spectral-interpolation semantic; an inverse half-spectrum has no such
  reading and still rejects).  The client receives the bucket-shape
  spectrum; ``Request.padded`` and the ``padded_up`` counter record it.

Queueing is priority-with-aging: a request's effective priority is
``priority + aging_rate * wait_seconds``, so old low-priority work
eventually outranks fresh high-priority work (no starvation).  For a fixed
``aging_rate`` the pairwise order of two queued requests never flips over
time, so each bucket keeps a heap on the time-invariant key
``priority - aging_rate * t_submit``; cross-bucket selection compares head
scores at "now".  Deadlines expire lazily: every :meth:`next_batch` sweep
retires queued requests past their deadline through the ``on_timeout``
callback before selecting, so a dead request never occupies a batch slot.

The clock is injectable (tests drive a fake clock through admission,
aging, and expiry deterministically).

Thread safety: in threaded serving, client threads call :meth:`admit`
while the staging thread calls :meth:`next_batch` — an internal lock
guards every queue/counter mutation (the expiry sweep's rebuild-and-heapify
would otherwise silently drop a concurrently pushed request, orphaning
it).  ``on_timeout`` callbacks fire *outside* the lock so they may safely
re-enter the scheduler or take the server's own lock.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.plan import PLAN_KINDS


class NoBucketError(ValueError):
    """No configured bucket can serve this request's (kind, shape)."""


@dataclasses.dataclass(frozen=True)
class BucketConfig:
    """One serving shape bucket == one plan-registry key.

    ``max_batch`` is the compiled batch size (requests per dispatch, padded
    up to exactly this — one XLA program per bucket).  ``None`` derives it
    from the resolved plan's tuned ``block_batch`` at server construction
    (at least 8, rounded up to a block_batch multiple so the kernel tile
    never pads internally)."""
    shape: Tuple[int, ...]
    kind: str = "c2c"                 # "c2c" | "rfft"
    inverse: bool = False
    dtype: str = "float32"
    backend: str = "pallas"
    max_batch: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "shape",
                           tuple(int(d) for d in self.shape))
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"kind must be one of {PLAN_KINDS}, "
                             f"got {self.kind!r}")
        if len(self.shape) not in (1, 2):
            raise ValueError(f"1-D or 2-D buckets only, got {self.shape}")

    @property
    def label(self) -> str:
        d = "i" if self.inverse else "f"
        return f"{self.kind}/{d}/{'x'.join(map(str, self.shape))}"

    def plan_spec(self) -> dict:
        """The :func:`repro.core.plan.warm` key spec for this bucket."""
        return {"shape": self.shape, "dtype": self.dtype, "kind": self.kind,
                "inverse": self.inverse, "backend": self.backend}


@dataclasses.dataclass
class Request:
    """One admitted transform request (host-side payload)."""
    rid: object
    payload: object                   # np ndarray / SplitComplex of ndarrays
    kind: str = "c2c"
    inverse: bool = False
    shape: Tuple[int, ...] = ()       # the payload's *transform* shape
    priority: float = 0.0
    deadline: Optional[float] = None  # absolute, on the scheduler clock
    t_submit: float = 0.0
    bucket_label: Optional[str] = None
    padded: bool = False
    seq: int = 0                      # admission order (FIFO tie-break)

    def score(self, now: float, aging_rate: float) -> float:
        return self.priority + aging_rate * (now - self.t_submit)


class ShapeBucketScheduler:
    """Admit ragged requests into shape buckets; hand out dispatch batches.

    ``on_timeout(request)`` fires for every queued request retired by a
    deadline sweep (the server completes it as ``timed_out_queued``).
    ``max_queue`` bounds total queued requests across buckets — admission
    past it returns ``False`` (backpressure; nothing is enqueued).
    """

    def __init__(self, buckets, *, unmatched: str = "reject",
                 max_queue: int = 1024, aging_rate: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 on_timeout: Optional[Callable[[Request], None]] = None):
        if unmatched not in ("reject", "pad_up"):
            raise ValueError(f'unmatched must be "reject" or "pad_up", '
                             f"got {unmatched!r}")
        self.buckets: Dict[str, BucketConfig] = {}
        for b in buckets:
            if b.label in self.buckets:
                raise ValueError(f"duplicate bucket {b.label}")
            if b.max_batch is not None and b.max_batch < 1:
                raise ValueError(f"max_batch must be >= 1, got "
                                 f"{b.max_batch} for {b.label}")
            self.buckets[b.label] = b
        self.unmatched = unmatched
        self.max_queue = max_queue
        self.aging_rate = aging_rate
        self._clock = clock
        self._on_timeout = on_timeout
        self._queues: Dict[str, List[tuple]] = {lbl: []
                                                for lbl in self.buckets}
        self._pending = 0
        self._seq = 0
        # guards _queues/_pending/_seq against admit()-vs-next_batch()
        # races in threaded serving (see module docstring)
        self._mutex = threading.Lock()

    # -- admission -----------------------------------------------------------

    def match(self, kind: str, shape, inverse: bool = False
              ) -> Tuple[Optional[BucketConfig], bool]:
        """(bucket, padded) serving this request shape; (None, False) when
        nothing matches under the configured policy."""
        shape = tuple(int(d) for d in shape)
        for b in self.buckets.values():
            if (b.kind, b.inverse, b.shape) == (kind, inverse, shape):
                return b, False
        if self.unmatched != "pad_up" or inverse:
            return None, False
        fits = [b for b in self.buckets.values()
                if b.kind == kind and not b.inverse
                and len(b.shape) == len(shape)
                and all(bd >= rd for bd, rd in zip(b.shape, shape))]
        if not fits:
            return None, False
        best = min(fits, key=lambda b: (_numel(b.shape), b.shape))
        return best, True

    def admit(self, req: Request) -> bool:
        """Enqueue ``req`` into its bucket.  Raises :class:`NoBucketError`
        when no bucket serves its shape; returns False (backpressure) when
        the global queue bound is hit; True on admission."""
        bucket, padded = self.match(req.kind, req.shape, req.inverse)
        if bucket is None:
            raise NoBucketError(
                f"no bucket serves kind={req.kind!r} shape={req.shape} "
                f"inverse={req.inverse} (policy={self.unmatched!r}; "
                f"configured: {sorted(self.buckets)})")
        req.bucket_label = bucket.label
        req.padded = padded
        with self._mutex:
            if self._pending >= self.max_queue:
                return False
            req.t_submit = self._clock()
            self._seq += 1
            req.seq = self._seq
            # time-invariant heap key: see module docstring
            key = (-(req.priority - self.aging_rate * req.t_submit),
                   req.seq)
            heapq.heappush(self._queues[bucket.label], (key, req))
            self._pending += 1
        return True

    # -- dispatch ------------------------------------------------------------

    def _sweep_expired_locked(self, now: float) -> List[Request]:
        """Retire queued past-deadline requests; the expired list (the
        caller fires ``on_timeout`` after releasing the lock)."""
        expired: List[Request] = []
        for q in self._queues.values():
            live = []
            for key, req in q:
                if req.deadline is not None and now >= req.deadline:
                    self._pending -= 1
                    expired.append(req)
                else:
                    live.append((key, req))
            if len(live) != len(q):
                q[:] = live
                heapq.heapify(q)
        return expired

    def next_batch(self) -> Optional[Tuple[BucketConfig, List[Request]]]:
        """Retire expired queued requests, then dequeue up to ``max_batch``
        requests from the bucket whose head scores highest right now.
        None when nothing is queued."""
        now = self._clock()
        sel = None
        with self._mutex:
            expired = self._sweep_expired_locked(now)
            best_lbl, best_rank = None, None
            for lbl, q in self._queues.items():
                if not q:
                    continue
                head = q[0][1]
                rank = (head.score(now, self.aging_rate), -head.t_submit,
                        -head.seq)
                if best_rank is None or rank > best_rank:
                    best_lbl, best_rank = lbl, rank
            if best_lbl is not None:
                bucket = self.buckets[best_lbl]
                cap = bucket.max_batch or 8
                q = self._queues[best_lbl]
                out = []
                while q and len(out) < cap:
                    out.append(heapq.heappop(q)[1])
                self._pending -= len(out)
                sel = (bucket, out)
        # outside the lock: the server's callback takes its own lock and
        # may re-enter the scheduler (metrics read queue depths)
        if self._on_timeout is not None:
            for req in expired:
                self._on_timeout(req)
        return sel

    def pending(self) -> int:
        with self._mutex:
            return self._pending

    def queue_depths(self) -> Dict[str, int]:
        with self._mutex:
            return {lbl: len(q) for lbl, q in self._queues.items()}


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n
