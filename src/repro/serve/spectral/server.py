"""The spectral server: admission → shape buckets → pipelined executor.

:class:`SpectralServer` composes the subsystem:

- :class:`~repro.serve.spectral.scheduler.ShapeBucketScheduler` admits
  ragged requests into plan-registry shape buckets (reject or pad-up,
  deadlines, priority aging, bounded queue backpressure);
- :func:`repro.core.plan.warm` resolves every bucket's plan up front
  (wisdom-aware, degrade-to-jnp on failure — the ``serve.prewarm`` fault
  site lives inside it);
- :mod:`~repro.serve.spectral.prewarm` compiles each bucket's fixed-shape
  dispatch function before the server reports ready (skippable with
  ``prewarm=False`` to measure cold starts);
- :class:`~repro.serve.spectral.executor.PipelinedExecutor` runs staging/
  dispatch/drain, threaded (production) or inline (deterministic tests);
- :class:`~repro.serve.spectral.metrics.Metrics` snapshots it all as JSON.

Request lifecycle: ``submit`` → queued → in-flight → exactly one terminal
record (completed / timed_out_queued / timed_out_inflight / error), never
more, never none — ``drain()`` + ``close()`` guarantee zero orphans on
shutdown.  ``result(rid)`` blocks until that terminal record exists, then
consumes it (records are evicted once read — no per-request leak).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.core import plan as plan_lib
from repro.core.complexmath import SplitComplex
from repro.resilience import executor as _rexec

from . import prewarm as prewarm_mod
from .executor import BucketState, PipelinedExecutor, derive_max_batch
from .metrics import Metrics, start_http
from .scheduler import (BucketConfig, NoBucketError, Request,
                        ShapeBucketScheduler)

TERMINAL = ("completed", "timed_out_queued", "timed_out_inflight", "error")


@dataclasses.dataclass
class RequestRecord:
    """One request's terminal state."""
    rid: object
    status: str                   # one of TERMINAL
    value: object = None          # SplitComplex / ndarray when completed
    bucket: Optional[str] = None
    padded: bool = False
    latency_s: float = 0.0        # admission -> terminal, on server clock
    error: Optional[BaseException] = None


class SpectralServer:
    def __init__(self, buckets, *, unmatched: str = "reject",
                 max_queue: int = 1024, aging_rate: float = 1.0,
                 depth: int = 2, threaded: bool = True, prewarm: bool = True,
                 tune: bool = False, tune_batch: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.metrics = Metrics()
        self._clock = clock
        self._lock = threading.Lock()
        self._records: Dict[object, RequestRecord] = {}
        self._done: Dict[object, threading.Event] = {}
        self._outstanding = 0
        self._accepting = True
        self._httpd = None

        # resolve every bucket's plan through the shared warm-or-degrade
        # path (one bulk call; serve.prewarm faults fire per key inside)
        buckets = [b if isinstance(b, BucketConfig) else BucketConfig(*b)
                   for b in buckets]
        specs = [b.plan_spec() for b in buckets]
        if tune:
            for b, s in zip(buckets, specs):
                s["tune_batch"] = tune_batch or derive_max_batch(
                    b, plan_lib.get_plan(b.shape, dtype=b.dtype,
                                         kind=b.kind, inverse=b.inverse,
                                         backend="jnp"))
        results = plan_lib.warm(specs, tune=tune)
        self.states: Dict[str, BucketState] = {}
        resolved = []
        for b, wr in zip(buckets, results):
            cfg = dataclasses.replace(b,
                                      max_batch=derive_max_batch(b, wr.plan))
            state = BucketState(cfg=cfg, plan=wr.plan,
                                requested_backend=wr.requested_backend,
                                degraded=wr.degraded, reason=wr.reason)
            self.states[cfg.label] = state
            resolved.append(cfg)
            self.metrics.annotate(
                cfg.label, plan_backend=wr.plan.backend,
                plan_algo=wr.plan.algo, block_batch=wr.plan.block_batch,
                max_batch=cfg.max_batch, degraded=wr.degraded,
                degrade_reason=wr.reason,
                demote_reason=wr.plan.demote_reason)

        self.scheduler = ShapeBucketScheduler(
            resolved, unmatched=unmatched, max_queue=max_queue,
            aging_rate=aging_rate, clock=clock,
            on_timeout=self._queued_timeout)
        self.executor = PipelinedExecutor(
            self.states, self.scheduler, self.metrics, self._finish,
            depth=depth, threaded=threaded, clock=clock)

        self.prewarm_report = None
        if prewarm:
            self.prewarm_report = prewarm_mod.compile_states(
                self.states, metrics=self.metrics)
        self.ready = True
        self.executor.start()

    # -- introspection -------------------------------------------------------

    @property
    def degraded_buckets(self):
        return sorted(lbl for lbl, s in self.states.items() if s.degraded)

    def snapshot(self) -> dict:
        """Metrics snapshot + kernel-path health: each pallas bucket's
        guarded-executor counters (attempts/failures/fallbacks) ride along
        under ``resilience``."""
        snap = self.metrics.snapshot()
        for lbl, state in self.states.items():
            if state.requested_backend != "pallas":
                continue
            key = plan_lib._plan_key(state.cfg.shape, state.cfg.dtype,
                                     state.cfg.inverse, "pallas",
                                     state.cfg.kind)
            snap["buckets"].setdefault(lbl, {})["resilience"] = \
                _rexec.stats(key)
        snap["pending"] = self.scheduler.pending()
        snap["degraded_buckets"] = self.degraded_buckets
        return snap

    def metrics_json(self) -> str:
        import json
        return json.dumps(self.snapshot(), indent=2, sort_keys=True,
                          default=str)

    def serve_metrics_http(self, port: int = 0) -> int:
        """Expose ``GET /metrics`` on a daemon thread; returns the port."""
        self._httpd, port = start_http(self.metrics, port)
        return port

    # -- request lifecycle ---------------------------------------------------

    def submit(self, rid, payload, *, kind: str = "c2c",
               inverse: bool = False, deadline_s: Optional[float] = None,
               priority: float = 0.0) -> bool:
        """Admit one request.  Returns False under backpressure (queue
        bound hit — nothing recorded, retry later); raises
        :class:`NoBucketError` when no bucket serves the shape (the
        ``rejected_nobucket`` counter still ticks); True on admission."""
        if not self._accepting:
            return False
        shape = self._payload_shape(payload, kind, inverse)
        req = Request(rid=rid, payload=payload, kind=kind, inverse=inverse,
                      shape=shape, priority=priority)
        if deadline_s is not None:
            req.deadline = self._clock() + deadline_s
        # the done-event must exist BEFORE admission: a running executor
        # thread may complete the request the instant it is enqueued
        with self._lock:
            if rid in self._done:
                raise ValueError(f"duplicate request id {rid!r}")
            self._done[rid] = threading.Event()
            self._outstanding += 1
        try:
            admitted = self.scheduler.admit(req)
        except NoBucketError:
            with self._lock:
                del self._done[rid]
                self._outstanding -= 1
            self.metrics.inc("_unmatched", "rejected_nobucket")
            raise
        if not admitted:
            with self._lock:
                del self._done[rid]
                self._outstanding -= 1
            self.metrics.inc(req.bucket_label or "_unmatched",
                             "rejected_backpressure")
            return False
        lbl = req.bucket_label
        self.metrics.inc(lbl, "admitted")
        if req.padded:
            self.metrics.inc(lbl, "padded_up")
        self.metrics.sample(lbl, "queue_depth", self.scheduler.pending())
        self.executor.poke()
        return True

    @staticmethod
    def _payload_shape(payload, kind: str, inverse: bool):
        if isinstance(payload, SplitComplex):
            arr_shape = payload.shape
        else:
            arr = np.asarray(payload)
            if kind == "rfft" and not inverse and np.iscomplexobj(arr):
                raise ValueError("rfft forward requests take real payloads")
            arr_shape = arr.shape
        if len(arr_shape) not in (1, 2):
            raise ValueError(f"requests are single 1-D or 2-D transforms "
                             f"(no batch dims), got payload shape "
                             f"{tuple(arr_shape)}")
        shape = tuple(int(d) for d in arr_shape)
        if kind == "rfft" and inverse:
            # payload is the (h, w/2+1) half spectrum; the transform
            # shape is the real-output shape the bucket is keyed on
            shape = shape[:-1] + (2 * (shape[-1] - 1),)
        return shape

    def _finish(self, req: Request, status: str, value, now: float) -> None:
        rec = RequestRecord(
            rid=req.rid, status=status,
            value=value if status == "completed" else None,
            bucket=req.bucket_label, padded=req.padded,
            latency_s=now - req.t_submit,
            error=value if status == "error" else None)
        with self._lock:
            self._records[req.rid] = rec
            self._outstanding -= 1
            ev = self._done.get(req.rid)
        if ev is not None:
            ev.set()

    def _queued_timeout(self, req: Request) -> None:
        self.metrics.inc(req.bucket_label, "timed_out_queued")
        self.metrics.observe(req.bucket_label, "e2e",
                             self._clock() - req.t_submit)
        self._finish(req, "timed_out_queued", None, self._clock())

    def result(self, rid, timeout: Optional[float] = None
               ) -> Optional[RequestRecord]:
        """Block until ``rid`` reaches a terminal state; its record (None
        on wall-clock timeout — the request itself is still in flight).

        Returning the terminal record *consumes* it: the server evicts the
        bookkeeping (a long-lived server would otherwise leak one record —
        potentially a full result array — plus an Event per request), and
        the rid becomes reusable for a fresh submit."""
        with self._lock:
            ev = self._done.get(rid)
        if ev is None:
            raise KeyError(f"unknown request id {rid!r}")
        if not ev.wait(timeout):
            return None
        with self._lock:
            self._done.pop(rid, None)
            return self._records.pop(rid)

    def _n_outstanding(self) -> int:
        with self._lock:
            return self._outstanding

    # -- shutdown ------------------------------------------------------------

    def drain(self, timeout_s: Optional[float] = 60.0) -> bool:
        """Complete every admitted request (terminal records for all)."""
        return self.executor.run_pending(self._n_outstanding, timeout_s)

    def close(self, timeout_s: Optional[float] = 60.0) -> bool:
        """Drain-on-shutdown: stop admission, complete all admitted work,
        then stop the pipeline threads.  Returns False if the drain timed
        out (threads are stopped regardless)."""
        self._accepting = False
        ok = self.drain(timeout_s)
        self.executor.shutdown()
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd = None
        return ok

    def __enter__(self) -> "SpectralServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
