"""Load generation against a :class:`SpectralServer`.

Two canonical disciplines:

- **Closed loop** (:func:`closed_loop`): a fixed window of outstanding
  requests; each completion immediately triggers the next submission.
  Measures *capacity* — sustained throughput at a given concurrency —
  which is what the batched-vs-serial A/B in ``table9_serve`` gates on.
- **Open loop** (:func:`open_loop`): seeded Poisson arrivals at an
  *offered* QPS, submitted on the wall clock regardless of completions —
  the regime a real front door sees, where queueing delay shows up in
  p99 instead of silently throttling the generator (the closed-loop
  coordinated-omission blind spot).

Both draw requests from a seeded shape ``mix`` (ragged by construction)
and report achieved throughput plus p50/p99 latency computed from exact
per-request records — the server's histograms are the production path;
the generator keeps exact samples since it only lives for a benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.complexmath import SplitComplex

from .scheduler import NoBucketError
from .server import SpectralServer


@dataclasses.dataclass(frozen=True)
class MixItem:
    """One request archetype in the offered mix."""
    shape: tuple
    kind: str = "c2c"
    inverse: bool = False
    weight: float = 1.0


def make_payload(rng: np.random.Generator, item: MixItem,
                 dtype=np.float32):
    """A seeded payload of ``item``'s archetype."""
    shape = tuple(item.shape)
    if item.kind == "rfft" and not item.inverse:
        return rng.standard_normal(shape).astype(dtype)
    if item.kind == "rfft" and item.inverse:
        half = shape[:-1] + (shape[-1] // 2 + 1,)
        return SplitComplex(rng.standard_normal(half).astype(dtype),
                            rng.standard_normal(half).astype(dtype))
    return SplitComplex(rng.standard_normal(shape).astype(dtype),
                        rng.standard_normal(shape).astype(dtype))


def _pick(rng: np.random.Generator, mix: Sequence[MixItem]) -> MixItem:
    w = np.asarray([m.weight for m in mix], float)
    return mix[int(rng.choice(len(mix), p=w / w.sum()))]


def _summarize(lat_s: List[float], *, wall_s: float, completed: int,
               timed_out: int, rejected: int, offered_qps: Optional[float]
               ) -> dict:
    lat = np.asarray(sorted(lat_s)) if lat_s else np.asarray([0.0])
    return {
        "offered_qps": offered_qps,
        "achieved_qps": completed / wall_s if wall_s > 0 else 0.0,
        "completed": completed, "timed_out": timed_out,
        "rejected": rejected, "wall_s": wall_s,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_ms": float(lat.mean() * 1e3),
    }


def closed_loop(server: SpectralServer, mix: Sequence[MixItem], *,
                requests: int, concurrency: int = 16, seed: int = 0,
                deadline_s: Optional[float] = None,
                rid_prefix: str = "cl") -> dict:
    """Submit ``requests`` total with at most ``concurrency`` outstanding
    (wait on the oldest, FIFO); returns the summary dict."""
    rng = np.random.default_rng(seed)
    lat, timed_out, rejected, completed = [], 0, 0, 0
    window: List[object] = []
    t0 = time.perf_counter()

    def reap(rid):
        nonlocal timed_out, completed
        rec = server.result(rid)
        if rec.status == "completed":
            completed += 1
            lat.append(rec.latency_s)
        else:
            timed_out += 1

    for i in range(requests):
        item = _pick(rng, mix)
        rid = f"{rid_prefix}-{seed}-{i}"
        payload = make_payload(rng, item)
        while not server.submit(rid, payload, kind=item.kind,
                                inverse=item.inverse,
                                deadline_s=deadline_s):
            if window:                 # backpressure: reap before retrying
                reap(window.pop(0))
            else:
                time.sleep(0.001)
        window.append(rid)
        while len(window) >= concurrency:
            reap(window.pop(0))
    while window:
        reap(window.pop(0))
    wall = time.perf_counter() - t0
    return _summarize(lat, wall_s=wall, completed=completed,
                      timed_out=timed_out, rejected=rejected,
                      offered_qps=None)


def open_loop(server: SpectralServer, mix: Sequence[MixItem], *,
              qps: float, duration_s: float, seed: int = 0,
              deadline_s: Optional[float] = None,
              rid_prefix: str = "ol") -> dict:
    """Seeded Poisson arrivals at ``qps`` for ``duration_s`` wall seconds;
    drains outstanding work before summarizing.  Backpressured and
    unmatched submissions count as ``rejected`` (the generator never
    retries — open loop measures the server, not the client's patience)."""
    rng = np.random.default_rng(seed)
    rids: List[object] = []
    rejected = 0
    t0 = time.perf_counter()
    next_at = t0
    i = 0
    while True:
        now = time.perf_counter()
        if now - t0 >= duration_s:
            break
        if now < next_at:
            time.sleep(min(next_at - now, 0.01))
            continue
        next_at += rng.exponential(1.0 / qps)
        item = _pick(rng, mix)
        rid = f"{rid_prefix}-{seed}-{i}"
        i += 1
        try:
            if server.submit(rid, make_payload(rng, item), kind=item.kind,
                             inverse=item.inverse, deadline_s=deadline_s):
                rids.append(rid)
            else:
                rejected += 1
        except NoBucketError:
            rejected += 1
    server.drain()
    wall = time.perf_counter() - t0
    lat, timed_out, completed = [], 0, 0
    for rid in rids:
        rec = server.result(rid)
        if rec.status == "completed":
            completed += 1
            lat.append(rec.latency_s)
        else:
            timed_out += 1
    return _summarize(lat, wall_s=wall, completed=completed,
                      timed_out=timed_out, rejected=rejected,
                      offered_qps=qps)
