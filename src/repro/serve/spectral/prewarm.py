"""Startup pre-warm: every configured bucket compiles before "ready".

FFTW's lesson — amortised planning only pays when a long-lived server
reuses plans across requests — applied at two levels:

1. **Plan resolution** through :func:`repro.core.plan.warm` (the shared
   "compile these plans now or degrade" path): wisdom from
   ``$REPRO_FFT_WISDOM`` has already auto-loaded tuned winners at import,
   so every bucket's (algo, backend, block_batch) is decided before the
   first request.  The ``serve.prewarm`` fault site fires per bucket
   inside ``warm`` — an injected failure degrades that bucket to its jnp
   twin instead of killing startup, integrating with the same resilience
   policy the guarded executor uses.
2. **XLA compilation**: each bucket's jitted dispatch function executes
   once on zeros of its fixed ``(max_batch, *shape)`` geometry, so no
   client request ever pays the compile.  A compile/execute failure
   degrades the bucket (jnp twin, recompile) rather than raising; if even
   the twin fails, the bucket is recorded as failed in the report and the
   runtime degrade path retries at first dispatch — startup never crashes.

:func:`compile_states` returns a :class:`PrewarmReport` with per-bucket
compile seconds and degrade reasons — the benchmark's cold-p99 comparison
reads straight off it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax

from repro.core import plan as plan_lib

from .executor import BucketState, make_fn, zeros_input


@dataclasses.dataclass(frozen=True)
class PrewarmEntry:
    label: str
    backend: str                  # the backend that will actually serve
    algo: str
    block_batch: int
    max_batch: int
    tuned: bool
    degraded: bool
    reason: Optional[str]
    compile_s: float


@dataclasses.dataclass(frozen=True)
class PrewarmReport:
    entries: List[PrewarmEntry]
    wisdom_entries: int           # plans installed from $REPRO_FFT_WISDOM
    total_s: float

    @property
    def degraded(self) -> List[str]:
        return [e.label for e in self.entries if e.degraded]


def compile_states(states: Dict[str, BucketState],
                   metrics=None) -> PrewarmReport:
    """Compile every bucket's dispatch function (execute-once-on-zeros).

    Buckets whose plan resolution already degraded compile their jnp twin;
    a *compile* failure on a healthy pallas plan degrades it here, the
    same never-crash contract as :func:`repro.core.plan.warm`."""
    t_start = time.perf_counter()
    entries = []
    for label, state in states.items():
        t0 = time.perf_counter()
        x = zeros_input(state.cfg, state.cfg.max_batch)
        try:
            state.fn = make_fn(state)
            jax.block_until_ready(state.fn(x))
        except Exception as e:      # noqa: BLE001 — degrade, never crash
            reason = f"{type(e).__name__}: {e}"
            try:
                cfg = state.cfg
                state.plan = plan_lib.get_plan(
                    cfg.shape, dtype=cfg.dtype, inverse=cfg.inverse,
                    kind=cfg.kind, backend="jnp")
                state.degraded = True
                state.reason = reason
                state.fn = make_fn(state)
                jax.block_until_ready(state.fn(x))
            except Exception as e2:  # noqa: BLE001 — still never crash
                # even the jnp twin failed to compile/execute: record the
                # bucket as failed and keep starting up — the runtime
                # degrade path retries at first dispatch
                state.degraded = True
                state.reason = (f"{reason}; jnp twin failed: "
                                f"{type(e2).__name__}: {e2}")
                state.fn = None
        compile_s = time.perf_counter() - t0
        entry = PrewarmEntry(
            label=label, backend=state.plan.backend, algo=state.plan.algo,
            block_batch=state.plan.block_batch,
            max_batch=state.cfg.max_batch, tuned=state.plan.tuned,
            degraded=state.degraded, reason=state.reason,
            compile_s=compile_s)
        entries.append(entry)
        if metrics is not None:
            metrics.annotate(label, plan_backend=state.plan.backend,
                             plan_algo=state.plan.algo,
                             block_batch=state.plan.block_batch,
                             max_batch=state.cfg.max_batch,
                             degraded=state.degraded,
                             degrade_reason=state.reason,
                             prewarm_compile_s=compile_s)
    return PrewarmReport(entries=entries,
                         wisdom_entries=plan_lib.WISDOM_AUTOLOADED,
                         total_s=time.perf_counter() - t_start)
