"""internvl2-76b — 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
InternViT + Llama-3-70B-style backbone.  [arXiv:2404.16821; unverified]

VLM: the InternViT frontend is a STUB per the assignment — training/prefill
consume precomputed patch embeddings (input_mode="embeddings"); decode
generates text tokens through the vocab head.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn_mlp",),
    repeat=80,
    rope_theta=500_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    input_mode="embeddings",
    dtype="bfloat16",
    tie_embeddings=False,
)
