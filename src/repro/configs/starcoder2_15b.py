"""starcoder2-15b — 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
GQA + RoPE, LayerNorm, GELU MLP with bias.  [arXiv:2402.19173; hf]

Kept full-attention per the assignment's tagging ([dense] "GQA, RoPE"), so
`long_500k` is skipped for this arch (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    block_pattern=("attn_mlp",),
    repeat=40,
    rope_theta=100_000.0,
    mlp_type="gelu",
    mlp_bias=True,
    norm_type="layernorm",
    dtype="bfloat16",
    tie_embeddings=True,
)
