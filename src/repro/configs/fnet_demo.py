"""fnet_demo — the paper's technique inside a transformer: FNet-style
Fourier token mixing (repro.core.spectral) replaces attention.  Used by the
end-to-end training example; not part of the assigned pool.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="fnet_demo",
    family="dense",
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=32000,
    block_pattern=("fourier_mlp",),
    repeat=12,
    token_mixing="fourier",
    mlp_type="gelu",
    norm_type="layernorm",
    tie_embeddings=True,
)
