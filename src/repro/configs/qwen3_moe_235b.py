"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4) MoE 128e top-8,
expert d_ff=1536, vocab=151936.  [hf:Qwen/Qwen3-30B-A3B family; hf]

Qwen3 specifics: head_dim=128 (decoupled from d_model/n_heads), qk-norm,
no qkv bias, every layer MoE, SwiGLU experts, RMSNorm, rope_theta=1e6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    block_pattern=("attn_moe",),
    repeat=94,
    n_experts=128,
    n_experts_active=8,
    moe_d_ff=1536,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    dtype="bfloat16",
    tie_embeddings=False,
)
