"""nemotron-4-340b — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.  GQA + squared-ReLU MLP.  [arXiv:2402.16819; unverified]

The memory monster of the pool: ~340B params.  Uses the `fsdp2d` sharding
profile (params sharded over data AND model axes, ZeRO-3 style) plus bf16
params to fit the v5e HBM budget — see launch/sharding.py and
EXPERIMENTS.md §Dry-run.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    block_pattern=("attn_mlp",),
    repeat=96,
    rope_theta=10_000.0,
    mlp_type="relu2",
    norm_type="layernorm",
    tie_embeddings=False,
    dtype="bfloat16",
)
