"""xlstm-350m — 24 blocks d_model=1024 4H, sLSTM + mLSTM mix, d_ff=0 (the
blocks carry their own up/down projections), vocab=50304.
[arXiv:2405.04517; unverified]

Block ratio ~[5:1] mLSTM:sLSTM (the paper's large models are mLSTM-heavy).
4 heads do not divide the 16-wide model axis: `fsdp` sharding profile.
Fully recurrent -> long_500k decode runs with O(1) state.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 5 + ("slstm",),
    repeat=4,                        # 24 blocks
    mlstm_chunk=128,
    norm_type="layernorm",
    tie_embeddings=True,
)
