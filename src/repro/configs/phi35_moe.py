"""phi3.5-moe-42b-a6.6b — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    block_pattern=("attn_moe",),
    repeat=32,
    n_experts=16,
    n_experts_active=2,
    moe_d_ff=6400,
    rope_theta=10_000.0,
    mlp_type="swiglu",
    norm_type="layernorm",
    dtype="bfloat16",
    tie_embeddings=False,
)
