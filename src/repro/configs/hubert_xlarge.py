"""hubert-xlarge — 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504.
Encoder-only audio transformer (wav2vec2 arch).  [arXiv:2106.07447; unverified]

The CNN waveform frontend is a STUB per the assignment: inputs are
precomputed frame embeddings (input_mode="embeddings").  Encoder-only: no
decode step — decode_32k / long_500k cells are skipped with reason
(DESIGN.md §Arch-applicability).  The natural FFT frontend (STFT features
via repro.core) is demonstrated in examples/audio_frontend.py.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    block_pattern=("attn_mlp",),
    repeat=48,
    causal=False,
    mlp_type="gelu",
    mlp_bias=True,
    norm_type="layernorm",
    input_mode="embeddings",
    tie_embeddings=False,
    vocab_pad_multiple=128,          # 504 -> 512 (16-way shardable)
)
