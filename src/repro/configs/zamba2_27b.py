"""zamba2-2.7b — 54 blocks d_model=2560, Mamba2 mixers + a shared
attention+MLP block applied every 6th position, ssm_state=64, 32H MHA,
d_ff=10240, vocab=32000.  [arXiv:2411.15242; hf]

Simplification noted in DESIGN.md: the shared block reuses one set of
weights at every application (true to Zamba2), but we omit the per-
application LoRA deltas and the concatenated-embedding re-injection.

This is the arch most representative of the paper's technique: its Mamba2
conv branch can run through repro.core.fftconv, and hybrid 500k-context
decode stresses the data-movement trade-offs the paper studies.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("mamba2",) * 6 + ("shared_attn",),
    repeat=9,                        # 54 mamba2 blocks + 9 shared-attn apps
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_theta=10_000.0,
    mlp_type="gelu",
    norm_type="rmsnorm",
    tie_embeddings=True,
)
