"""ssm_demo — a small pure-Mamba2 stack whose causal-conv branch runs
through the fused spectral-convolution plan (``use_fft_conv=True``,
``fft_backend="pallas"``): the model-stack consumer of
``kind="conv_causal"`` plans.  Used by the training example's ``--ssm``
mode and the CI model-smoke step; not part of the assigned pool.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="ssm_demo",
    family="ssm",
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=32000,
    block_pattern=("mamba2",),
    repeat=4,
    ssm_state=32,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=64,
    mlp_type="gelu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    use_fft_conv=True,
    fft_backend="pallas",
)
