"""h2o-danube-1.8b — 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
Llama+Mistral mix with sliding-window attention.  [arXiv:2401.16818; hf]

The 4096-token sliding window makes this arch sub-quadratic: `long_500k`
decode runs with a bounded ring KV cache (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    block_pattern=("attn_mlp",),
    repeat=24,
    sliding_window=4096,
    rope_theta=10_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=False,
)
