"""qwen1.5-4b — 40L d_model=2560 20H (kv=20, i.e. MHA) d_ff=6912
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]

20 heads do not divide the 16-wide model axis: this arch uses the `fsdp`
sharding profile (see launch/sharding.py).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    block_pattern=("attn_mlp",),
    repeat=40,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=True,
)
