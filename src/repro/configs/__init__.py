"""Config registry: ``--arch <id>`` resolves here.

Each assigned architecture carries its own shape set (the assignment table);
``shapes_for(arch)`` returns the runnable cells and ``SKIPPED_CELLS`` records
the skipped ones with reasons (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

from . import (fnet_demo, h2o_danube_18b, hubert_xlarge, internvl2_76b,
               nemotron4_340b, phi35_moe, qwen15_4b, qwen3_moe_235b,
               ssm_demo, starcoder2_15b, xlstm_350m, zamba2_27b)

REGISTRY: Dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (qwen3_moe_235b, phi35_moe, internvl2_76b, h2o_danube_18b,
              nemotron4_340b, qwen15_4b, starcoder2_15b, zamba2_27b,
              hubert_xlarge, xlstm_350m, fnet_demo, ssm_demo)
}

ASSIGNED = [
    "qwen3-moe-235b-a22b", "phi3.5-moe-42b-a6.6b", "internvl2-76b",
    "h2o-danube-1.8b", "nemotron-4-340b", "qwen1.5-4b", "starcoder2-15b",
    "zamba2-2.7b", "hubert-xlarge", "xlstm-350m",
]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    shape: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# archs whose mixer is sub-quadratic (SSM / hybrid / sliding-window):
# these run long_500k; pure full-attention archs skip it.
SUBQUADRATIC = {"zamba2-2.7b", "xlstm-350m", "h2o-danube-1.8b"}
ENCODER_ONLY = {"hubert-xlarge"}

SKIPPED_CELLS: List[Tuple[str, str, str]] = []   # (arch, shape, reason)
for _a in ASSIGNED:
    if _a in ENCODER_ONLY:
        SKIPPED_CELLS.append((_a, "decode_32k", "encoder-only: no decode step"))
        SKIPPED_CELLS.append((_a, "long_500k", "encoder-only: no decode step"))
    elif _a not in SUBQUADRATIC:
        SKIPPED_CELLS.append((_a, "long_500k",
                              "pure full-attention arch: 524k dense KV cache "
                              "out of scope per assignment"))


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def shapes_for(arch: str) -> List[ShapeCell]:
    skipped = {s for a, s, _ in SKIPPED_CELLS if a == arch}
    return [c for n, c in SHAPES.items() if n not in skipped]


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) cell; skipped ones only if requested."""
    out = []
    for a in ASSIGNED:
        skipped = {s for aa, s, _ in SKIPPED_CELLS if aa == a}
        for n, c in SHAPES.items():
            if n in skipped and not include_skipped:
                continue
            out.append((a, c))
    return out
