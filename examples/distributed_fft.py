"""Distributed pencil FFT demo on 8 (emulated) devices.

Shows the paper's Section 5 pattern at multi-device scale: local row FFTs,
all_to_all global transpose, local column FFTs — plus the chunked-overlap
and hierarchical multi-pod schedules.

    python examples/distributed_fft.py        (sets its own XLA_FLAGS)
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                       # noqa: E402
import jax                                               # noqa: E402
import jax.numpy as jnp                                  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.complexmath import SplitComplex, from_complex, to_complex  # noqa: E402
from repro.dist import pencil                            # noqa: E402
from repro.launch.mesh import make_mesh                  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    H = W = 512
    x = (rng.standard_normal((H, W))
         + 1j * rng.standard_normal((H, W))).astype(np.complex64)
    ref = np.fft.fft2(x)

    mesh = make_mesh((8,), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    z = from_complex(jnp.asarray(x))
    z = SplitComplex(jax.device_put(z.re, sh), jax.device_put(z.im, sh))

    out = pencil.pfft2(z, mesh, "data")                 # 1 all_to_all
    err = np.abs(np.asarray(to_complex(out)).T - ref).max() / np.abs(ref).max()
    print(f"pfft2 (single all_to_all)        rel err {err:.2e}")

    out = pencil.pfft2(z, mesh, "data", chunks=4)       # overlapped schedule
    err = np.abs(np.asarray(to_complex(out)).T - ref).max() / np.abs(ref).max()
    print(f"pfft2 (4-chunk overlap schedule) rel err {err:.2e}")

    mesh2 = make_mesh((2, 4), ("pod", "data"))
    sh2 = NamedSharding(mesh2, P(("pod", "data"), None))
    z2 = SplitComplex(jax.device_put(jnp.real(jnp.asarray(x)), sh2),
                      jax.device_put(jnp.imag(jnp.asarray(x)), sh2))
    out = pencil.pfft2_hierarchical(z2, mesh2)          # two-hop multi-pod
    err = np.abs(np.asarray(to_complex(out)).T - ref).max() / np.abs(ref).max()
    print(f"pfft2_hierarchical (2 pods x 4)  rel err {err:.2e}")

    # 3-D pencil FFT over a 2-D process grid
    mesh3 = make_mesh((2, 4), ("data", "model"))
    X = Y = 32
    Z = 64
    x3 = (rng.standard_normal((X, Y, Z))
          + 1j * rng.standard_normal((X, Y, Z))).astype(np.complex64)
    sh3 = NamedSharding(mesh3, P("data", "model", None))
    z3 = from_complex(jnp.asarray(x3))
    z3 = SplitComplex(jax.device_put(z3.re, sh3), jax.device_put(z3.im, sh3))
    out3 = pencil.pfft3(z3, mesh3)                       # (Z, Y, X) pencils
    got3 = np.asarray(to_complex(out3)).transpose(2, 1, 0)
    ref3 = np.fft.fftn(x3)
    err = np.abs(got3 - ref3).max() / np.abs(ref3).max()
    print(f"pfft3 (2x4 process grid)         rel err {err:.2e}")

    # one giant distributed 1-D FFT
    n = 1 << 16
    v = (rng.standard_normal(n) + 1j * rng.standard_normal(n)) \
        .astype(np.complex64)
    mesh1 = make_mesh((8,), ("data",))
    sh1 = NamedSharding(mesh1, P("data"))
    vz = from_complex(jnp.asarray(v))
    vz = SplitComplex(jax.device_put(vz.re, sh1), jax.device_put(vz.im, sh1))
    out = pencil.pfft1d(vz, mesh1, "data")
    back = pencil.pfft1d(out, mesh1, "data", inverse=True)
    err = np.abs(np.asarray(to_complex(back)) - v).max()
    print(f"pfft1d 65536 roundtrip           max err {err:.2e}")


if __name__ == "__main__":
    main()
