"""Batched spectral serving example: a ragged fft2/rfft2 request mix
through the continuous-batching :class:`repro.serve.spectral.SpectralServer`
(shape-bucket scheduling, pipelined host<->device execution, pre-warmed
plans), finishing with the per-bucket latency snapshot.

    PYTHONPATH=src python examples/serve_batched.py
"""
import argparse
import json

import numpy as np

from repro.serve.spectral import (BucketConfig, MixItem, SpectralServer,
                                  closed_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--concurrency", type=int, default=16)
    ap.add_argument("--pad-up", action="store_true",
                    help="admit off-bucket shapes by zero-padding up")
    args = ap.parse_args()

    buckets = [
        BucketConfig((64, 64), kind="c2c"),
        BucketConfig((64, 64), kind="rfft"),
        BucketConfig((128, 128), kind="c2c"),
    ]
    # a ragged mix: two bucket shapes, complex and real transforms; with
    # --pad-up a 48x48 archetype rides the 64x64 bucket (padded_up counter)
    mix = [MixItem((64, 64), "c2c"), MixItem((64, 64), "rfft"),
           MixItem((128, 128), "c2c", weight=0.5)]
    if args.pad_up:
        mix.append(MixItem((48, 48), "c2c", weight=0.5))

    with SpectralServer(buckets,
                        unmatched="pad_up" if args.pad_up else "reject"
                        ) as srv:
        rep = srv.prewarm_report
        print(f"[serve] pre-warm: {len(rep.entries)} buckets in "
              f"{rep.total_s:.2f}s (wisdom entries: {rep.wisdom_entries})")
        for e in rep.entries:
            print(f"[serve]   {e.label}: backend={e.backend} "
                  f"algo={e.algo} max_batch={e.max_batch} "
                  f"compile={e.compile_s:.2f}s"
                  + (f" DEGRADED ({e.reason})" if e.degraded else ""))

        res = closed_loop(srv, mix, requests=args.requests,
                          concurrency=args.concurrency, seed=0)
        print(f"[serve] {res['completed']}/{args.requests} completed in "
              f"{res['wall_s']:.2f}s ({res['achieved_qps']:.1f} req/s), "
              f"p50={res['p50_ms']:.1f}ms p99={res['p99_ms']:.1f}ms")

        snap = srv.snapshot()
        for lbl in sorted(snap["buckets"]):
            b = snap["buckets"][lbl]
            c, e2e = b["counters"], b["latency"]["e2e"]
            if not c["admitted"]:
                continue
            print(f"[serve] {lbl}: admitted={c['admitted']} "
                  f"completed={c['completed']} padded_up={c['padded_up']} "
                  f"fallback={c['fallback_served']} "
                  f"batches={c['batches']} "
                  f"occupancy={b['gauges']['batch_occupancy']['mean']:.2f} "
                  f"e2e p50={e2e['p50_ms']:.1f}ms p99={e2e['p99_ms']:.1f}ms")
        print("[serve] totals:", json.dumps(snap["totals"], sort_keys=True))


if __name__ == "__main__":
    main()
