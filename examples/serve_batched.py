"""Batched serving example: the slot-based engine decodes a stream of
requests for a reduced h2o-danube (SWA ring cache exercised).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys

from repro.launch import serve as serve_mod


def main():
    sys.argv = [sys.argv[0], "--arch", "h2o-danube-1.8b", "--reduced",
                "--requests", "6", "--batch-size", "3", "--max-new", "12"] \
        + sys.argv[1:]
    serve_mod.main()


if __name__ == "__main__":
    main()
