"""STFT audio frontend built from repro.core — the natural FFT use for the
hubert-xlarge stub (the assignment stubs the waveform frontend; this shows
the paper's kernel producing the frame features such a frontend computes).

    PYTHONPATH=src python examples/audio_frontend.py [--backend pallas]
"""
import sys

import jax.numpy as jnp
import numpy as np

import repro.core as rc


def stft(wave: jnp.ndarray, frame: int = 512, hop: int = 160,
         backend: str = "jnp"):
    """Frames (..., T) -> magnitude spectrogram (..., n_frames, frame//2+1).

    The per-frame rfft routes through the plan registry; ``backend="pallas"``
    requests the kernel path for the (frame,) rfft key (demoting with a
    registry-visible reason when no kernel schedule exists)."""
    t = wave.shape[-1]
    n_frames = 1 + (t - frame) // hop
    idx = np.arange(frame)[None, :] + hop * np.arange(n_frames)[:, None]
    frames = wave[..., idx]                                # gather windows
    window = jnp.asarray(np.hanning(frame), jnp.float32)
    spec = rc.rfft(frames * window, backend=backend)
    return jnp.sqrt(spec.re ** 2 + spec.im ** 2)


def main():
    backend = "jnp"
    if "--backend" in sys.argv:
        backend = sys.argv[sys.argv.index("--backend") + 1]
    rng = np.random.default_rng(0)
    sr = 16_000
    t = np.arange(sr, dtype=np.float32) / sr
    wave = (np.sin(2 * np.pi * 440 * t) + 0.5 * np.sin(2 * np.pi * 1320 * t)
            + 0.1 * rng.standard_normal(sr).astype(np.float32))
    mag = stft(jnp.asarray(wave), backend=backend)
    print(f"waveform {wave.shape} -> spectrogram {mag.shape}")
    peaks = np.asarray(jnp.argmax(mag, axis=-1))
    freq_resolution = sr / 512
    print(f"dominant bin ~{np.median(peaks) * freq_resolution:.0f} Hz "
          f"(expected 440 Hz)")
    ref = np.abs(np.fft.rfft(np.asarray(
        wave[: 512] * np.hanning(512))))
    err = np.abs(np.asarray(mag[0]) - ref).max() / ref.max()
    print(f"first-frame vs numpy rel err: {err:.2e}")
    # these (n_frames, 257) features are exactly the `embeds` input the
    # hubert-xlarge config consumes (after a linear projection to d_model)


if __name__ == "__main__":
    main()
