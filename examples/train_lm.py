"""End-to-end training driver example: a ~15M-param FNet-style LM (the
paper's FFT as the token mixer) trained for a few hundred steps on CPU,
with checkpointing and resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

``--ssm`` swaps in the small Mamba2 config whose causal-conv branch runs
through the fused spectral-convolution plan (``ssm_demo``:
``use_fft_conv=True``, ``fft_backend="pallas"``); pair it with
``--fft-backend jnp`` for a tokens/sec A/B of the conv backends — the
driver prints steady-state tokens/sec either way.

This drives the same launcher a cluster run uses:
    python -m repro.launch.train --arch fnet_demo --steps 200 ...
Scale up by dropping --reduced and binding --mesh single|multi.
"""
import sys

from repro.launch import train as train_mod


def main():
    extra = sys.argv[1:]
    if "--ssm" in extra:
        extra = [a for a in extra if a != "--ssm"]
        argv = ["--arch", "ssm_demo", "--reduced",
                "--steps", "60", "--seq-len", "128", "--global-batch", "8",
                "--lr", "3e-3", "--ckpt-dir", "runs/ckpt_example_ssm",
                "--ckpt-every", "0", "--log-every", "20"]
    else:
        argv = ["--arch", "fnet_demo", "--reduced",
                "--steps", "200", "--seq-len", "128", "--global-batch", "8",
                "--lr", "3e-3", "--ckpt-dir", "runs/ckpt_example",
                "--ckpt-every", "100", "--log-every", "20"]
    sys.argv = [sys.argv[0]] + argv + extra
    train_mod.main()


if __name__ == "__main__":
    main()
