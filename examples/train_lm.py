"""End-to-end training driver example: a ~15M-param FNet-style LM (the
paper's FFT as the token mixer) trained for a few hundred steps on CPU,
with checkpointing and resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

This drives the same launcher a cluster run uses:
    python -m repro.launch.train --arch fnet_demo --steps 200 ...
Scale up by dropping --reduced and binding --mesh single|multi.
"""
import sys

from repro.launch import train as train_mod


def main():
    argv = ["--arch", "fnet_demo", "--reduced",
            "--steps", "200", "--seq-len", "128", "--global-batch", "8",
            "--lr", "3e-3", "--ckpt-dir", "runs/ckpt_example",
            "--ckpt-every", "100", "--log-every", "20"]
    extra = sys.argv[1:]
    sys.argv = [sys.argv[0]] + argv + extra
    train_mod.main()


if __name__ == "__main__":
    main()
