"""Quickstart: the public FFT API in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

import repro.core as rc


def main():
    rng = np.random.default_rng(0)

    # --- 1-D FFT, algorithm auto-selected (four-step matmul at this size)
    x = rng.standard_normal(4096).astype(np.float32)
    z = rc.from_real(jnp.asarray(x))
    spectrum = rc.fft(z)
    err = np.abs(np.asarray(rc.to_complex(spectrum)) - np.fft.fft(x)).max()
    print(f"1-D fft (auto)            max err vs numpy: {err:.2e}")

    # --- pick algorithms explicitly: the paper's ladder
    for algo in ("cooley_tukey", "cooley_tukey_fused", "stockham",
                 "four_step"):
        got = rc.fft(z, algo=algo)
        e = np.abs(np.asarray(rc.to_complex(got)) - np.fft.fft(x)).max()
        print(f"1-D fft ({algo:20s}) max err: {e:.2e}")

    # --- FFTW-style plans (baked twiddles/dispatch, jit-friendly)
    plan = rc.plan_fft(4096)
    print(f"plan for n=4096 resolved to algo={plan.algo}")

    # --- real-input transforms (half spectrum)
    xf = rc.rfft(jnp.asarray(x))
    print(f"rfft output bins: {xf.shape[-1]} (= n/2+1)")

    # --- 2-D FFT (the paper's Section 5 workload)
    img = rng.standard_normal((256, 256)).astype(np.float32)
    f2 = rc.fft2(rc.from_real(jnp.asarray(img)))
    err = np.abs(np.asarray(rc.to_complex(f2)) - np.fft.fft2(img)).max() \
        / np.abs(np.fft.fft2(img)).max()
    print(f"2-D fft 256x256           rel err: {err:.2e}")

    # --- FFT long convolution (the LM integration point)
    sig = rng.standard_normal((2, 512)).astype(np.float32)
    ker = rng.standard_normal((2, 64)).astype(np.float32)
    y = rc.fft_conv(jnp.asarray(sig), jnp.asarray(ker))
    ref = np.stack([np.convolve(s, k)[:512] for s, k in zip(sig, ker)])
    print(f"fft_conv causal           max err: {np.abs(np.asarray(y)-ref).max():.2e}")

    # --- Pallas TPU kernels (interpret mode on CPU)
    from repro.kernels import ops
    zz = rc.SplitComplex(jnp.asarray(rng.standard_normal((4, 1024)),
                                     jnp.float32),
                         jnp.zeros((4, 1024), jnp.float32))
    k_out = ops.fft_stockham(zz)
    ref_k = np.fft.fft(np.asarray(zz.re))
    print(f"pallas stockham kernel    max err: "
          f"{np.abs(np.asarray(rc.to_complex(k_out)) - ref_k).max():.2e}")


if __name__ == "__main__":
    main()
