"""Table 10 — GEMM-first FFT core: four-step matmul contractions vs the
Stockham-stage schedule, and the fused 3-D brick kernel.

PR 9 rebuilt the complex fused 2-D kernel around one level of Bailey
four-step GEMM contractions (dense DFT leaves <= 256, transpose absorbed
into the matmul operand order) and added a fused 3-D path.  This table is
the evidence:

- 2-D: the GEMM kernel (algo="fused") against the demoted Stockham-stage
  oracle (algo="fused_stockham"), interleaved A/B on the same plan inputs
  (the ratio gates the acceptance criterion: GEMM >= 1.1x at the largest
  benched size, rel err vs fp64 numpy <= 1e-6 in fp32);
- 3-D: the fused brick kernel against the jnp fft3 row-column schedule
  (acceptance: fused >= 1.3x at the largest benched size);
- model-predicted vs measured (operand-counted) HBM traffic for both GEMM
  kernels — the counted bytes come from the kernel's REAL operand buffers
  (gemm_tables + in/out planes), independent of repro.tt.trace, so a model
  drift shows up as model_vs_measured != 1;
- VMEM high-water verdicts from trace_plan: fp32 GEMM at 1024^2 does NOT
  fit 16 MiB, the bf16 variants (plain and compensated) do;
- bf16 precision rows: the split-twiddle compensated variant's rel err vs
  fp64 next to the plain bf16 cast (compensated <= 5e-3, pinned in tests).

All rows land in BENCH_gemm_fft.json (section "table10").
``--smoke`` runs the smallest 2-D/3-D case only (CI).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clear_plan_cache, get_plan, to_complex
from repro.core.complexmath import SplitComplex, from_complex
from repro.tt import trace as tttrace
from .common import emit, time_fn_pair, write_json

BENCH_JSON = "BENCH_gemm_fft.json"


def measured_traffic_bytes_2d(h: int, w: int, *, dtype=np.float32,
                              variant: str = "plain") -> int:
    """HBM bytes the GEMM 2-D kernel stages per image, counted from its
    real operand buffers: the 12 four-step table arrays gemm_tables
    actually builds, plus the split-complex in/out planes."""
    from repro.kernels.fft2d_gemm import gemm_tables
    tables = sum(np.asarray(t).nbytes
                 for t in gemm_tables(h, w, False, jnp.dtype(dtype), variant))
    itemsize = np.dtype(dtype).itemsize
    planes = 2 * 2 * h * w * itemsize        # (re, im) x (in, out)
    return planes + tables


def measured_traffic_bytes_3d(d: int, h: int, w: int, *,
                              dtype=np.float32) -> int:
    """Same count for the fused 3-D kernel's 18 table operands + brick."""
    from repro.kernels.fft3d_fused import gemm_tables3
    tables = sum(np.asarray(t).nbytes
                 for t in gemm_tables3(d, h, w, False,
                                       jnp.dtype(dtype), "plain"))
    itemsize = np.dtype(dtype).itemsize
    bricks = 2 * 2 * d * h * w * itemsize
    return bricks + tables


def _rel_err(out, ref):
    got = (np.asarray(out.re, np.float64) + 1j * np.asarray(out.im,
                                                            np.float64))
    return np.linalg.norm(got - ref) / np.linalg.norm(ref)


def run_2d(sizes=(256, 1024)):
    sink = {}
    rng = np.random.default_rng(0)
    for n in sizes:
        z = (rng.standard_normal((n, n))
             + 1j * rng.standard_normal((n, n))).astype(np.complex64)
        x = from_complex(jnp.asarray(z))
        ref = np.fft.fft2(np.asarray(z).astype(np.complex128))

        clear_plan_cache()
        plan_gemm = get_plan((n, n), backend="pallas")
        assert (plan_gemm.algo, plan_gemm.variant) == ("fused", "plain")
        plan_stock = get_plan((n, n), backend="pallas",
                              algo="fused_stockham")
        fn_gemm = jax.jit(lambda q: plan_gemm(q))
        fn_stock = jax.jit(lambda q: plan_stock(q))

        # interleaved A/B — the ratio gates the acceptance criterion
        us_stock, us_gemm = time_fn_pair(fn_stock, fn_gemm, x, iters=11)
        err_gemm = _rel_err(fn_gemm(x), ref)
        err_stock = _rel_err(fn_stock(x), ref)
        emit(f"table10/fft2_{n}_stockham_fused", us_stock,
             f"rel_err={err_stock:.1e};log2(n) Stockham stages per axis "
             "(the demoted oracle)", sink)
        emit(f"table10/fft2_{n}_gemm_fused", us_gemm,
             f"rel_err={err_gemm:.1e};one four-step GEMM contraction "
             "per axis, transpose absorbed into operand order", sink)
        emit(f"table10/fft2_{n}_gemm_speedup_vs_stockham",
             us_stock / us_gemm,
             "ratio(us_stockham/us_gemm);acceptance >= 1.1 at largest "
             f"size;fp32 rel err acceptance <= 1e-6 (got {err_gemm:.1e})",
             sink)

        # model-predicted vs measured (operand-counted) HBM traffic
        tr = tttrace.trace_plan(plan_gemm, arch="tpu_v5e")
        measured = measured_traffic_bytes_2d(n, n)
        emit(f"table10/fft2_{n}_traffic_model_bytes", tr.dram_bytes,
             f"measured_operand_bytes={measured:.0f};"
             f"model_vs_measured={tr.dram_bytes / measured:.4f}", sink)

        # VMEM verdicts: fp32 GEMM vs the bf16 variants
        emit(f"table10/fft2_{n}_vmem_fp32", tr.sram_high_water,
             f"fits_16MiB={tr.fits};algo=fused variant=plain", sink)
        for variant in ("plain", "compensated"):
            pb = get_plan((n, n), backend="pallas", dtype=jnp.bfloat16,
                          variant=variant)
            tb = tttrace.trace_plan(pb, arch="tpu_v5e")
            emit(f"table10/fft2_{n}_vmem_bf16_{variant}",
                 tb.sram_high_water, f"fits_16MiB={tb.fits}", sink)

        # bf16 precision: split-twiddle compensation vs the plain cast
        xb = SplitComplex(jnp.asarray(z.real, jnp.bfloat16),
                          jnp.asarray(z.imag, jnp.bfloat16))
        errs = {}
        for variant in ("plain", "compensated"):
            pv = get_plan((n, n), backend="pallas", dtype=jnp.bfloat16,
                          variant=variant)
            errs[variant] = _rel_err(pv(xb), ref)
        emit(f"table10/fft2_{n}_bf16_rel_err_plain", errs["plain"],
             f"rel_err={errs['plain']:.2e} vs fp64 numpy (value, not us)",
             sink)
        emit(f"table10/fft2_{n}_bf16_rel_err_compensated",
             errs["compensated"],
             f"rel_err={errs['compensated']:.2e};split hi/lo twiddle "
             "tables, fp32 accumulation;acceptance <= 5e-3", sink)
    return sink


def run_3d(sizes=((16, 16, 16), (2, 256, 256))):
    # The large case is a small-depth pencil brick — the local-pass shape
    # the pencil decomposition hands the single-chip kernel — where the
    # 256 axes take the (16, 16) four-step split (fourstep_factors3) and
    # the whole brick stays cache-resident between the three passes.
    sink = {}
    rng = np.random.default_rng(1)
    for dhw in sizes:
        d, h, w = dhw
        tag = f"{d}x{h}x{w}"
        z = (rng.standard_normal(dhw)
             + 1j * rng.standard_normal(dhw)).astype(np.complex64)
        x = from_complex(jnp.asarray(z))
        ref = np.fft.fftn(np.asarray(z).astype(np.complex128),
                          axes=(-3, -2, -1))

        clear_plan_cache()
        plan_pal = get_plan(dhw, backend="pallas")
        assert plan_pal.algo == "fused" and plan_pal.demote_reason is None
        plan_jnp = get_plan(dhw, backend="jnp")
        fn_pal = jax.jit(lambda q: plan_pal(q))
        fn_jnp = jax.jit(lambda q: plan_jnp(q))

        us_jnp, us_pal = time_fn_pair(fn_jnp, fn_pal, x, iters=11)
        err_pal = _rel_err(fn_pal(x), ref)
        err_jnp = _rel_err(fn_jnp(x), ref)
        emit(f"table10/fft3_{tag}_jnp", us_jnp,
             f"rel_err={err_jnp:.1e};three 1-D passes + axis swaps", sink)
        emit(f"table10/fft3_{tag}_pallas_fused", us_pal,
             f"rel_err={err_pal:.1e};one kernel, three GEMM passes per "
             "brick, D via (d, h*w) reshape", sink)
        emit(f"table10/fft3_{tag}_fused_speedup_vs_jnp", us_jnp / us_pal,
             "ratio(us_jnp/us_pallas);acceptance >= 1.3 at largest "
             f"size;fp32 rel err acceptance <= 1e-6 (got {err_pal:.1e})",
             sink)

        tr = tttrace.trace_plan(plan_pal, arch="tpu_v5e")
        measured = measured_traffic_bytes_3d(d, h, w)
        emit(f"table10/fft3_{tag}_traffic_model_bytes", tr.dram_bytes,
             f"measured_operand_bytes={measured:.0f};"
             f"model_vs_measured={tr.dram_bytes / measured:.4f}", sink)
        emit(f"table10/fft3_{tag}_vmem_fp32", tr.sram_high_water,
             f"fits_16MiB={tr.fits};single fused_fft3d stage", sink)
    return sink


def run(smoke: bool = False):
    sink = {}
    sink.update(run_2d(sizes=(256,) if smoke else (256, 1024)))
    sink.update(run_3d(sizes=((16, 16, 16),) if smoke
                       else ((16, 16, 16), (2, 256, 256))))
    clear_plan_cache()
    write_json(BENCH_JSON, "table10", sink)
    return sink


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest 2-D/3-D case only (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
