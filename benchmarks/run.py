# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    print("name,us_per_call,derived")
    from . import (table1_fft_variants, table2_ablation, table3_fft2d,
                   table4_plan_autotune)
    for mod in (table1_fft_variants, table2_ablation, table3_fft2d,
                table4_plan_autotune):
        try:
            mod.run()
        except Exception as ex:                          # pragma: no cover
            print(f"{mod.__name__},0.0,ERROR={ex!r}", file=sys.stderr)
            raise


if __name__ == '__main__':
    main()
