"""Paper Table 1 — the single-core optimisation ladder for a 16384-element
FFT, reproduced on this repo's TPU-adapted variants.

Paper (Wormhole n300, ms): Initial 14.39 -> Chunked 9.38 -> ThCon 7.56 ->
128-bit 6.61 -> Single copy 5.31; Xeon core 1.85.

Mapping (DESIGN.md §2): *Initial* = per-stage gather/scatter radix-2
(``cooley_tukey``); *Single data copy* = fused next-step reorder
(``cooley_tukey_fused``); the TPU-native end-points of the ladder are
*Stockham* (reorder-free, contiguous) and *four-step* (MXU matmul form).
Pallas kernels run in interpret mode (correctness path); their TPU cost is
the dry-run roofline, so wall times here compare the pure-JAX variants and
``derived`` reports GFLOP/s on this host CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft1d, from_complex
from .common import emit, fft_flops, time_fn

N = 16384
BATCH = 8


def run():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((BATCH, N)).astype(np.float32)
    y = rng.standard_normal((BATCH, N)).astype(np.float32)
    z = from_complex(jnp.asarray(x + 1j * y, jnp.complex64))

    variants = [
        ("table1/initial_two_reorder",
         functools.partial(fft1d.fft_cooley_tukey, variant="two_reorder")),
        ("table1/single_copy_one_reorder",
         functools.partial(fft1d.fft_cooley_tukey, variant="one_reorder")),
        ("table1/stockham_autosort", fft1d.fft_stockham),
        ("table1/four_step_matmul", fft1d.fft_four_step),
        ("table1/naive_dft_matmul", None),   # O(N^2): skipped at this size
    ]
    ref = np.fft.fft(np.asarray(x + 1j * y))
    for name, fn in variants:
        if fn is None:
            emit(name, 0.0, "skipped_oN2_at_16384")
            continue
        jitted = jax.jit(lambda q, f=fn: f(q))
        out = jitted(z)
        got = np.asarray(out.re) + 1j * np.asarray(out.im)
        err = np.abs(got - ref).max() / np.abs(ref).max()
        us = time_fn(jitted, z)
        gflops = fft_flops(N, BATCH) / (us * 1e-6) / 1e9
        emit(name, us, f"gflops={gflops:.2f};rel_err={err:.1e}")
