"""Table 7 — fused real-input 2-D FFT: the kind="rfft" hardware path.

The paper's core finding is that FFT performance is bounded by data
movement; real-input transforms were doing twice the movement they need
to (rfft2 was pinned to the jnp row-column schedule).  This table pits
the fused real-input Pallas kernel (:mod:`repro.kernels.rfft2d_fused`)
against that jnp rfft2 path on the same machine:

- measured wall time, interleaved A/B (the ratio gates the acceptance
  criterion: fused >= 1.3x at 1024x1024, rel err vs numpy <= 1e-6);
- the inverse twin (irfft2) timed the same way;
- model-predicted vs measured (operand-counted) HBM traffic: the fused
  kernel moves one real plane + one half spectrum per image — ~half the
  complex fused kernel's two full planes — and the
  :func:`repro.tt.trace.trace_plan` prediction must agree with the byte
  count the kernel's operands actually imply.

All rows land in BENCH_rfft2d.json (section "table7").
``--smoke`` runs the 256x256 case only (CI).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clear_plan_cache, get_plan, to_complex
from repro.core.complexmath import from_complex
from repro.tt import trace as tttrace
from .common import emit, time_fn_pair, write_json

BENCH_JSON = "BENCH_rfft2d.json"


def measured_traffic_bytes(h: int, w: int, *, dtype=np.float32) -> int:
    """HBM bytes the fused rfft kernel actually stages per image, counted
    from its REAL operand buffers — the table arrays the kernel builds
    (``fourstep_tables_np``, cast to the working dtype) plus the input
    plane and output spectrum ShapeDtypeStructs.  Deliberately independent
    of :mod:`repro.tt.trace`'s accounting, so a model drift (forgotten
    table, wrong spectrum width) shows up as model_vs_measured != 1."""
    from repro.kernels.rfft2d_fused import fourstep_tables_np
    tables = sum(np.asarray(t, dtype).nbytes
                 for t in fourstep_tables_np(w, False)
                 + fourstep_tables_np(h, False))
    itemsize = np.dtype(dtype).itemsize
    plane_in = h * w * itemsize                      # real input
    spec_out = 2 * h * (w // 2 + 1) * itemsize       # re+im half spectrum
    return plane_in + spec_out + tables


def run(sizes=(256, 1024)):
    sink = {}
    rng = np.random.default_rng(0)
    for n in sizes:
        x = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
        ref = np.fft.rfft2(np.asarray(x))

        def _err(out):
            return np.abs(np.asarray(to_complex(out))
                          - ref).max() / np.abs(ref).max()

        clear_plan_cache()
        plan_jnp = get_plan((n, n), kind="rfft", backend="jnp")
        plan_pal = get_plan((n, n), kind="rfft", backend="pallas")
        assert plan_pal.algo == "fused" and plan_pal.backend == "pallas"
        fn_jnp = jax.jit(lambda q: plan_jnp(q))
        fn_pal = jax.jit(lambda q: plan_pal(q))

        # interleaved A/B — the ratio gates the acceptance criterion, so
        # spend extra alternating samples to push the shared-box noise
        # floor down before taking the median
        us_jnp, us_pal = time_fn_pair(fn_jnp, fn_pal, x, iters=11)
        err_jnp, err_pal = _err(fn_jnp(x)), _err(fn_pal(x))
        emit(f"table7/rfft2_{n}_jnp", us_jnp,
             f"rel_err={err_jnp:.1e};rfft rows + transpose + c2c cols",
             sink)
        emit(f"table7/rfft2_{n}_pallas_fused", us_pal,
             f"rel_err={err_pal:.1e};one kernel, row-pair packing, "
             "half-width column pass", sink)
        emit(f"table7/rfft2_{n}_fused_speedup_vs_jnp", us_jnp / us_pal,
             "ratio(us_jnp/us_pallas);acceptance >= 1.3 at 1024", sink)

        # inverse twin
        xf = from_complex(jnp.asarray(ref.astype(np.complex64)))
        pi_jnp = get_plan((n, n), kind="rfft", backend="jnp", inverse=True)
        pi_pal = get_plan((n, n), kind="rfft", backend="pallas",
                          inverse=True)
        fni_jnp = jax.jit(lambda q: pi_jnp(q))
        fni_pal = jax.jit(lambda q: pi_pal(q))
        us_ij, us_ip = time_fn_pair(fni_jnp, fni_pal, xf)
        ierr = np.abs(np.asarray(fni_pal(xf)) - np.asarray(x)).max()
        emit(f"table7/irfft2_{n}_jnp", us_ij, "inverse twin", sink)
        emit(f"table7/irfft2_{n}_pallas_fused", us_ip,
             f"roundtrip_err={ierr:.1e}", sink)
        emit(f"table7/irfft2_{n}_fused_speedup_vs_jnp", us_ij / us_ip,
             "ratio(us_jnp/us_pallas)", sink)

        # model-predicted vs measured (operand-counted) HBM traffic
        tr = tttrace.trace_plan(plan_pal, arch="tpu_v5e")
        tc = tttrace.trace_plan(
            get_plan((n, n), backend="pallas"), arch="tpu_v5e")
        measured = measured_traffic_bytes(n, n)
        emit(f"table7/rfft2_{n}_traffic_model_bytes", tr.dram_bytes,
             f"measured_operand_bytes={measured:.0f};"
             f"model_vs_measured={tr.dram_bytes / measured:.4f}", sink)
        regime = "~0.5 — half the plane bytes" if n > 256 else \
            "dense-DFT leaf tables dominate below the four-step split"
        emit(f"table7/rfft2_{n}_traffic_vs_complex_fused",
             tr.dram_bytes / tc.dram_bytes,
             f"ratio(rfft_fused/c2c_fused);{regime}", sink)
        emit(f"table7/rfft2_{n}_vmem_high_water", tr.sram_high_water,
             f"fits_16MiB_v5e={tr.fits};complex_fused="
             f"{tc.sram_high_water} (fits={tc.fits})", sink)
    clear_plan_cache()
    write_json(BENCH_JSON, "table7", sink)
    return sink


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="256x256 only (CI)")
    args = ap.parse_args()
    run(sizes=(256,) if args.smoke else (256, 1024))


if __name__ == "__main__":
    main()
