"""Paper Table 2 — component ablation: which part of the per-stage FFT
pipeline costs what.

The paper disables read-reorder / compute / write-reorder on the Tensix and
finds reordering dominates (14.4 ms full vs 0.9 ms compute-only).  We ablate
the same components of the paper-faithful ``cooley_tukey`` variant: the
gather ("read reorder"), the butterfly arithmetic ("compute") and the
scatter ("write reorder"), timing each pipeline on this host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fft1d, from_complex
from repro.core.complexmath import SplitComplex
from repro.core import complexmath as cm
from repro.core import twiddle as tw
from .common import emit, time_fn

N = 16384
BATCH = 8


def _pipeline(read_reorder: bool, compute: bool, write_reorder: bool):
    """The per-stage pipeline with components toggled (paper Table 2)."""
    rev, stages = fft1d._ct_stage_indices(N)
    w_table = tw.twiddles(N, dtype=jnp.float32)
    half = N // 2

    def fn(z: SplitComplex) -> SplitComplex:
        z = fft1d._take(z, rev)
        for (idx0, idx1, tw_idx, inv_perm) in stages:
            if read_reorder:
                lhs = fft1d._take(z, idx0)
                rhs = fft1d._take(z, idx1)
            else:                      # contiguous halves: no gather
                lhs = SplitComplex(z.re[..., :half], z.im[..., :half])
                rhs = SplitComplex(z.re[..., half:], z.im[..., half:])
            if compute:
                w = fft1d._take(w_table, tw_idx)
                f = cm.mul(rhs, w)
                out0, out1 = cm.add(lhs, f), cm.sub(lhs, f)
            else:
                out0, out1 = lhs, rhs
            cat = SplitComplex(
                jnp.concatenate([out0.re, out1.re], axis=-1),
                jnp.concatenate([out0.im, out1.im], axis=-1))
            z = fft1d._take(cat, inv_perm) if write_reorder else cat
        return z

    return jax.jit(fn)


def run():
    rng = np.random.default_rng(0)
    z = from_complex(jnp.asarray(
        rng.standard_normal((BATCH, N)) + 1j * rng.standard_normal((BATCH, N)),
        jnp.complex64))
    cases = [
        ("table2/full_pipeline", (True, True, True)),
        ("table2/no_read_reorder", (False, True, True)),
        ("table2/no_write_reorder", (True, True, False)),
        ("table2/no_reorder_at_all", (False, True, False)),
        ("table2/compute_disabled", (True, False, True)),
        ("table2/reorder_only", (True, False, False)),
    ]
    base_us = None
    for name, (r, c, w) in cases:
        fn = _pipeline(r, c, w)
        us = time_fn(fn, z)
        if base_us is None:
            base_us = us
        emit(name, us, f"fraction_of_full={us / base_us:.3f}")
