"""Shared benchmark utilities: timing, CSV emission, JSON sinks, FLOP math."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of jit'd fn; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def time_fn_pair(fn_a, fn_b, *args, warmup: int = 2, iters: int = 7):
    """Interleaved A/B timing (us, us): alternating samples cancel the
    machine-load drift that two sequential time_fn passes pick up — use for
    any ratio that gates an acceptance criterion."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta) * 1e6), float(np.median(tb) * 1e6)


def fft_flops(n: int, batch: int = 1) -> float:
    """Canonical 5 N log2 N real-op count for a complex FFT."""
    return 5.0 * n * np.log2(n) * batch


def emit(name: str, us: float, derived: str, sink: dict = None):
    print(f"{name},{us:.1f},{derived}")
    if sink is not None:
        # store unrounded: ratio rows (e.g. acceptance-gating speedups) go
        # through this sink too, and 1.26 vs 1.34 must stay distinguishable
        sink[name] = {"us": float(us), "derived": derived}


def write_json(path: str, section: str, payload: dict):
    """Merge `payload` under `section` into the JSON file at `path` (so
    table3 and table4 can share one BENCH_fft2d.json)."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                data = json.load(fh)
        except (OSError, json.JSONDecodeError):
            data = {}
    data[section] = payload
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {section} -> {path}")
