"""Shared benchmark utilities: timing, CSV emission, FLOP math."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of jit'd fn; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def fft_flops(n: int, batch: int = 1) -> float:
    """Canonical 5 N log2 N real-op count for a complex FFT."""
    return 5.0 * n * np.log2(n) * batch


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
