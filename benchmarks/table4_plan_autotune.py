"""Table 4 (beyond-paper) — plan-registry autotuning: tuned vs default.

FFTW's central lesson (and *Parallel FFTW on RISC-V*, Strack et al. 2025):
measured plans beat heuristic dispatch, and the measurement cost amortises
because plans are cached.  For each (shape, backend) below we time the
default heuristic plan and the ``tune=True`` winner on the same batch, and
report the candidate table the tuner measured.  Rows land in
BENCH_fft2d.json (section "table4").
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import clear_plan_cache, get_plan
from repro.core.complexmath import SplitComplex
from .common import emit, time_fn_pair, write_json

BENCH_JSON = "BENCH_fft2d.json"

CASES = [
    ((1024,), "jnp", 64),
    ((4096,), "jnp", 16),
    ((1024,), "pallas", 64),
    ((256, 256), "pallas", 4),
]


def run():
    sink = {}
    rng = np.random.default_rng(0)
    for shape, backend, batch in CASES:
        shp = (batch,) + shape
        x = SplitComplex(jnp.asarray(rng.standard_normal(shp), jnp.float32),
                         jnp.asarray(rng.standard_normal(shp), jnp.float32))
        name = "x".join(map(str, shape))

        clear_plan_cache()                    # measure cold heuristic plan
        default = get_plan(shape, backend=backend)
        tuned = get_plan(shape, backend=backend, tune=True, tune_batch=batch)
        us_default, us_tuned = time_fn_pair(
            jax.jit(lambda q, p=default: p(q)),
            jax.jit(lambda q, p=tuned: p(q)), x)

        cfg_d = f"{default.algo}/r{default.radix}/bb{default.block_batch}"
        cfg_t = f"{tuned.algo}/r{tuned.radix}/bb{tuned.block_batch}"
        emit(f"table4/{name}_{backend}_default", us_default,
             f"batch={batch};plan={cfg_d}", sink)
        # "|"-joined pairs keep the CSV's third column comma-free
        report = "|".join(f"{k}={v}" for k, v in tuned.tune_report.items())
        emit(f"table4/{name}_{backend}_tuned", us_tuned,
             f"batch={batch};plan={cfg_t};candidates={report}", sink)
        emit(f"table4/{name}_{backend}_tuned_speedup",
             us_default / us_tuned, "ratio(default/tuned)", sink)

    clear_plan_cache()                        # leave no tuned state behind
    write_json(BENCH_JSON, "table4", sink)
    return sink
