"""Table 6 — distributed pencil FFTs, model-verified wire traffic.

The ROADMAP's "halve the all_to_all bytes" follow-on as numbers: the
real-input :func:`repro.dist.pencil.prfft2` exchanges W/2 packed pencils
where :func:`~repro.dist.pencil.pfft2` exchanges W, so both the *measured*
per-device wire bytes (the pencil wire log, priced by
``compression.wire_bytes``) and the *predicted* exchange bytes
(:func:`repro.tt.trace.trace_dist` on the multi-chip hop table) must show
~(N/2+1)/N ~ 0.5 of the complex schedule's exchange traffic.

Three sections land in BENCH_dist_model.json:

- ``predicted``  trace_dist rows per (size, schedule, wire format, arch):
                 wall time, energy, per-device exchange wire bytes.
- ``measured``   an 8-emulated-device subprocess runs the real pfft2 /
                 prfft2, recording wall time and the wire log.
- ``ranking``    measured-vs-predicted agreement: wire-byte ratios match
                 exactly (same ``wire_bytes`` pricing on both sides) and
                 the wire ordering always ranks prfft2 cheaper.

``--smoke`` shrinks sizes for CI; the full run covers the 512/1024 rows
the regression tests pin.

Usage: ``python -m benchmarks.table6_dist_model [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys

from repro.tt import report as ttreport
from repro.tt import trace as tttrace
from .common import write_json

BENCH_JSON = "BENCH_dist_model.json"

DEVICES = 8
MODEL_ARCHS = ("wormhole_n300", "tpu_v5e")

_MEASURE_CODE = r"""
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.complexmath import SplitComplex
from repro.dist import pencil
from repro.launch.mesh import make_mesh

sizes = %(sizes)r
methods = %(methods)r
mesh = make_mesh((%(devices)d,), ("data",))
rng = np.random.default_rng(0)
out = {}
for n in sizes:
    x = rng.standard_normal((n, n)).astype(np.float32)
    sh = NamedSharding(mesh, P("data", None))
    xr = jax.device_put(jnp.asarray(x), sh)
    xc = SplitComplex(xr, jnp.zeros_like(xr))
    row = {}
    for method in methods:
        for kind in ("pfft2", "prfft2"):
            fn = (lambda m=method: pencil.prfft2(xr, mesh, "data",
                                                 compress=m)) \
                if kind == "prfft2" else \
                (lambda m=method: pencil.pfft2(xc, mesh, "data", compress=m))
            pencil.reset_wire_log()
            y = fn()
            jax.block_until_ready((y.re, y.im))
            wire = pencil.logged_exchange_bytes()
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                y = fn()
                jax.block_until_ready((y.re, y.im))
                best = min(best, time.perf_counter() - t0)
            row[f"{kind}/{method}"] = {"us": best * 1e6, "wire_bytes": wire}
    out[f"{n}x{n}"] = row
print("TABLE6_JSON " + json.dumps(out))
"""


def predicted_rows(sizes, *, devices: int = DEVICES, archs=MODEL_ARCHS,
                   methods=("none",)) -> dict:
    """Pure-model section: trace_dist per (size, schedule, method, arch).
    No devices needed — this is what tests/test_tt_model.py pins."""
    out = {}
    for n in sizes:
        row = {}
        for arch in archs:
            for method in methods:
                for kind, real in (("pfft2", False), ("prfft2", True)):
                    t = tttrace.trace_dist((n, n), devices=devices,
                                           arch=arch, real=real,
                                           method=method)
                    row[f"{kind}/{method}/{arch}"] = {
                        "us": t.seconds * 1e6,
                        "exchange_wire_bytes": t.exchange_wire_bytes,
                        "energy_j": t.energy_j,
                        "stages": [s.name for s in t.stages],
                    }
                a = row[f"pfft2/{method}/{arch}"]
                b = row[f"prfft2/{method}/{arch}"]
                row[f"wire_ratio/{method}/{arch}"] = \
                    b["exchange_wire_bytes"] / a["exchange_wire_bytes"]
        out[f"{n}x{n}"] = row
    return out


def measured_rows(sizes, *, devices: int = DEVICES,
                  methods=("none",)) -> dict:
    """Run the actual pencil transforms on emulated devices (subprocess so
    this process's single-device jax stays untouched)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = _MEASURE_CODE % {"sizes": tuple(sizes), "methods": tuple(methods),
                            "devices": devices}
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, \
        f"measure subprocess failed:\nSTDOUT:{proc.stdout}\n" \
        f"STDERR:{proc.stderr[-3000:]}"
    for line in proc.stdout.splitlines():
        if line.startswith("TABLE6_JSON "):
            return json.loads(line[len("TABLE6_JSON "):])
    raise AssertionError(f"no TABLE6_JSON line in:\n{proc.stdout}")


def ranking_rows(sizes, predicted: dict, measured: dict,
                 methods=("none",)) -> dict:
    """Measured-vs-predicted agreement per size: the wire-byte ratio and
    the "which schedule ships fewer bytes" ordering."""
    out = {}
    for n in sizes:
        key = f"{n}x{n}"
        m = measured[key]
        row = {}
        for method in methods:
            m_ratio = m[f"prfft2/{method}"]["wire_bytes"] \
                / m[f"pfft2/{method}"]["wire_bytes"]
            bound = math.ceil((n // 2 + 1) / n * m[f"pfft2/{method}"]
                              ["wire_bytes"])
            row[f"measured_wire_ratio/{method}"] = m_ratio
            row[f"halved_bound_holds/{method}"] = \
                m[f"prfft2/{method}"]["wire_bytes"] <= bound
            for arch in MODEL_ARCHS:
                p_ratio = predicted[key][f"wire_ratio/{method}/{arch}"]
                row[f"predicted_wire_ratio/{method}/{arch}"] = p_ratio
                row[f"wire_ratio_agrees/{method}/{arch}"] = \
                    abs(p_ratio - m_ratio) < 1e-9
                row[f"wire_order_agrees/{method}/{arch}"] = \
                    (p_ratio < 1.0) == (m_ratio < 1.0)
        out[key] = row
        print(f"table6/rank_{n}: measured_ratio="
              f"{row['measured_wire_ratio/none']:.3f} agree="
              f"{[row[f'wire_ratio_agrees/none/{a}'] for a in MODEL_ARCHS]}")
    return out


def run(smoke: bool = False) -> dict:
    sizes = (128, 256) if smoke else (512, 1024)
    methods = ("none", "bf16") if smoke else ("none", "bf16", "int8")
    predicted = predicted_rows(sizes, methods=methods)
    write_json(BENCH_JSON, "predicted", predicted)
    print(ttreport.dist_markdown_table(ttreport.dist_compare(sizes)))
    measured = measured_rows(sizes, methods=methods)
    write_json(BENCH_JSON, "measured", measured)
    ranking = ranking_rows(sizes, predicted, measured, methods=methods)
    write_json(BENCH_JSON, "ranking", ranking)
    return {"predicted": predicted, "measured": measured, "ranking": ranking}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI smoke runs")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
