"""Table 11 — fused spectral convolution: rfft -> pointwise multiply ->
irfft in one VMEM-resident pass (kind="conv_*" plans,
repro.kernels.fftconv_fused) against the unfused registry-composed
schedule.

PR 10 built the fused conv kernel and wired it through the model stack
(SSM causal-conv branch, fourier_mix, the audio STFT frontend).  This
table is the evidence:

- conv A/B at FFT lengths 1024/4096/16384 with a 64-row filter bank,
  interleaved on the same plan inputs (the ratio gates the acceptance
  criterion: fused >= 1.3x unfused at the largest benched length, rel
  err vs fp64 numpy <= 1e-6 in fp32).  Both kinds run the same kernel;
  the circular kind is benched so the named length IS the FFT length.
- model-predicted vs measured (operand-counted) HBM traffic for the
  fused kernel — counted from its REAL operand buffers (12 conv_tables
  arrays + x/y planes + the packed filter pair), independent of
  repro.tt.trace, so a model drift shows up as model_vs_measured != 1;
- VMEM high-water verdicts from trace_plan for the fused stage;
- SSM tokens/sec: the ssm_demo train step (causal conv branch through
  fft_conv) with fft_backend pallas vs jnp, interleaved (acceptance:
  pallas >= jnp).

All rows land in BENCH_fftconv.json (section "table11").
``--smoke`` runs the smallest conv case + a tiny SSM step (CI).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clear_plan_cache
from repro.core import plan as plan_mod
from repro.core.complexmath import SplitComplex
from repro.tt import trace as tttrace
from .common import emit, time_fn_pair, write_json

BENCH_JSON = "BENCH_fftconv.json"
ROWS = 64            # conv channels per call (an SSM bank width)
KLEN = 129           # odd filter length, zero-padded to the FFT length


def measured_traffic_bytes(m: int, rows: int, *, dtype=np.float32) -> int:
    """HBM bytes the fused conv kernel stages per call, counted from its
    real operand buffers: the 12 four-step table arrays conv_tables
    actually builds, the real in/out planes, and the packed filter pair
    E/F (re + im each)."""
    from repro.kernels.fftconv_fused import conv_tables
    tables = sum(np.asarray(t).nbytes
                 for t in conv_tables(m, jnp.dtype(dtype)))
    itemsize = np.dtype(dtype).itemsize
    hm = m // 2
    planes = 2 * rows * m * itemsize          # x in + y out
    ef = 4 * rows * hm * itemsize             # packed filter pair (E, F)
    return planes + ef + tables


def run_conv(lengths=(1024, 4096, 16384)):
    sink = {}
    rng = np.random.default_rng(0)
    for m in lengths:
        x = rng.standard_normal((ROWS, m)).astype(np.float32)
        k = np.zeros((ROWS, m), np.float32)
        k[:, :KLEN] = rng.standard_normal((ROWS, KLEN)).astype(np.float32)
        kf64 = np.fft.rfft(k.astype(np.float64))
        ref = np.fft.irfft(np.fft.rfft(x.astype(np.float64)) * kf64, m)

        clear_plan_cache()
        pf = plan_mod.get_plan((m,), kind="conv_circular", backend="pallas")
        assert (pf.algo, pf.demote_reason) == ("fused", None)
        pu = plan_mod.get_plan((m,), kind="conv_circular", backend="jnp")
        assert pu.algo == "unfused"
        xj = jnp.asarray(x)
        kf = SplitComplex(jnp.asarray(kf64.real, jnp.float32),
                          jnp.asarray(kf64.imag, jnp.float32))
        fn_f = jax.jit(lambda q: pf(q, kf))
        fn_u = jax.jit(lambda q: pu(q, kf))

        # interleaved A/B — the ratio gates the acceptance criterion
        us_u, us_f = time_fn_pair(fn_u, fn_f, xj, iters=11)
        err_f = float(np.linalg.norm(np.asarray(fn_f(xj), np.float64) - ref)
                      / np.linalg.norm(ref))
        err_u = float(np.linalg.norm(np.asarray(fn_u(xj), np.float64) - ref)
                      / np.linalg.norm(ref))
        emit(f"table11/conv_{m}_unfused_jnp", us_u,
             f"rel_err={err_u:.1e};registry-composed rfft -> mul -> irfft "
             "(six half/full planes through HBM)", sink)
        emit(f"table11/conv_{m}_fused_pallas", us_f,
             f"rel_err={err_f:.1e};one kernel: packed half-length rfft, "
             "pointwise multiply, packed irfft — spectrum stays in VMEM",
             sink)
        emit(f"table11/conv_{m}_fused_speedup_vs_unfused", us_u / us_f,
             "ratio(us_unfused/us_fused);acceptance >= 1.3 at largest "
             f"length;fp32 rel err acceptance <= 1e-6 (got {err_f:.1e})",
             sink)

        # model-predicted vs measured (operand-counted) HBM traffic
        tr = tttrace.trace_plan(pf, arch="tpu_v5e", batch=ROWS)
        measured = measured_traffic_bytes(m, ROWS)
        emit(f"table11/conv_{m}_traffic_model_bytes", tr.dram_bytes,
             f"measured_operand_bytes={measured:.0f};"
             f"model_vs_measured={tr.dram_bytes / measured:.4f}", sink)
        emit(f"table11/conv_{m}_vmem_fp32", tr.sram_high_water,
             f"fits_16MiB={tr.fits};single fused_fftconv stage "
             f"({ROWS} rows)", sink)
    return sink


def run_ssm(smoke: bool = False):
    import dataclasses

    import repro.configs as C
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as M
    from repro.train import optimizer as opt_lib
    from repro.train.train_step import make_train_step, init_opt_state

    sink = {}
    # the full run benches at seq 4096 (padded conv length 8192) — the
    # long-conv regime the fused kernel targets; tiny sequences keep the
    # whole step matmul-dominated and the conv backend barely registers
    seq, gbatch, iters = (64, 2, 3) if smoke else (4096, 2, 5)
    base = C.get_config("ssm_demo").reduced()
    assert base.use_fft_conv
    dcfg = DataConfig(seq_len=seq, global_batch=gbatch)
    ocfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)

    clear_plan_cache()
    steps = {}
    for backend in ("jnp", "pallas"):
        cfg = dataclasses.replace(base, fft_backend=backend)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = init_opt_state(cfg, ocfg, params)
        # no donation: the same (params, opt_state, batch) operands are
        # replayed every timed iteration
        steps[backend] = (jax.jit(make_train_step(cfg, ocfg)),
                          params, opt_state)
    batch = SyntheticLM(dcfg, base).batch_at(0)

    fn_j = lambda b: steps["jnp"][0](steps["jnp"][1], steps["jnp"][2], b)
    fn_p = lambda b: steps["pallas"][0](steps["pallas"][1],
                                        steps["pallas"][2], b)
    us_j, us_p = time_fn_pair(fn_j, fn_p, batch, iters=iters)
    toks = gbatch * seq
    tps_j, tps_p = toks / (us_j / 1e6), toks / (us_p / 1e6)
    emit("table11/ssm_tokens_per_sec_jnp", tps_j,
         f"ssm_demo reduced train step, seq={seq} batch={gbatch}, "
         "causal conv via the unfused jnp schedule (value=tokens/sec)",
         sink)
    emit("table11/ssm_tokens_per_sec_pallas", tps_p,
         "same step, causal conv via the fused conv plan "
         "(value=tokens/sec)", sink)
    emit("table11/ssm_pallas_speedup_vs_jnp", tps_p / tps_j,
         "ratio(tokens_pallas/tokens_jnp);acceptance >= 1.0", sink)
    return sink


def run(smoke: bool = False):
    sink = {}
    sink.update(run_conv(lengths=(1024,) if smoke
                         else (1024, 4096, 16384)))
    sink.update(run_ssm(smoke=smoke))
    clear_plan_cache()
    write_json(BENCH_JSON, "table11", sink)
    return sink


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest conv case + tiny SSM step (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
