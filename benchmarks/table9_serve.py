"""Table 9 — spectral serving: continuous batching vs serial dispatch,
offered-QPS sweep, pre-warm cold-start, and prewarm-fault degrade.

Four sections land in BENCH_serve.json:

- ``batched_vs_serial``: the acceptance A/B.  The same closed-loop request
  mix runs through one :class:`~repro.serve.spectral.SpectralServer` at
  full concurrency (continuous batching fills dispatch slots) and at
  concurrency 1 (every request pays a whole ``max_batch``-padded dispatch
  alone).  Runs interleave A/B/A/B... so machine-load drift cancels; the
  full run asserts batched throughput >= 1.3x serial.
- ``qps_sweep``: open-loop (Poisson arrivals) at increasing offered QPS;
  achieved QPS + p50/p99 per point — the knee where queueing delay takes
  over p99 is visible in the committed numbers.
- ``prewarm``: per-request latency of the first requests into a fresh
  server with and without startup pre-warm.  Without it the first request
  of every bucket pays XLA compilation inline (cold p99); the full run
  asserts pre-warm cuts cold p99 by >= 2x.
- ``fault_degrade``: a ``serve.prewarm`` fault injected at startup — the
  server must come up degraded (jnp twin) with no crash and serve spectra
  identical to a healthy server's (max_rel_err <= 1e-6, asserted always:
  a wrong answer from the degrade path is a silent corruption).

Usage: ``python -m benchmarks.table9_serve [--smoke]``
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.complexmath import SplitComplex
from repro.resilience import faults
from repro.serve.spectral import (BucketConfig, MixItem, SpectralServer,
                                  closed_loop, open_loop)

from .common import write_json

BENCH_JSON = "BENCH_serve.json"


def _buckets(smoke: bool):
    shape = (64, 64) if smoke else (128, 128)
    return [BucketConfig(shape, kind="c2c"),
            BucketConfig(shape, kind="rfft")], \
        [MixItem(shape, "c2c"), MixItem(shape, "rfft")]


# -- batched vs serial -------------------------------------------------------


def batched_vs_serial(smoke: bool) -> dict:
    buckets, mix = _buckets(smoke)
    requests = 32 if smoke else 96
    iters = 2 if smoke else 5
    qa, qb = [], []
    with SpectralServer(buckets) as srv:
        conc = srv.states[buckets[0].label].cfg.max_batch * 2
        closed_loop(srv, mix, requests=requests, concurrency=conc,
                    seed=99)                      # warm both paths
        for i in range(iters):                    # interleaved A/B
            a = closed_loop(srv, mix, requests=requests, concurrency=conc,
                            seed=2 * i, rid_prefix=f"a{i}")
            b = closed_loop(srv, mix, requests=requests, concurrency=1,
                            seed=2 * i + 1, rid_prefix=f"b{i}")
            qa.append(a["achieved_qps"])
            qb.append(b["achieved_qps"])
        occ = srv.snapshot()["buckets"][buckets[0].label][
            "gauges"]["batch_occupancy"]["mean"]
    batched, serial = float(np.median(qa)), float(np.median(qb))
    row = {"requests": requests, "concurrency": conc, "iters": iters,
           "batched_qps": batched, "serial_qps": serial,
           "speedup": batched / serial, "mean_batch_occupancy": occ}
    print(f"table9/batched_vs_serial,batched={batched:.1f}qps,"
          f"serial={serial:.1f}qps,speedup={row['speedup']:.2f}x")
    return row


# -- offered-QPS sweep -------------------------------------------------------


def qps_sweep(smoke: bool) -> list:
    buckets, mix = _buckets(smoke)
    points = [50, 200] if smoke else [50, 100, 200, 400, 800]
    duration = 0.5 if smoke else 2.0
    rows = []
    with SpectralServer(buckets) as srv:
        for qps in points:
            r = open_loop(srv, mix, qps=float(qps), duration_s=duration,
                          seed=qps, rid_prefix=f"q{qps}")
            rows.append({k: r[k] for k in
                         ("offered_qps", "achieved_qps", "completed",
                          "rejected", "timed_out", "p50_ms", "p99_ms")})
            print(f"table9/qps_sweep,offered={qps},"
                  f"achieved={r['achieved_qps']:.1f},"
                  f"p50={r['p50_ms']:.1f}ms,p99={r['p99_ms']:.1f}ms")
    return rows


# -- pre-warm cold start -----------------------------------------------------


def _first_request_p99(buckets, mix, *, prewarm: bool, seed: int) -> dict:
    """Latency stats of the first requests into a *fresh* server."""
    rng = np.random.default_rng(seed)
    lat = []
    with SpectralServer(buckets, prewarm=prewarm) as srv:
        for i, item in enumerate(mix * 4):      # few per bucket
            shape = tuple(item.shape)
            if item.kind == "rfft":
                payload = rng.standard_normal(shape).astype(np.float32)
            else:
                payload = SplitComplex(
                    rng.standard_normal(shape).astype(np.float32),
                    rng.standard_normal(shape).astype(np.float32))
            t0 = time.perf_counter()
            srv.submit(f"w{i}", payload, kind=item.kind)
            rec = srv.result(f"w{i}", timeout=180)
            assert rec is not None and rec.status == "completed"
            lat.append(time.perf_counter() - t0)
        report = srv.prewarm_report
    return {"p99_ms": float(np.percentile(lat, 99) * 1e3),
            "max_ms": float(np.max(lat) * 1e3),
            "prewarm_total_s": report.total_s if report else None}


def prewarm_cold_start(smoke: bool) -> dict:
    buckets, mix = _buckets(smoke)
    cold = _first_request_p99(buckets, mix, prewarm=False, seed=5)
    warm = _first_request_p99(buckets, mix, prewarm=True, seed=6)
    row = {"cold_p99_ms": cold["p99_ms"], "cold_max_ms": cold["max_ms"],
           "warm_p99_ms": warm["p99_ms"], "warm_max_ms": warm["max_ms"],
           "prewarm_total_s": warm["prewarm_total_s"],
           "cold_over_warm": cold["p99_ms"] / max(warm["p99_ms"], 1e-9)}
    print(f"table9/prewarm,cold_p99={cold['p99_ms']:.1f}ms,"
          f"warm_p99={warm['p99_ms']:.1f}ms,"
          f"ratio={row['cold_over_warm']:.1f}x,"
          f"prewarm={row['prewarm_total_s']:.2f}s")
    return row


# -- prewarm-fault degrade ---------------------------------------------------


def fault_degrade(smoke: bool) -> dict:
    buckets, _ = _buckets(smoke)
    shape = buckets[0].shape
    rng = np.random.default_rng(7)
    x = SplitComplex(rng.standard_normal(shape).astype(np.float32),
                     rng.standard_normal(shape).astype(np.float32))
    with SpectralServer([buckets[0]]) as healthy:
        healthy.submit("r", x)
        ref = healthy.result("r", timeout=120).value
    crashed = False
    try:
        with faults.inject("serve.prewarm", "error", times=None):
            srv = SpectralServer([buckets[0]])
    except Exception:       # noqa: BLE001 — the thing we are measuring
        crashed = True
        srv = None
    if crashed:
        row = {"crashed": True}
    else:
        with srv:
            degraded = srv.degraded_buckets
            srv.submit("r", x)
            got = srv.result("r", timeout=120).value
        num = max(float(np.max(np.abs(np.asarray(got.re)
                                      - np.asarray(ref.re)))),
                  float(np.max(np.abs(np.asarray(got.im)
                                      - np.asarray(ref.im)))))
        den = max(float(np.max(np.abs(np.asarray(ref.re)))),
                  float(np.max(np.abs(np.asarray(ref.im)))))
        row = {"crashed": False, "degraded_buckets": degraded,
               "max_rel_err": num / den}
    print(f"table9/fault_degrade,crashed={row['crashed']},"
          f"degraded={row.get('degraded_buckets')},"
          f"err={row.get('max_rel_err')}")
    return row


# -- driver ------------------------------------------------------------------


def run(smoke: bool = False) -> dict:
    payload = {"smoke": smoke}
    payload["batched_vs_serial"] = batched_vs_serial(smoke)
    payload["qps_sweep"] = qps_sweep(smoke)
    payload["prewarm"] = prewarm_cold_start(smoke)
    payload["fault_degrade"] = fault_degrade(smoke)

    fd = payload["fault_degrade"]
    assert not fd["crashed"], "prewarm fault crashed the server"
    assert fd["max_rel_err"] <= 1e-6, \
        f"degrade path changed the math: rel_err={fd['max_rel_err']}"
    assert fd["degraded_buckets"], "fault injected but nothing degraded"
    if not smoke:
        sp = payload["batched_vs_serial"]["speedup"]
        assert sp >= 1.3, f"batched speedup {sp:.2f}x < 1.3x"
        ratio = payload["prewarm"]["cold_over_warm"]
        assert ratio >= 2.0, \
            f"pre-warm should cut cold p99 >= 2x, got {ratio:.1f}x"
    write_json(BENCH_JSON, "serve", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI smoke runs")
    args = ap.parse_args()
    run(smoke=args.smoke)
