"""Table 5 — the Wormhole/Tensix model (repro.tt) against measurement.

Three sections land in BENCH_wormhole_model.json:

- ``paper_table``     the §6 Wormhole-vs-Xeon time/power/energy table from
                      the published anchors in :mod:`repro.tt.arch` — the
                      ~8x power / ~2.8x energy headline — plus the same
                      table from the analytic model for contrast.
- ``model_vs_measured``  predicted-vs-measured *rankings* of the PR 1
                      fused vs transpose-based 2-D paths: the model is
                      useful iff it orders real candidates correctly.
- ``prune``           the model-pruned autotuner vs the full measuring
                      tuner: candidates measured, winners, agreement.

``--smoke`` shrinks sizes for CI; the full run covers the 256/512
ranking cases the regression tests pin.

Usage: ``python -m benchmarks.table5_wormhole_model [--smoke]``
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import clear_plan_cache, get_plan
from repro.core.complexmath import SplitComplex
from repro.core.plan import FFTPlan, _time_candidates
from repro.tt import report as ttreport
from repro.tt import trace as tttrace
from .common import write_json

BENCH_JSON = "BENCH_wormhole_model.json"

MODEL_ARCHS = ("wormhole_n300", "tpu_v5e")


def _candidate_plans(size: int):
    return [
        ("fused/bb1", FFTPlan(shape=(size, size), algo="fused",
                              backend="pallas", block_batch=1)),
        ("row_col/bb8", FFTPlan(shape=(size, size), algo="row_col",
                                backend="pallas", block_batch=8)),
    ]


def model_vs_measured(sizes) -> dict:
    out = {}
    rng = np.random.default_rng(0)
    for size in sizes:
        # small images are tens of ms in interpret mode — inside a shared
        # box's noise floor — so measure them on a batch
        batch = 4 if size <= 256 else 1
        cands = _candidate_plans(size)
        shp = (batch, size, size)
        x = SplitComplex(jnp.asarray(rng.standard_normal(shp), jnp.float32),
                         jnp.asarray(rng.standard_normal(shp), jnp.float32))
        measured_us, _ = _time_candidates([p for _, p in cands], x, iters=3)
        row = {"batch": batch,
               "measured_us": {lbl: round(us, 1)
                               for (lbl, _), us in zip(cands, measured_us)}}
        m_order = [cands[i][0] for i in
                   sorted(range(len(cands)), key=measured_us.__getitem__)]
        row["measured_order"] = m_order
        for arch in MODEL_ARCHS:
            pred = [tttrace.predict_cost(p, arch=arch, batch=batch)
                    for _, p in cands]
            p_order = [cands[i][0] for i in
                       sorted(range(len(cands)), key=pred.__getitem__)]
            row[f"predicted_us_{arch}"] = {
                lbl: round(c * 1e6, 2) for (lbl, _), c in zip(cands, pred)}
            row[f"predicted_order_{arch}"] = p_order
            row[f"ranking_agrees_{arch}"] = p_order == m_order
        out[f"{size}x{size}"] = row
        print(f"table5/rank_{size}: measured={m_order} "
              f"agree={[row[f'ranking_agrees_{a}'] for a in MODEL_ARCHS]}")
    return out


def prune_section(size: int, tune_batch: int) -> dict:
    clear_plan_cache()
    full = get_plan((size, size), backend="pallas", tune=True,
                    tune_batch=tune_batch)
    clear_plan_cache()
    pruned = get_plan((size, size), backend="pallas", tune=True,
                      tune_batch=tune_batch, prune="model")
    clear_plan_cache()
    out = {
        "size": size,
        "full_report": full.tune_report,
        "pruned_report": pruned.tune_report,
        "full_winner": f"{full.algo}/r{full.radix}/bb{full.block_batch}",
        "pruned_winner":
            f"{pruned.algo}/r{pruned.radix}/bb{pruned.block_batch}",
        "fewer_measured": pruned.tune_report["n_measured"]
            < full.tune_report["n_measured"],
        "same_winner_algo": full.algo == pruned.algo,
    }
    print(f"table5/prune_{size}: measured "
          f"{pruned.tune_report['n_measured']}/"
          f"{full.tune_report['n_candidates']}, winners "
          f"{out['full_winner']} vs {out['pruned_winner']}")
    return out


def run(smoke: bool = False) -> dict:
    sizes = (64, 128) if smoke else (256, 512)
    paper_rows = ttreport.compare(source="paper")
    model_rows = ttreport.compare(source="model", sizes=sizes)
    print(ttreport.markdown_table(paper_rows))
    paper = {
        "paper_rows": paper_rows,
        "model_rows": model_rows,
        "markdown": ttreport.markdown_table(paper_rows),
    }
    write_json(BENCH_JSON, "paper_table", paper)
    ranks = model_vs_measured(sizes)
    write_json(BENCH_JSON, "model_vs_measured", ranks)
    # tune_batch=2 keeps the fused/bb2 candidate alive so the 3-candidate
    # grid is actually prunable in both smoke and full modes
    prune = prune_section(sizes[-1], tune_batch=2)
    write_json(BENCH_JSON, "prune", prune)
    return {"paper_table": paper, "model_vs_measured": ranks, "prune": prune}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI smoke runs")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
