"""Table 8 — resilience: the fault-sweep matrix and the guard overhead pin.

Two sections land in BENCH_resilience.json:

- ``fault_matrix``: every instrumented fault site x fault kind, injected
  via :mod:`repro.resilience.faults` and driven through the production
  path it targets.  Each row records whether the fault was *detected*
  (guard / checksum / breaker / warning fired), *recovered* (the call
  still completed), and whether the recovered result matches the
  fault-free reference (``max_rel_err`` <= 1e-6, or exact).  The run
  **asserts zero silent corruptions**: every injected fault must be
  detected-and-recovered-correct or detected-and-reported — a wrong
  answer that looks healthy fails the benchmark, in CI too.
- ``guard_overhead``: interleaved A/B wall time of the guarded executor
  on the fused 2-D path with ``guard_level="off"`` vs ``"basic"`` (the
  production default, a NaN/Inf scan).  The full run measures 1024x1024
  and asserts overhead <= 5%; ``--smoke`` measures 256x256 and only
  records the number.

Usage: ``python -m benchmarks.table8_resilience [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core.complexmath import SplitComplex

from .common import write_json

BENCH_JSON = "BENCH_resilience.json"
DEVICES = 8


def _row(site, kind, detected, recovered, max_rel_err, note=""):
    correct = max_rel_err is not None and max_rel_err <= 1e-6
    row = {"site": site, "kind": kind, "detected": bool(detected),
           "recovered": bool(recovered),
           "result_correct": bool(correct) if max_rel_err is not None
           else None,
           "max_rel_err": max_rel_err, "note": note}
    print(f"table8/{site}/{kind},detected={row['detected']},"
          f"recovered={row['recovered']},correct={row['result_correct']},"
          f"err={max_rel_err}")
    return row


def _rel_err(a: SplitComplex, b: SplitComplex) -> float:
    num = max(float(jnp.max(jnp.abs(a.re - b.re))),
              float(jnp.max(jnp.abs(a.im - b.im))))
    den = max(float(jnp.max(jnp.abs(b.re))), float(jnp.max(jnp.abs(b.im))))
    return num / den


def local_fault_rows(tmpdir: str) -> list:
    """plan/autotune/wisdom/serve sites, in-process."""
    from repro import resilience
    from repro.resilience import config as rcfg
    from repro.resilience import executor, faults, policy

    rows = []
    rng = np.random.default_rng(0)
    x = SplitComplex(jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
                     jnp.asarray(rng.standard_normal((64, 64)), jnp.float32))

    def fresh():
        resilience.reset()
        plan_lib.clear_plan_cache()
        rcfg.configure(guard_level="full")
        ref = plan_lib.get_plan((64, 64), backend="jnp")._execute(x)
        return plan_lib.get_plan((64, 64), backend="pallas"), ref

    # kernel-launch failure
    pl, ref = fresh()
    key = plan_lib._plan_key((64, 64), jnp.float32, False, "pallas", "c2c")
    with faults.inject("plan.execute", "error") as fp:
        y = pl(x)
    rows.append(_row("plan.execute", "error",
                     detected=executor.stats(key)["failures"] == 1
                     and fp.fired() == 1,
                     recovered=True, max_rel_err=_rel_err(y, ref),
                     note="breaker counted the failure; jnp fallback served"))

    # kernel-output corruption, every array kind
    for kind in ("nan", "inf", "corrupt", "drop"):
        pl, ref = fresh()
        with faults.inject("plan.output", kind):
            y = pl(x)
        st = executor.stats(key)
        rows.append(_row("plan.output", kind,
                         detected=st["failures"] == 1,
                         recovered=True, max_rel_err=_rel_err(y, ref),
                         note=str(st["last_reason"])[:80]))

    # slow candidate during autotune: watchdog excludes it
    resilience.reset()
    plan_lib.clear_plan_cache()
    rcfg.configure(measure_timeout_s=0.5)
    with faults.inject("autotune.measure", "hang", duration=2.0,
                       tag="four_step", times=None):
        tuned = plan_lib.get_plan((64,), backend="jnp", tune=True)
    rows.append(_row("autotune.measure", "hang",
                     detected="four_step" in tuned.tune_report.get(
                         "timeouts", ""),
                     recovered=tuned.tuned
                     and tuned.tune_report["winner"] != "four_step",
                     max_rel_err=0.0,
                     note=f"winner={tuned.tune_report['winner']}"))

    # torn wisdom write: crash mid-save must leave the target intact
    resilience.reset()
    plan_lib.clear_plan_cache()
    path = os.path.join(tmpdir, "wisdom.json")
    plan_lib.get_plan((256,), tune=True)
    plan_lib.save_wisdom(path)
    before = open(path).read()
    crashed = False
    with faults.inject("wisdom.save", "error"):
        try:
            plan_lib.save_wisdom(path)
        except faults.FaultInjected:
            crashed = True
    plan_lib.clear_plan_cache()
    rows.append(_row("wisdom.save", "error",
                     detected=crashed,
                     recovered=open(path).read() == before
                     and plan_lib.load_wisdom(path) == 1,
                     max_rel_err=0.0, note="os.replace atomicity"))

    # serve pre-warm failure: degrade, keep serving, same tokens
    import repro.configs as C
    from repro.models import model as M
    from repro.serve.engine import Engine, ServeConfig

    resilience.reset()
    plan_lib.clear_plan_cache()
    cfg = C.get_config("fnet_demo").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([5, 6, 7], np.int32)
    want = Engine(cfg, ServeConfig(batch_size=2, max_len=64),
                  params).run([(0, prompt)], max_new=4)[0]
    with faults.inject("serve.prewarm", "error"):
        eng = Engine(cfg, ServeConfig(batch_size=2, max_len=64), params)
    got = eng.run([(0, prompt)], max_new=4)[0]
    rows.append(_row("serve.prewarm", "error",
                     detected=eng.degraded,
                     recovered=got == want, max_rel_err=0.0,
                     note=str(eng.degrade_reason)[:60]))

    resilience.reset()
    plan_lib.clear_plan_cache()
    return rows


_DIST_CODE = r"""
import json
import numpy as np
import jax.numpy as jnp
from repro.core.complexmath import SplitComplex
from repro.dist import pencil
from repro.dist._compat import make_mesh
from repro.resilience import faults

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = SplitComplex(jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
                 jnp.asarray(rng.standard_normal((64, 64)), jnp.float32))
ref = pencil.pfft2(x, mesh)
rows = []
for kind in ("drop", "corrupt", "nan"):
    pencil.reset_exchange_log()
    with faults.inject("dist.exchange", kind):
        out = pencil.pfft2(x, mesh, verify=True)
    log = pencil.exchange_log()
    err = max(float(np.abs(np.asarray(out.re) - np.asarray(ref.re)).max()),
              float(np.abs(np.asarray(out.im) - np.asarray(ref.im)).max()))
    scale = float(np.abs(np.asarray(ref.re)).max())
    rows.append({"site": "dist.exchange", "kind": kind,
                 "detected": [e["ok"] for e in log] == [False, True],
                 "recovered": True, "max_rel_err": err / scale,
                 "note": "checksum mismatch -> one retry, clean re-run"})
print("TABLE8_JSON " + json.dumps(rows))
"""


def dist_fault_rows(devices: int = DEVICES) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_CODE], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, \
        f"dist subprocess failed:\nSTDOUT:{proc.stdout}\n" \
        f"STDERR:{proc.stderr[-3000:]}"
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("TABLE8_JSON "):
            for r in json.loads(line[len("TABLE8_JSON "):]):
                rows.append(_row(r["site"], r["kind"], r["detected"],
                                 r["recovered"], r["max_rel_err"],
                                 r["note"]))
            return rows
    raise AssertionError(f"no TABLE8_JSON line in:\n{proc.stdout}")


def guard_overhead(n: int, *, iters: int = 15) -> dict:
    """Interleaved eager A/B: guarded executor with guards off vs the
    basic NaN/Inf scan, on the fused (n, n) pallas path."""
    import time as _time

    from repro import resilience
    from repro.resilience import config as rcfg

    resilience.reset()
    plan_lib.clear_plan_cache()
    rng = np.random.default_rng(0)
    x = SplitComplex(jnp.asarray(rng.standard_normal((n, n)), jnp.float32),
                     jnp.asarray(rng.standard_normal((n, n)), jnp.float32))
    pl = plan_lib.get_plan((n, n), backend="pallas")

    def run_at(level):
        with rcfg.overrides(guard_level=level):
            t0 = _time.perf_counter()
            jax.block_until_ready(pl(x).re)
            return _time.perf_counter() - t0

    for level in ("off", "basic"):              # warm both traces
        run_at(level)
    t_off, t_basic = [], []
    for _ in range(iters):
        t_off.append(run_at("off"))
        t_basic.append(run_at("basic"))
    us_off = float(np.median(t_off) * 1e6)
    us_basic = float(np.median(t_basic) * 1e6)
    overhead = us_basic / us_off - 1.0
    print(f"table8/guard_overhead_{n},off_us={us_off:.1f},"
          f"basic_us={us_basic:.1f},overhead={overhead * 100:.2f}%")
    resilience.reset()
    plan_lib.clear_plan_cache()
    return {"size": f"{n}x{n}", "us_guard_off": us_off,
            "us_guard_basic": us_basic, "overhead_frac": overhead,
            "acceptance": "<= 0.05 at 1024x1024 (full run)"}


def run(smoke: bool = False) -> dict:
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        rows = local_fault_rows(td)
    rows += dist_fault_rows()

    silent = [r for r in rows
              if not (r["detected"] and r["recovered"]
                      and r["result_correct"] in (True, None))]
    assert not silent, f"SILENT CORRUPTIONS: {silent}"
    matrix = {f"{r['site']}/{r['kind']}": r for r in rows}
    matrix["silent_corruptions"] = 0
    write_json(BENCH_JSON, "fault_matrix", matrix)

    ov = guard_overhead(256 if smoke else 1024)
    if not smoke:
        assert ov["overhead_frac"] <= 0.05, \
            f"guard overhead {ov['overhead_frac']:.3f} > 5%"
    write_json(BENCH_JSON, "guard_overhead", ov)
    return {"fault_matrix": matrix, "guard_overhead": ov}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI smoke runs")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
