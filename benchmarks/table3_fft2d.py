"""Paper Table 3 — 2D FFT 1024x1024 scale-up + power/energy comparison.

Paper: 24-core Xeon 10.24 ms @ 353 W (3.62 J) vs 64 Tensix 23.56 ms @ 42 W
(0.99 J) — the accelerator is slower but 3.6x more energy-efficient.

Here: (a) measured wall time of this repo's fft2 on the host CPU;
(b) a MODELLED TPU v5e estimate from the roofline terms of the compiled
single-chip program (compute/memory bound, whichever dominates) — no TPU
hardware is present, so energy = modelled time x 215 W chip power, clearly
labelled as a model; (c) the distributed pencil version's collective bytes
per chip (the paper's identified multi-card bottleneck), from the 8-way
shard_map lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hloparse import analyze
from repro.analysis.roofline import HW
from repro.core import fft2, from_complex
from .common import emit, time_fn

H = W = 1024


def run():
    rng = np.random.default_rng(0)
    z = from_complex(jnp.asarray(
        rng.standard_normal((H, W)) + 1j * rng.standard_normal((H, W)),
        jnp.complex64))

    fn = jax.jit(lambda q: fft2(q))
    us = time_fn(fn, z)
    ref = np.fft.fft2(np.asarray(z.re) + 1j * np.asarray(z.im))
    out = fn(z)
    err = np.abs((np.asarray(out.re) + 1j * np.asarray(out.im)) - ref).max() \
        / np.abs(ref).max()
    emit("table3/fft2_1024_host_cpu", us, f"rel_err={err:.1e}")

    # modelled v5e single-chip estimate from the compiled HLO
    cost = analyze(jax.jit(lambda q: fft2(q)).lower(z).compile().as_text())
    compute_s = cost.flops / HW["peak_flops_f32"]
    memory_s = cost.traffic / HW["hbm_bw"]
    step_s = max(compute_s, memory_s)
    energy = step_s * HW["chip_power_w"]
    emit("table3/fft2_1024_v5e_model", step_s * 1e6,
         f"modelled;compute_s={compute_s:.2e};memory_s={memory_s:.2e};"
         f"energy_j={energy:.4f}")

    # paper reference rows for side-by-side reading
    emit("table3/paper_xeon_24c", 10_240.0, "power_w=353;energy_j=3.62")
    emit("table3/paper_wormhole_64tensix", 23_560.0,
         "power_w=42;energy_j=0.99")
