"""Paper Table 3 — 2D FFT 1024x1024 scale-up + power/energy comparison.

Paper: 24-core Xeon 10.24 ms @ 353 W (3.62 J) vs 64 Tensix 23.56 ms @ 42 W
(0.99 J) — the accelerator is slower but 3.6x more energy-efficient.

Here: (a) measured wall time of this repo's fft2 on the host CPU;
(b) the fused transpose-free Pallas kernel vs the transpose-based
two-kernel pipeline **on the same backend** — the paper's §5 finding is that
the global transpose dominates, so eliminating its HBM round-trip is the
headline row; (c) MODELLED TPU v5e estimates from the roofline traffic model
(repro.analysis.roofline.fft2d_traffic_bytes), which credits the fused path
with 2 instead of 8 HBM plane-traversals — no TPU hardware is present, so
energy = modelled time x 215 W chip power, clearly labelled as a model.

All rows land in BENCH_fft2d.json (section "table3").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hloparse import analyze
from repro.analysis.roofline import HW, fft2d_roofline
from repro.core import fft2, from_complex
from repro.kernels import ops
from .common import emit, time_fn, time_fn_pair, write_json

H = W = 1024
BENCH_JSON = "BENCH_fft2d.json"


def run():
    sink = {}
    rng = np.random.default_rng(0)
    z = from_complex(jnp.asarray(
        rng.standard_normal((H, W)) + 1j * rng.standard_normal((H, W)),
        jnp.complex64))
    ref = np.fft.fft2(np.asarray(z.re) + 1j * np.asarray(z.im))

    def _err(out):
        return np.abs((np.asarray(out.re) + 1j * np.asarray(out.im))
                      - ref).max() / np.abs(ref).max()

    # (a) host jnp row-column baseline (1-D passes resolve via plans;
    # resolve_algo(1024) picks four_step)
    fn = jax.jit(lambda q: fft2(q))
    us_host = time_fn(fn, z)
    emit("table3/fft2_1024_host_cpu", us_host, f"rel_err={_err(fn(z)):.1e}",
         sink)

    # (b) fused vs transpose-based on the same (pallas) backend — timed
    # interleaved because the ratio gates the acceptance criterion
    fn_t = jax.jit(lambda q: fft2(q, backend="pallas", algo="row_col"))
    fn_f = jax.jit(lambda q: ops.fft2d_fused(q))
    us_transpose, us_fused = time_fn_pair(fn_t, fn_f, z)
    emit("table3/fft2_1024_pallas_transpose", us_transpose,
         f"rel_err={_err(fn_t(z)):.1e};2x fft_stockham kernel + 2 HBM "
         "transposes", sink)
    err_fused = _err(fn_f(z))
    emit("table3/fft2_1024_pallas_fused", us_fused,
         f"rel_err={err_fused:.1e};single kernel, transpose in VMEM", sink)
    emit("table3/fused_speedup_vs_transpose", us_transpose / us_fused,
         "ratio(us_transpose/us_fused);acceptance >= 1.3", sink)

    # (c) modelled v5e single-chip estimates
    cost = analyze(jax.jit(lambda q: fft2(q)).lower(z).compile().as_text())
    compute_s = cost.flops / HW["peak_flops_f32"]
    memory_s = cost.traffic / HW["hbm_bw"]
    step_s = max(compute_s, memory_s)
    energy = step_s * HW["chip_power_w"]
    emit("table3/fft2_1024_v5e_model", step_s * 1e6,
         f"modelled;compute_s={compute_s:.2e};memory_s={memory_s:.2e};"
         f"energy_j={energy:.4f}", sink)

    # roofline traffic model: the transpose's HBM round-trips eliminated
    for fused in (False, True):
        r = fft2d_roofline(H, W, fused=fused)
        tag = "fused" if fused else "transpose"
        emit(f"table3/fft2_1024_v5e_model_{tag}", r["step_s"] * 1e6,
             f"modelled;traffic_bytes={r['traffic_bytes']:.3e};"
             f"dominant={r['dominant'].replace('_s', '')};"
             f"energy_j={r['energy_j']:.5f}", sink)

    # paper reference rows for side-by-side reading
    emit("table3/paper_xeon_24c", 10_240.0, "power_w=353;energy_j=3.62", sink)
    emit("table3/paper_wormhole_64tensix", 23_560.0,
         "power_w=42;energy_j=0.99", sink)

    write_json(BENCH_JSON, "table3", sink)
    return sink
