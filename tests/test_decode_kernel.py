"""Flash-decode Pallas kernel vs the dense oracle: shape/GQA/window sweeps,
ring-cache semantics (negative positions), dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _setup(b, s, h, kvh, d, dtype=jnp.float32, seed=0, fill=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, d), dtype)
    fill = s if fill is None else fill
    kv_pos = jnp.where(jnp.arange(s) < fill, jnp.arange(s), -1)
    kv_pos = jnp.broadcast_to(kv_pos, (b, s)).astype(jnp.int32)
    q_pos = jnp.full((b,), fill - 1, jnp.int32)
    return q, k, v, kv_pos, q_pos


@pytest.mark.parametrize("b,s,h,kvh,d", [
    (2, 128, 4, 2, 16),
    (3, 512, 8, 8, 32),       # MHA, batch padding path
    (8, 1024, 8, 2, 64),      # GQA 4x
])
def test_matches_oracle(b, s, h, kvh, d):
    q, k, v, kv_pos, q_pos = _setup(b, s, h, kvh, d)
    got = ops.decode_attention(q, k, v, kv_pos, q_pos, chunk=128)
    want = ref.decode_attention_ref(q, k, v, kv_pos, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_partial_cache_fill():
    """Empty slots (pos = -1) must be masked out."""
    q, k, v, kv_pos, q_pos = _setup(2, 256, 4, 2, 16, fill=100)
    got = ops.decode_attention(q, k, v, kv_pos, q_pos, chunk=64)
    want = ref.decode_attention_ref(q, k, v, kv_pos, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_sliding_window():
    q, k, v, kv_pos, q_pos = _setup(2, 256, 4, 2, 16, seed=3)
    got = ops.decode_attention(q, k, v, kv_pos, q_pos, window=64, chunk=64)
    want = ref.decode_attention_ref(q, k, v, kv_pos, q_pos, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_bf16_cache():
    q, k, v, kv_pos, q_pos = _setup(2, 256, 4, 2, 16, dtype=jnp.bfloat16,
                                    seed=5)
    got = ops.decode_attention(q, k, v, kv_pos, q_pos, chunk=64)
    want = ref.decode_attention_ref(q, k, v, kv_pos, q_pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=5e-2)


def test_chunk_invariance():
    """Result must not depend on the chunking."""
    q, k, v, kv_pos, q_pos = _setup(2, 512, 4, 4, 32, seed=7)
    a = ops.decode_attention(q, k, v, kv_pos, q_pos, chunk=512)
    b = ops.decode_attention(q, k, v, kv_pos, q_pos, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
