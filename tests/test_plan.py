"""FFTPlan dispatch: algo auto-selection, the registry cache, the autotuner
(including model pruning), wisdom persistence, rfft-kind plans, and the
Pallas backend."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FFTPlan, autotune_count, clear_plan_cache, fft,
                        from_complex, get_plan, load_wisdom, plan_fft,
                        plan_fft2, plan_ifft, resolve_algo, save_wisdom,
                        to_complex)


def test_auto_algo_selection():
    assert plan_fft(128).algo == "naive"
    assert plan_fft(4096).algo == "four_step"
    assert plan_fft(100).algo == "naive"
    assert plan_fft(1000).algo == "bluestein"
    assert plan_fft(1 << 21).algo == "stockham"


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("n", [512, 4096])
def test_plan_executes(backend, n):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((4, n)) + 1j * rng.standard_normal((4, n))) \
        .astype(np.complex64)
    plan = plan_fft(n, backend=backend)
    got = np.asarray(to_complex(plan(from_complex(jnp.asarray(x)))))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(got, ref, atol=5e-4 * np.abs(ref).max())


def test_inverse_plan_roundtrip():
    rng = np.random.default_rng(1)
    x = (rng.standard_normal((2, 1024)) + 1j * rng.standard_normal((2, 1024))) \
        .astype(np.complex64)
    z = from_complex(jnp.asarray(x))
    back = plan_ifft(1024)(plan_fft(1024)(z))
    np.testing.assert_allclose(np.asarray(to_complex(back)), x, atol=2e-3)


def test_pallas_backend_falls_back_for_nonpow2():
    plan = FFTPlan.create(1000, backend="pallas")
    assert plan.backend == "jnp"            # bluestein has no kernel path


def test_resolve_algo_shared_table():
    """plan and fft1d dispatch through the one size table (no drift)."""
    for n in (100, 128, 1000, 4096, 1 << 21):
        assert plan_fft(n).algo == resolve_algo(n)


def test_plan_cache_returns_same_object():
    """Identical (shape, dtype, direction, backend) -> the same plan object."""
    assert plan_fft(2048) is plan_fft(2048)
    assert plan_fft(2048) is get_plan((2048,))
    assert plan_fft2(64, 64) is plan_fft2(64, 64)
    # any key component changing gives a distinct plan
    assert plan_fft(2048) is not plan_fft(2048, inverse=True)
    assert plan_fft(2048) is not plan_fft(2048, backend="pallas")
    assert plan_fft(2048) is not plan_fft(2048, dtype=jnp.bfloat16)


def test_explicit_algo_does_not_pollute_auto_cache():
    """An algo override must never become the cached plan for the auto key."""
    clear_plan_cache()
    forced = plan_fft(4096, algo="naive")       # cold key, explicit algo
    assert forced.algo == "naive"
    auto = plan_fft(4096)
    assert auto.algo == resolve_algo(4096) == "four_step"
    assert plan_fft(4096) is auto


def test_fused_algo_demotes_with_backend():
    # non-pow2 kills the pallas backend; algo="fused" must demote with it
    plan = plan_fft2(12, 20, backend="pallas", algo="fused")
    assert plan.backend == "jnp" and plan.algo == "row_col"
    # and on the jnp backend outright, fused is an error at the direct path
    from repro.core.fft2d import _fft2_direct
    from repro.core.complexmath import SplitComplex
    z = SplitComplex(jnp.zeros((4, 4)), jnp.zeros((4, 4)))
    with pytest.raises(ValueError, match="fused"):
        _fft2_direct(z, algo="fused", backend="jnp")


def test_fft_auto_routes_through_registry():
    rng = np.random.default_rng(3)
    x = (rng.standard_normal((2, 512)) + 1j * rng.standard_normal((2, 512))) \
        .astype(np.complex64)
    z = from_complex(jnp.asarray(x))
    before = plan_fft(512)
    got = np.asarray(to_complex(fft(z)))
    np.testing.assert_allclose(got, np.fft.fft(x),
                               atol=5e-4 * np.abs(np.fft.fft(x)).max())
    assert plan_fft(512) is before          # fft() reused the cached plan


def test_autotune_runs_at_most_once_per_key():
    p1 = plan_fft(256, tune=True)
    assert p1.tuned and p1.tune_report and "winner" in p1.tune_report
    p2 = plan_fft(256, tune=True)
    assert p1 is p2
    assert autotune_count((256,)) == 1
    # un-tuned request for the same key also reuses the tuned plan
    assert plan_fft(256) is p1


def test_model_prune_measures_fewer_same_winner():
    """Acceptance: prune="model" measures strictly fewer candidates than
    the full tuner and still lands on the same winner of the
    fused-vs-transpose decision at 512x512."""
    clear_plan_cache()
    full = get_plan((512, 512), backend="pallas", tune=True, tune_batch=2)
    clear_plan_cache()
    pruned = get_plan((512, 512), backend="pallas", tune=True, tune_batch=2,
                      prune="model")
    clear_plan_cache()
    assert full.tune_report["n_measured"] == full.tune_report["n_candidates"]
    assert pruned.tune_report["n_measured"] < full.tune_report["n_measured"]
    assert pruned.tune_report["n_candidates"] == \
        full.tune_report["n_candidates"]
    assert "model_pruned" in pruned.tune_report
    # same winner of the fused-vs-transpose decision
    assert full.algo == pruned.algo == "fused"
    # the heuristic default config is always in the measured set
    assert "default" in pruned.tune_report


def test_wisdom_roundtrip_skips_remeasure(tmp_path):
    path = str(tmp_path / "wisdom.json")
    clear_plan_cache()
    tuned = get_plan((256,), tune=True)
    assert save_wisdom(path) == 1
    clear_plan_cache()
    assert load_wisdom(path) == 1
    again = get_plan((256,), tune=True)       # must not re-measure
    assert again.tuned and again.tune_report["source"] == "wisdom"
    assert autotune_count((256,)) == 0
    assert (again.algo, again.radix, again.block_batch) == \
        (tuned.algo, tuned.radix, tuned.block_batch)
    clear_plan_cache()


def test_wisdom_version_and_hash_guards(tmp_path):
    import json
    from repro.core import plan as plan_mod
    path = str(tmp_path / "wisdom.json")
    clear_plan_cache()
    get_plan((256,), tune=True)
    save_wisdom(path)
    clear_plan_cache()
    # stale version: refused (0 loaded), strict raises
    data = json.load(open(path))
    data["version"] = plan_mod.WISDOM_VERSION + 1
    json.dump(data, open(path, "w"))
    assert load_wisdom(path) == 0
    with pytest.raises(ValueError, match="version"):
        load_wisdom(path, strict=True)
    # tampered key: entry skipped by the hash guard
    data["version"] = plan_mod.WISDOM_VERSION
    good = dict(data["entries"][0])
    data["entries"][0]["key"] = data["entries"][0]["key"].replace("256", "512")
    json.dump(data, open(path, "w"))
    assert load_wisdom(path) == 0
    with pytest.raises(ValueError, match="hash"):
        load_wisdom(path, strict=True)
    # tampered *value* (the hash covers algo/radix/block_batch too), and a
    # malformed entry: both skipped without strict, raised with it
    data["entries"] = [dict(good, algo="fused"), {"key": good["key"]}]
    json.dump(data, open(path, "w"))
    assert load_wisdom(path) == 0
    with pytest.raises(ValueError):
        load_wisdom(path, strict=True)
    clear_plan_cache()


def test_rfft_kind_interned_separately():
    """rfft/irfft/rfft2/irfft2 resolve once under kind="rfft" keys that
    never collide with the c2c plans of the same shape."""
    clear_plan_cache()
    r = get_plan((512,), kind="rfft")
    c = get_plan((512,))
    assert r is not c and r.kind == "rfft" and c.kind == "c2c"
    assert r is get_plan((512,), kind="rfft")
    # forward resolves the inner half-length transform, inverse full-length
    assert r.algo == resolve_algo(256)
    ri = get_plan((512,), kind="rfft", inverse=True)
    assert ri.algo == resolve_algo(512)
    r2 = get_plan((64, 128), kind="rfft")
    assert r2 is get_plan((64, 128), kind="rfft")
    clear_plan_cache()


def test_rfft_plan_executes_correctly():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((3, 256)).astype(np.float32)
    from repro.core import irfft, rfft
    got = np.asarray(to_complex(rfft(jnp.asarray(x))))
    ref = np.fft.rfft(x)
    np.testing.assert_allclose(got, ref, atol=5e-4 * np.abs(ref).max())
    back = np.asarray(irfft(rfft(jnp.asarray(x))))
    np.testing.assert_allclose(back, x, atol=2e-4)
    img = rng.standard_normal((2, 32, 64)).astype(np.float32)
    from repro.core import irfft2, rfft2
    got2 = np.asarray(to_complex(rfft2(jnp.asarray(img))))
    ref2 = np.fft.rfft2(img)
    np.testing.assert_allclose(got2, ref2, atol=5e-4 * np.abs(ref2).max())
    back2 = np.asarray(irfft2(rfft2(jnp.asarray(img))))
    np.testing.assert_allclose(back2, img, atol=2e-4)


def test_tuned_2d_plan_executes():
    plan = plan_fft2(32, 32, backend="pallas", tune=True)
    assert plan.tuned
    rng = np.random.default_rng(4)
    z = (rng.standard_normal((32, 32)) + 1j * rng.standard_normal((32, 32))) \
        .astype(np.complex64)
    got = np.asarray(to_complex(plan(from_complex(jnp.asarray(z)))))
    ref = np.fft.fft2(z)
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4
    assert autotune_count((32, 32), backend="pallas") == 1


# ---------------------------------------------------------------------------
# Wisdom auto-load from $REPRO_FFT_WISDOM (import-time, subprocess-tested)
# ---------------------------------------------------------------------------

def _import_with_wisdom_env(value):
    """Import repro.core.plan in a fresh interpreter with REPRO_FFT_WISDOM
    set (or unset for None) and report (autoloaded_count, tuned_plan_info)."""
    import os
    import subprocess
    import sys
    code = (
        "from repro.core import plan as P\n"
        "pl = P.get_plan((256,), tune=True)\n"
        "src = (pl.tune_report or {}).get('source', 'measured')\n"
        "print('WISDOM', P.WISDOM_AUTOLOADED, src, P.autotune_count((256,)))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.pop("REPRO_FFT_WISDOM", None)
    if value is not None:
        env["REPRO_FFT_WISDOM"] = value
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("WISDOM")][0]
    _, count, source, tuned_runs = line.split()
    return int(count), source, int(tuned_runs)


def test_wisdom_autoload_from_env(tmp_path):
    """A valid wisdom file named by $REPRO_FFT_WISDOM installs its plans at
    import, so a later tune=True request skips the measuring autotuner."""
    path = str(tmp_path / "wisdom.json")
    clear_plan_cache()
    get_plan((256,), tune=True)
    assert save_wisdom(path) == 1
    clear_plan_cache()
    count, source, tuned_runs = _import_with_wisdom_env(path)
    assert (count, source, tuned_runs) == (1, "wisdom", 0)


def test_wisdom_autoload_unset_missing_and_corrupt(tmp_path):
    """Unset, empty, missing-file and corrupt-file paths must all be
    harmless no-ops at import (the registry simply starts cold)."""
    for value in (None, "", str(tmp_path / "nope.json")):
        count, source, tuned_runs = _import_with_wisdom_env(value)
        assert (count, source, tuned_runs) == (0, "measured", 1), value
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{not json")
    count, source, tuned_runs = _import_with_wisdom_env(str(corrupt))
    assert (count, source, tuned_runs) == (0, "measured", 1)
    # ...and wrong-schema-but-valid-JSON files are equally harmless, down
    # to a top-level type that is not even a dict
    for text in ('{"version": 1, "entries": [{"key": 3}]}', "[1, 2, 3]",
                 '{"version": 1, "entries": 7}'):
        corrupt.write_text(text)
        count, source, tuned_runs = _import_with_wisdom_env(str(corrupt))
        assert (count, source, tuned_runs) == (0, "measured", 1), text


def test_wisdom_v3_variant_roundtrip_subprocess(tmp_path):
    """Wisdom v3 carries the GEMM precision variant: a bf16 key tuned to
    "compensated" must come back "compensated" in a fresh process (v2
    files silently resurrected plain-table winners, which is the bug the
    version bump guards against)."""
    import dataclasses
    import json
    import os
    import subprocess
    import sys
    from repro.core import plan as plan_mod
    path = str(tmp_path / "wisdom.json")
    clear_plan_cache()
    auto = get_plan((64, 64), backend="pallas", dtype=jnp.bfloat16)
    assert auto.variant == "compensated"
    key = plan_mod._plan_key((64, 64), jnp.bfloat16, False, "pallas", "c2c")
    plan_mod._PLAN_CACHE[key] = dataclasses.replace(
        auto, tuned=True, tune_report={"winner": "default"})
    assert save_wisdom(path) == 1
    entry = json.load(open(path))["entries"][0]
    assert entry["variant"] == "compensated"
    clear_plan_cache()
    # fresh interpreter: autoload via $REPRO_FFT_WISDOM, report the variant
    code = (
        "import jax.numpy as jnp\n"
        "from repro.core import plan as P\n"
        "pl = P.get_plan((64, 64), backend='pallas', dtype=jnp.bfloat16,"
        " tune=True)\n"
        "print('VAR', pl.variant, pl.tuned,"
        " (pl.tune_report or {}).get('source'))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["REPRO_FFT_WISDOM"] = path
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("VAR")][0]
    assert line.split() == ["VAR", "compensated", "True", "wisdom"]
    # a v2 file (no variant in hash) is refused outright, never half-loaded
    data = json.load(open(path))
    data["version"] = 2
    json.dump(data, open(path, "w"))
    assert load_wisdom(path) == 0
    with pytest.raises(ValueError, match="version"):
        load_wisdom(path, strict=True)
    # tampering with the variant field breaks the v3 hash guard
    data["version"] = plan_mod.WISDOM_VERSION
    data["entries"][0]["variant"] = "plain"
    json.dump(data, open(path, "w"))
    assert load_wisdom(path) == 0
    with pytest.raises(ValueError, match="hash"):
        load_wisdom(path, strict=True)
    clear_plan_cache()
