"""FFTPlan dispatch: algorithm auto-selection and the Pallas backend."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FFTPlan, from_complex, plan_fft, plan_ifft, to_complex


def test_auto_algo_selection():
    assert plan_fft(128).algo == "naive"
    assert plan_fft(4096).algo == "four_step"
    assert plan_fft(100).algo == "naive"
    assert plan_fft(1000).algo == "bluestein"
    assert plan_fft(1 << 21).algo == "stockham"


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("n", [512, 4096])
def test_plan_executes(backend, n):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((4, n)) + 1j * rng.standard_normal((4, n))) \
        .astype(np.complex64)
    plan = plan_fft(n, backend=backend)
    got = np.asarray(to_complex(plan(from_complex(jnp.asarray(x)))))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(got, ref, atol=5e-4 * np.abs(ref).max())


def test_inverse_plan_roundtrip():
    rng = np.random.default_rng(1)
    x = (rng.standard_normal((2, 1024)) + 1j * rng.standard_normal((2, 1024))) \
        .astype(np.complex64)
    z = from_complex(jnp.asarray(x))
    back = plan_ifft(1024)(plan_fft(1024)(z))
    np.testing.assert_allclose(np.asarray(to_complex(back)), x, atol=2e-3)


def test_pallas_backend_falls_back_for_nonpow2():
    plan = FFTPlan.create(1000, backend="pallas")
    assert plan.backend == "jnp"            # bluestein has no kernel path