"""HLO analyzer + roofline math + sharding-rule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hloparse
from repro.analysis.roofline import HW, roofline_terms


def test_loop_aware_flops_scale_with_trip_count():
    def build(n):
        w = jnp.zeros((256, 256))
        def f(x):
            def body(c, _):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, None, length=n)[0]
        return jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 256), jnp.float32)).compile()
    f2 = hloparse.analyze(build(2).as_text()).flops
    f8 = hloparse.analyze(build(8).as_text()).flops
    assert 3.5 < f8 / f2 < 4.5                     # ~4x, not 1x
    expect = 8 * 2 * 64 * 256 * 256
    assert abs(f8 - expect) / expect < 0.05


def test_nested_tuple_while_parsed():
    """Nested carries (tuples of tuples) must not drop the while op."""
    def f(x):
        def body(carry, _):
            (a, b), c = carry
            return ((jnp.tanh(a @ b), b), c + 1.0), None
        w = jnp.zeros((64, 64))
        out, _ = jax.lax.scan(body, ((x, w), jnp.zeros(())), None, length=6)
        return out[0][0]
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    flops = hloparse.analyze(c.as_text()).flops
    expect = 6 * 2 * 64 * 64 * 64
    assert abs(flops - expect) / expect < 0.1, flops


def test_shape_bytes():
    assert hloparse.shape_bytes("f32[128,512]{1,0}") == 128 * 512 * 4
    assert hloparse.shape_bytes("bf16[4]") == 8
    assert hloparse.shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert hloparse.shape_bytes("pred[]") == 1


def test_roofline_terms_math():
    rec = {
        "mesh": "16x16", "devices": 256, "dtype": "bfloat16",
        "kind": "train", "global_batch": 256, "seq_len": 4096,
        "n_active": 1_000_000_000,
        "loop_aware": {"flops": 197e12, "traffic_bytes": 819e9,
                       "collective_total": 50e9},
    }
    t = roofline_terms(rec)
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["memory_s"] - 1.0) < 1e-6
    assert abs(t["collective_s"] - 1.0) < 1e-6
    model = 6 * 1e9 * 256 * 4096
    assert abs(t["model_flops"] - model) < 1
    assert t["chips"] == 256


def test_sharding_fit_degrades():
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import _fit
    mesh = make_mesh((1,), ("model",))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = _fit(("data", "model"), (32, 160), FakeMesh())
    assert spec[0] == "data" and spec[1] == "model"
    spec = _fit(("data", "model"), (30, 160), FakeMesh())
    assert spec[0] is None                       # 30 % 16 != 0 -> dropped
    spec = _fit((("data", "model"), None), (512, 7), FakeMesh())
    assert spec[0] == ("data", "model")          # 512 % 256 == 0


def test_skipped_cells_bookkeeping():
    import repro.configs as C
    assert len(C.SKIPPED_CELLS) == 8
    assert len(C.all_cells()) == 32
    assert len(C.all_cells(include_skipped=True)) == 40
    archs = {a for a, _, _ in C.SKIPPED_CELLS}
    assert "zamba2-2.7b" not in archs            # hybrid runs everything
    assert "h2o-danube-1.8b" not in archs        # SWA makes long_500k legal
