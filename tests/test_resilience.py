"""repro.resilience: fault injection, guards, circuit breaker, guarded
executor, autotune watchdog, serve degradation/deadlines, atomic wisdom."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import resilience
from repro.core import plan as P
from repro.core.complexmath import SplitComplex
from repro.resilience import config as rconfig
from repro.resilience import executor, faults, guards, policy
from repro.resilience.faults import FaultInjected, FaultPlan
from repro.resilience.policy import RUNTIME_DEMOTE_REASON

SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


@pytest.fixture(autouse=True)
def _isolate():
    resilience.reset()
    P.clear_plan_cache()
    yield
    resilience.reset()
    P.clear_plan_cache()


def _x(shape=(64, 64), seed=0):
    rng = np.random.default_rng(seed)
    return SplitComplex(
        jnp.asarray(rng.standard_normal(shape), jnp.float32),
        jnp.asarray(rng.standard_normal(shape), jnp.float32))


def _key(shape=(64, 64), kind="c2c", inverse=False):
    return P._plan_key(shape, jnp.float32, inverse, "pallas", kind)


# ---------------------------------------------------------------------------
# Fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_visit_schedule():
    fp = FaultPlan(seed=0).add("s", "error", after=2, times=2)
    fired = []
    with fp:
        for _ in range(6):
            try:
                faults.check("s")
                fired.append(False)
            except FaultInjected:
                fired.append(True)
    # skip 2, fire 2, then exhausted
    assert fired == [False, False, True, True, False, False]
    assert fp.fired("s") == 2


def test_fault_plan_seeded_prob_deterministic():
    def run(seed):
        out = []
        with FaultPlan(seed=seed).add("s", "error", prob=0.5, times=None):
            for _ in range(20):
                try:
                    faults.check("s")
                    out.append(0)
                except FaultInjected:
                    out.append(1)
        return out
    a, b = run(7), run(7)
    assert a == b                      # same seed, same schedule
    assert 0 < sum(a) < 20             # actually probabilistic
    assert run(8) != a                 # seed matters


def test_fault_tag_filtering_and_nesting_guard():
    fp = FaultPlan().add("s", "error", tag="pallas", times=None)
    with fp:
        faults.check("s", tag="jnp/row_col")          # no match: silent
        with pytest.raises(FaultInjected):
            faults.check("s", tag="pallas/fused/64x64")
        with pytest.raises(RuntimeError, match="already installed"):
            fp.__enter__()
    assert faults.active() is None


def test_apply_corruption_kinds():
    v = SplitComplex(jnp.ones((4,)), jnp.ones((4,)))
    for kind, probe in (("nan", lambda a: np.isnan(a).any()),
                        ("inf", lambda a: np.isinf(a).any()),
                        ("drop", lambda a: (a == 0).all()),
                        ("corrupt", lambda a: (np.abs(a) > 2).all())):
        with faults.inject("s", kind):
            got = faults.corrupt("s", v)
        assert probe(np.asarray(got.re)), kind


# ---------------------------------------------------------------------------
# Guards
# ---------------------------------------------------------------------------

def test_guards_pass_on_clean_outputs():
    for kind, inverse in (("c2c", False), ("c2c", True), ("rfft", False)):
        pl = P.get_plan((64, 64), kind=kind, inverse=inverse, backend="jnp")
        x = _x() if kind == "c2c" else _x().re
        rep = guards.check_output(pl, x, pl._execute(x), level="full")
        assert rep.ok, (kind, inverse, rep)
        assert abs(rep.checks["parseval_ratio"] - 1.0) < 1e-4


def test_guards_catch_each_corruption_class():
    pl = P.get_plan((64, 64), kind="rfft", backend="jnp")
    x = _x().re
    y = pl._execute(x)
    # NaN poison -> finite check
    bad = SplitComplex(y.re.at[0, 0].set(jnp.nan), y.im)
    assert "finite" in guards.check_output(pl, x, bad, level="full").reason
    # scaled payload stays finite -> Parseval catches it
    bad = SplitComplex(y.re * 1.5, y.im * 1.5)
    assert "Parseval" in guards.check_output(pl, x, bad, level="full").reason
    # symmetry break in the DC column, too small for Parseval to see
    bad = SplitComplex(y.re, y.im.at[1, 0].add(0.5 * float(
        jnp.max(jnp.abs(y.re)))))
    rep = guards.check_output(pl, x, bad, level="full")
    assert not rep.ok and "Hermitian" in rep.reason
    # basic level only scans for NaN/Inf: the scaled payload slips through
    assert guards.check_output(pl, x, SplitComplex(y.re * 1.5, y.im * 1.5),
                               level="basic").ok


def test_config_validation_and_overrides():
    with pytest.raises(KeyError):
        rconfig.configure(bogus=1)
    with pytest.raises(ValueError):
        rconfig.configure(guard_level="extreme")
    before = rconfig.get("failure_threshold")
    with rconfig.overrides(failure_threshold=9):
        assert rconfig.get("failure_threshold") == 9
    assert rconfig.get("failure_threshold") == before


# ---------------------------------------------------------------------------
# Guarded executor + circuit breaker (the deterministic lifecycle)
# ---------------------------------------------------------------------------

def test_breaker_demote_halfopen_repromote_cycle():
    """The acceptance-criterion lifecycle, fully call-counted: K failures
    open the circuit and demote the registry key; cooldown_calls
    short-circuited calls later the half-open probe re-promotes it."""
    rconfig.configure(failure_threshold=2, cooldown_calls=2)
    pl = P.get_plan((64, 64), backend="pallas")
    x = _x()
    ref = P.get_plan((64, 64), backend="jnp")._execute(x)
    key = _key()

    with faults.inject("plan.execute", "error", times=None):
        for _ in range(2):                      # K consecutive failures
            y = pl(x)                           # fallback serves the call
            np.testing.assert_allclose(np.asarray(y.re), np.asarray(ref.re))
    assert policy.breaker_state(key) == "open"
    demoted = P.get_plan((64, 64), backend="pallas")
    assert demoted.backend == "jnp"
    assert demoted.demote_reason == RUNTIME_DEMOTE_REASON

    pl2 = P.get_plan((64, 64), backend="pallas")   # a post-demotion holder
    pl2(x)                                      # cooldown call 1 (short)
    assert policy.breaker_state(key) == "open"
    pl2(x)                                      # call 2 -> half-open probe
    assert policy.breaker_state(key) == "closed"
    restored = P.get_plan((64, 64), backend="pallas")
    assert restored.backend == "pallas" and restored.demote_reason is None
    br = policy.breaker(key)
    assert br.transitions == ["open", "half_open", "closed"]
    st = executor.stats(key)
    assert st["failures"] == 2 and st["short_circuits"] == 1


def test_breaker_failed_probe_reopens():
    rconfig.configure(failure_threshold=1, cooldown_calls=1)
    pl = P.get_plan((64, 64), backend="pallas")
    x = _x()
    with faults.inject("plan.execute", "error", times=3):
        pl(x)                                   # failure -> open
        assert policy.breaker_state(_key()) == "open"
        pl(x)                                   # cooldown -> half-open probe
        # the probe itself failed (fault still armed) -> re-open
        assert policy.breaker_state(_key()) == "open"
    assert policy.breaker(_key()).transitions == \
        ["open", "half_open", "open"]
    pl(x)                                       # cooldown again
    assert policy.breaker_state(_key()) == "closed"
    assert P.get_plan((64, 64), backend="pallas").backend == "pallas"


def test_guard_violation_falls_back_with_correct_result():
    # full guards: Parseval is what catches the finite corruptions
    # (scale/drop); threshold high enough to keep the circuit closed
    rconfig.configure(failure_threshold=10, guard_level="full")
    pl = P.get_plan((64, 64), backend="pallas")
    x = _x()
    ref = P.get_plan((64, 64), backend="jnp")._execute(x)
    for kind in ("nan", "inf", "corrupt", "drop"):
        with faults.inject("plan.output", kind):
            y = pl(x)
        # recovered result is the jnp schedule's: matches fault-free ref
        np.testing.assert_allclose(np.asarray(y.re), np.asarray(ref.re))
        np.testing.assert_allclose(np.asarray(y.im), np.asarray(ref.im))
    assert executor.stats(_key())["fallbacks"] == 4


def test_traced_execution_bypasses_guards():
    """jit'd bodies must never consult fault sites or pay for guards —
    the site would be baked into the trace cache."""
    pl = P.get_plan((64, 64), backend="pallas")
    fn = jax.jit(lambda q: pl(q))
    x = _x()
    with FaultPlan().add("plan.execute", "error", times=None) as fp:
        y = fn(x)
    assert fp.fired() == 0
    assert bool(jnp.isfinite(y.re).all())


def test_disabled_resilience_is_passthrough():
    rconfig.configure(enabled=False)
    pl = P.get_plan((64, 64), backend="pallas")
    with FaultPlan().add("plan.execute", "error", times=None) as fp:
        y = pl(_x())
    assert fp.fired() == 0 and bool(jnp.isfinite(y.re).all())
    assert executor.stats(_key()) == {"attempts": 0, "failures": 0,
                                      "fallbacks": 0, "short_circuits": 0,
                                      "last_reason": None}


def test_bf16_full_guards_dtype_aware_tolerances():
    """A *healthy* bf16 kernel execution under full guards must never walk
    the circuit breaker into runtime_circuit_open: both the Parseval and
    the Hermitian tolerance are picked by plan dtype (the lowp knobs), so
    bf16 quantisation noise is not misread as corruption — while an
    *injected* fault still trips the same guard stack."""
    rng = np.random.default_rng(3)
    xr = jnp.asarray(rng.standard_normal((64, 64)), jnp.bfloat16)
    rpl = P.get_plan((64, 64), kind="rfft", backend="pallas",
                     dtype=jnp.bfloat16)
    y = rpl._execute(xr)
    assert guards.check_output(rpl, xr, y, level="full").ok
    # the tolerance *selection* is dtype-aware: shrinking the lowp knob to
    # zero trips the very same healthy output, shrinking the fp32 knob
    # (what a bf16 plan must NOT consult) changes nothing
    with rconfig.overrides(hermitian_tol_lowp=0.0, parseval_tol_lowp=0.0):
        assert not guards.check_output(rpl, xr, y, level="full").ok
    with rconfig.overrides(hermitian_tol=0.0, parseval_tol=0.0):
        assert guards.check_output(rpl, xr, y, level="full").ok
    # lifecycle: healthy bf16 GEMM executions at threshold=1 keep the
    # breaker closed and the registry entry un-demoted
    rconfig.configure(failure_threshold=1, guard_level="full")
    xc = SplitComplex(jnp.asarray(rng.standard_normal((64, 64)), jnp.bfloat16),
                      jnp.asarray(rng.standard_normal((64, 64)), jnp.bfloat16))
    cpl = P.get_plan((64, 64), backend="pallas", dtype=jnp.bfloat16)
    assert cpl.variant == "compensated"          # the auto bf16 GEMM path
    key = P._plan_key((64, 64), jnp.bfloat16, False, "pallas", "c2c")
    for _ in range(3):
        cpl(xc)
    assert policy.breaker_state(key) in (None, "closed")   # never opened
    assert executor.stats(key)["failures"] == 0
    healthy = P.get_plan((64, 64), backend="pallas", dtype=jnp.bfloat16)
    assert healthy.backend == "pallas" and healthy.demote_reason is None
    # ...and the relaxed lowp tolerances still catch real corruption: one
    # injected output fault fails the guards and opens the circuit
    with faults.inject("plan.output", "corrupt"):
        cpl(xc)
    assert policy.breaker_state(key) == "open"
    assert P.get_plan((64, 64), backend="pallas",
                      dtype=jnp.bfloat16).demote_reason \
        == RUNTIME_DEMOTE_REASON


# ---------------------------------------------------------------------------
# Autotune watchdog
# ---------------------------------------------------------------------------

def test_autotune_watchdog_excludes_hung_candidate():
    rconfig.configure(measure_timeout_s=0.6)
    with faults.inject("autotune.measure", "hang", duration=2.0,
                       tag="four_step", times=None):
        pl = P.get_plan((64,), backend="jnp", tune=True)
    assert pl.tuned
    assert "four_step" in pl.tune_report["timeouts"]
    assert pl.tune_report["four_step"] == "timeout"
    assert pl.tune_report["winner"] != "four_step"
    assert pl.algo != "four_step"              # a hanger can never win
    # the non-hanging candidates were still measured normally
    measured = [v for k, v in pl.tune_report.items()
                if isinstance(v, float)]
    assert measured and all(v > 0 for v in measured)


def test_autotune_all_candidates_hang_keeps_default():
    rconfig.configure(measure_timeout_s=0.4)
    with faults.inject("autotune.measure", "hang", duration=2.0,
                       times=None):
        pl = P.get_plan((32,), backend="jnp", tune=True)
    assert pl.tuned and pl.tune_report["winner"] == "default/untimed"
    # the heuristic default config survived untouched
    assert pl.block_batch == 8


def test_watchdog_propagates_worker_exceptions():
    with pytest.raises(ZeroDivisionError):
        P._watchdog_call(lambda: 1 / 0, timeout_s=5.0)
    assert P._watchdog_call(lambda: 42, timeout_s=5.0) == 42
    with pytest.raises(P.CandidateTimeout):
        P._watchdog_call(lambda: __import__("time").sleep(2), timeout_s=0.1)


# ---------------------------------------------------------------------------
# Serving: degraded pre-warm + per-request deadlines
# ---------------------------------------------------------------------------

def _fourier_cfg():
    import repro.configs as C
    return C.get_config("fnet_demo").reduced()


def _engine(clock=None, scfg=None):
    from repro.models import model as M
    from repro.serve.engine import Engine, ServeConfig
    cfg = _fourier_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return Engine(cfg, scfg or ServeConfig(batch_size=2, max_len=64),
                  params, clock=clock)


def test_engine_degrades_instead_of_crashing_on_prewarm_failure():
    with faults.inject("serve.prewarm", "error"):
        eng = _engine()
    assert eng.degraded
    assert "FaultInjected" in eng.degrade_reason
    out = eng.run([(0, np.asarray([5, 6, 7], np.int32))], max_new=2)
    assert list(out) == [0] and len(out[0]) == 3   # still serves


def test_engine_not_degraded_normally():
    eng = _engine()
    assert not eng.degraded and eng.degrade_reason is None


def test_engine_honours_per_request_deadlines():
    t = {"v": 0.0}
    eng = _engine(clock=lambda: t["v"])
    prompt = np.asarray([5, 6, 7], np.int32)
    assert eng.add_request(0, prompt, deadline_s=2.5)   # expires at t=2.5
    assert eng.add_request(1, prompt)                   # no deadline
    for _ in range(6):
        t["v"] += 1.0
        eng.step(max_new=6)
    assert eng.timed_out == {0}
    assert len(eng.finished[0]) < 1 + 6       # cut short, partial kept
    assert len(eng.finished[1]) == 1 + 6      # undeadlined ran to max_new


# ---------------------------------------------------------------------------
# Wisdom: atomic save + crash simulation + observable autoload failure
# ---------------------------------------------------------------------------

def test_save_wisdom_is_atomic_under_crash(tmp_path):
    path = str(tmp_path / "wisdom.json")
    P.get_plan((256,), tune=True)
    assert P.save_wisdom(path) == 1
    good = open(path).read()
    json.loads(good)                           # valid on disk

    # crash mid-write over the existing file: the fault fires after half
    # the payload is written to the temp file
    with faults.inject("wisdom.save", "error"):
        with pytest.raises(FaultInjected):
            P.save_wisdom(path)
    assert open(path).read() == good           # target untouched, not torn
    P.clear_plan_cache()
    assert P.load_wisdom(path) == 1

    # crash on first-ever save: no destination file appears at all
    fresh = str(tmp_path / "fresh.json")
    with faults.inject("wisdom.save", "error"):
        with pytest.raises(FaultInjected):
            P.save_wisdom(fresh)
    assert not os.path.exists(fresh)


def _import_plan_with_wisdom(path):
    code = "import repro.core.plan as P; print('LOADED', P.WISDOM_AUTOLOADED)"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_FFT_WISDOM"] = path
    env["PYTHONWARNINGS"] = "always"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr   # import must never break
    return proc


def test_autoload_warns_on_corrupt_wisdom_file(tmp_path):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as fh:
        fh.write("{this is not json")
    proc = _import_plan_with_wisdom(path)
    assert "LOADED 0" in proc.stdout
    assert "REPRO_FFT_WISDOM" in proc.stderr
    assert "JSONDecodeError" in proc.stderr
    assert path in proc.stderr                 # names the offending file


def test_autoload_warns_on_version_mismatch(tmp_path):
    path = str(tmp_path / "old.json")
    json.dump({"version": 999, "entries": []}, open(path, "w"))
    proc = _import_plan_with_wisdom(path)
    assert "LOADED 0" in proc.stdout
    assert "version" in proc.stderr and "999" in proc.stderr


def test_autoload_silent_on_legitimate_empty_wisdom(tmp_path):
    path = str(tmp_path / "empty.json")
    json.dump({"version": P.WISDOM_VERSION, "entries": []}, open(path, "w"))
    proc = _import_plan_with_wisdom(path)
    assert "LOADED 0" in proc.stdout
    assert "REPRO_FFT_WISDOM" not in proc.stderr
