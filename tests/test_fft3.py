"""fft3 registry routing (the plumbing bugfix), the fused 3-D kernel, and
seeded property sweeps for the 3-D paths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft3, from_complex, to_complex
from repro.core import plan as P
from repro.core.complexmath import SplitComplex
from repro.kernels import ops


@pytest.fixture(autouse=True)
def _fresh_registry():
    P.clear_plan_cache()
    yield
    P.clear_plan_cache()


def _rand3d(shape, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape)
            + 1j * rng.standard_normal(shape)).astype(np.complex64)


def _rel(got, ref):
    return np.abs(got - ref).max() / np.abs(ref).max()


# ---------------------------------------------------------------------------
# The plumbing bugfix: fft3 takes backend= and routes through the registry
# ---------------------------------------------------------------------------

def test_fft3_backend_routes_through_registry():
    """fft3(backend="pallas") must intern a (d, h, w) pallas key that
    resolves to the fused kernel — previously fft3 took no backend and
    bypassed the registry entirely."""
    z = _rand3d((8, 16, 32), seed=1)
    x = from_complex(jnp.asarray(z))
    ref = np.fft.fftn(z, axes=(-3, -2, -1))
    got = np.asarray(to_complex(fft3(x, backend="pallas")))
    assert _rel(got, ref) < 1e-5
    key = P._plan_key((8, 16, 32), jnp.float32, False, "pallas", "c2c")
    plan = P._PLAN_CACHE[key]
    assert plan.backend == "pallas" and plan.algo == "fused"
    assert plan.demote_reason is None
    # the jnp request interns its own key, same numbers
    got_j = np.asarray(to_complex(fft3(x, backend="jnp")))
    assert _rel(got_j, ref) < 1e-5
    assert P._plan_key((8, 16, 32), jnp.float32, False, "jnp", "c2c") \
        in P._PLAN_CACHE


def test_fft3_nonpow2_demotes_with_reason():
    z = _rand3d((6, 16, 32), seed=2)
    x = from_complex(jnp.asarray(z))
    got = np.asarray(to_complex(fft3(x, backend="pallas")))
    assert _rel(got, np.fft.fftn(z, axes=(-3, -2, -1))) < 1e-4
    plan = P.get_plan((6, 16, 32), backend="pallas")
    assert plan.backend == "jnp" and plan.algo == "row_col"
    assert "power-of-two" in plan.demote_reason


def test_fft3_rejects_2d_input():
    x = from_complex(jnp.asarray(_rand3d((8, 8))[None][0]))
    with pytest.raises(ValueError, match="at least 3 axes"):
        fft3(x)


def test_fft3_explicit_algos_agree():
    z = _rand3d((8, 16, 16), seed=3)
    x = from_complex(jnp.asarray(z))
    ref = np.fft.fftn(z, axes=(-3, -2, -1))
    for algo, backend in (("fused", "pallas"), ("row_col", "pallas"),
                          ("row_col", "jnp")):
        got = np.asarray(to_complex(fft3(x, algo=algo, backend=backend)))
        assert _rel(got, ref) < 1e-4, (algo, backend)
    with pytest.raises(ValueError, match="pallas"):
        fft3(x, algo="fused", backend="jnp")


# ---------------------------------------------------------------------------
# The fused 3-D kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dhw", [(2, 4, 8), (8, 8, 8), (4, 16, 32),
                                 (32, 16, 4), (16, 16, 16), (32, 32, 32)])
def test_fused3d_kernel_matches_numpy(dhw):
    z = _rand3d(dhw, seed=sum(dhw))
    got = np.asarray(to_complex(ops.fft3d_fused(from_complex(jnp.asarray(z)))))
    assert _rel(got, np.fft.fftn(z, axes=(-3, -2, -1))) < 1e-5


def test_fused3d_leading_batch_and_padding():
    z = _rand3d((2, 3, 4, 8, 16), seed=5)
    got = np.asarray(to_complex(ops.fft3d_fused(from_complex(jnp.asarray(z)))))
    assert _rel(got, np.fft.fftn(z, axes=(-3, -2, -1))) < 1e-5
    z = _rand3d((3, 8, 8, 8), seed=6)           # ragged batch, bb=2 pads
    got = np.asarray(to_complex(
        ops.fft3d_fused(from_complex(jnp.asarray(z)), block_batch=2)))
    assert _rel(got, np.fft.fftn(z, axes=(-3, -2, -1))) < 1e-5


def test_fused3d_empty_batch():
    x = from_complex(jnp.zeros((0, 4, 4, 4), jnp.complex64))
    assert ops.fft3d_fused(x).shape == (0, 4, 4, 4)


def test_fused3d_bf16_compensated_error_bound():
    """3-D acceptance bound: compensated bf16 within 5e-3 of fp64 and
    tighter than the plain cast."""
    rng = np.random.default_rng(7)
    shape = (32, 32, 32)
    zr, zi = rng.standard_normal(shape), rng.standard_normal(shape)
    ref = np.fft.fftn(zr + 1j * zi)
    x = SplitComplex(jnp.asarray(zr[None], jnp.bfloat16),
                     jnp.asarray(zi[None], jnp.bfloat16))
    errs = {}
    for variant in ("plain", "compensated"):
        out = ops.fft3d_fused(x, variant=variant)
        got = (np.asarray(out.re, np.float64)
               + 1j * np.asarray(out.im, np.float64))[0]
        errs[variant] = np.linalg.norm(got - ref) / np.linalg.norm(ref)
    assert errs["compensated"] <= 5e-3, errs
    assert errs["compensated"] < errs["plain"], errs


# ---------------------------------------------------------------------------
# Seeded property sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fft3_property_sweep(backend):
    """Seeded sweep across pow2 shapes (kernel path), non-pow2 shapes
    (demote path) and ragged batches: forward matches the fp64 numpy
    reference and forward∘inverse returns the input."""
    rng = np.random.default_rng(11)
    cases = [((), (4, 8, 16)), ((3,), (8, 8, 8)), ((2, 2), (2, 4, 4)),
             ((), (6, 8, 8)), ((5,), (4, 12, 10))]     # last two demote
    for lead, dhw in cases:
        shape = lead + dhw
        zr = rng.standard_normal(shape)
        zi = rng.standard_normal(shape)
        ref = np.fft.fftn(zr + 1j * zi, axes=(-3, -2, -1))
        x = SplitComplex(jnp.asarray(zr, jnp.float32),
                         jnp.asarray(zi, jnp.float32))
        y = fft3(x, backend=backend)
        got = np.asarray(y.re, np.float64) + 1j * np.asarray(y.im, np.float64)
        assert np.linalg.norm(got - ref) / np.linalg.norm(ref) < 1e-5, \
            (backend, shape)
        back = fft3(y, inverse=True, backend=backend)
        gotb = (np.asarray(back.re, np.float64)
                + 1j * np.asarray(back.im, np.float64))
        assert np.linalg.norm(gotb - (zr + 1j * zi)) \
            / np.linalg.norm(zr + 1j * zi) < 1e-5, (backend, shape)


def test_fft3_inverse_plan_interned_separately():
    z = _rand3d((4, 8, 8), seed=9)
    x = from_complex(jnp.asarray(z))
    fft3(x, backend="pallas")
    fft3(x, inverse=True, backend="pallas")
    fwd = P._plan_key((4, 8, 8), jnp.float32, False, "pallas", "c2c")
    inv = P._plan_key((4, 8, 8), jnp.float32, True, "pallas", "c2c")
    assert fwd in P._PLAN_CACHE and inv in P._PLAN_CACHE
    assert P._PLAN_CACHE[fwd] is not P._PLAN_CACHE[inv]


def test_rfft_3d_plan_rejected():
    with pytest.raises(ValueError, match="rfft plans are 1-D or 2-D"):
        P.get_plan((4, 8, 8), kind="rfft")
