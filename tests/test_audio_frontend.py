"""The STFT audio frontend example (PR 10 satellite): previously
example-only untested code — now its frame rfft routes through the plan
registry with ``backend=`` and its first frame is pinned against numpy."""
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "examples"))
from audio_frontend import stft  # noqa: E402

from repro.core import clear_plan_cache, get_plan  # noqa: E402


def _wave(n=4000, sr=16_000):
    rng = np.random.default_rng(0)
    t = np.arange(n, dtype=np.float32) / sr
    return (np.sin(2 * np.pi * 440 * t)
            + 0.1 * rng.standard_normal(n).astype(np.float32))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stft_first_frame_matches_numpy(backend):
    """The satellite pin: first-frame magnitudes vs numpy <= 1e-6
    (relative), on BOTH backends — the backend routes through the
    registry, it must not change the numbers."""
    wave = _wave()
    mag = np.asarray(stft(jnp.asarray(wave), backend=backend))
    ref = np.abs(np.fft.rfft(wave[:512].astype(np.float64)
                             * np.hanning(512)))
    assert mag.shape == (1 + (4000 - 512) // 160, 257)
    err = np.abs(mag[0] - ref).max() / ref.max()
    assert err <= 1e-6, (backend, err)


def test_stft_pallas_request_goes_through_registry():
    """backend="pallas" interns the (512,) rfft key via the registry —
    demoted or not, the request is visible, never a crash."""
    clear_plan_cache()
    stft(jnp.asarray(_wave(1024)), backend="pallas")
    p = get_plan((512,), kind="rfft", backend="pallas")
    assert p.algo                       # resolved (kernel path or demoted)
    if p.backend == "jnp":
        assert p.demote_reason          # demotions carry their reason
    clear_plan_cache()


def test_stft_batched_leading_dims():
    wave = np.stack([_wave(), 2.0 * _wave()])
    mag = np.asarray(stft(jnp.asarray(wave)))
    assert mag.shape == (2, 1 + (4000 - 512) // 160, 257)
    np.testing.assert_allclose(mag[1], 2.0 * mag[0], rtol=1e-5)
