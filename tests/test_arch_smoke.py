"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward + one train step on CPU, asserting
output shapes and the absence of NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import model as M

S = 32
B = 2


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    if cfg.input_mode == "embeddings":
        return {"embeds": jax.random.normal(k1, (B, S, cfg.d_model),
                                            jnp.dtype(cfg.dtype)),
                "labels": labels}
    return {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
            "labels": labels}


@pytest.mark.parametrize("arch", C.ASSIGNED)
def test_forward_and_train_step(arch):
    cfg = C.get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = M.forward(params, cfg, tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves), \
        f"{arch}: NaN/inf grads"
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = M.loss_fn(params2, cfg, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", [a for a in C.ASSIGNED
                                  if a not in C.ENCODER_ONLY])
def test_decode_matches_forward(arch):
    cfg = C.get_config(arch).reduced(capacity_factor=8.0)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0,
                              cfg.vocab_size)
    cache = M.init_cache(cfg, B, 32)
    outs = []
    for t in range(8):
        lg, cache = M.decode_step(params, cfg, toks[:, t], cache,
                                  jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    ref, _ = M.forward(params, cfg, tokens=toks)
    assert float(jnp.abs(dec - ref).max()) < 5e-3, arch


def test_vocab_padding_masked():
    cfg = C.get_config("hubert-xlarge").reduced()
    assert cfg.padded_vocab % cfg.vocab_pad_multiple == 0
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, _ = M.forward(params, cfg, embeds=batch["embeds"])
    pad = logits[..., cfg.vocab_size:]
    assert bool((pad < -1e20).all()), "padded vocab logits must be masked"
