"""GEMM-formulated fused 2-D FFT kernel: correctness vs numpy, agreement
with the Stockham oracle, the precision-compensated bf16 variant's error
bounds, and the variant plumbing through the plan registry."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft2, from_complex, to_complex
from repro.core import plan as P
from repro.core.complexmath import SplitComplex
from repro.kernels import ops
from repro.kernels.fft2d_gemm import gemm_tables, split_table_np


@pytest.fixture(autouse=True)
def _fresh_registry():
    P.clear_plan_cache()
    yield
    P.clear_plan_cache()


def _rand2d(shape, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape)
            + 1j * rng.standard_normal(shape)).astype(np.complex64)


def _rel(got, ref):
    return np.abs(got - ref).max() / np.abs(ref).max()


@pytest.mark.parametrize("hw", [(8, 4), (8, 8), (32, 8), (8, 32), (64, 64),
                                (64, 128), (128, 64), (256, 256), (512, 512)])
def test_gemm_kernel_matches_numpy(hw):
    z = _rand2d(hw, seed=sum(hw))
    got = np.asarray(to_complex(ops.fft2d_gemm(from_complex(jnp.asarray(z)))))
    ref = np.fft.fft2(z)
    assert _rel(got, ref) < 1e-5


def test_gemm_kernel_leading_batch_and_padding():
    """Leading batch dims flatten, and batch=3 with block_batch=2 exercises
    the pad/unpad path."""
    z = _rand2d((2, 3, 16, 32), seed=7)
    got = np.asarray(to_complex(ops.fft2d_gemm(from_complex(jnp.asarray(z)))))
    assert _rel(got, np.fft.fft2(z)) < 1e-5
    z = _rand2d((3, 32, 32), seed=9)
    got = np.asarray(to_complex(
        ops.fft2d_gemm(from_complex(jnp.asarray(z)), block_batch=2)))
    assert _rel(got, np.fft.fft2(z)) < 1e-5


def test_gemm_empty_batch():
    x = from_complex(jnp.zeros((0, 16, 16), jnp.complex64))
    out = ops.fft2d_gemm(x)
    assert out.shape == (0, 16, 16)


def test_gemm_inverse_roundtrip():
    z = _rand2d((2, 64, 64), seed=3)
    x = from_complex(jnp.asarray(z))
    back = ops.fft2d_gemm(ops.fft2d_gemm(x), inverse=True)
    assert np.abs(np.asarray(to_complex(back)) - z).max() < 1e-4


def test_gemm_matches_stockham_oracle():
    """The GEMM kernel and the demoted Stockham-stage kernel are the same
    transform: bit-different, value-identical to fp32 noise."""
    z = _rand2d((2, 128, 64), seed=5)
    x = from_complex(jnp.asarray(z))
    gemm = np.asarray(to_complex(ops.fft2d_gemm(x)))
    stock = np.asarray(to_complex(ops.fft2d_fused(x)))
    assert _rel(gemm, stock) < 1e-4


def test_fft2_algo_names_route_to_each_kernel():
    """algo="fused" is now the GEMM kernel, "fused_stockham" the oracle —
    and both agree with numpy through the direct fft2 path."""
    z = _rand2d((64, 64), seed=4)
    x = from_complex(jnp.asarray(z))
    ref = np.fft.fft2(z)
    for algo in ("fused", "fused_stockham", "row_col"):
        got = np.asarray(to_complex(fft2(x, backend="pallas", algo=algo)))
        assert _rel(got, ref) < 1e-4, algo
    with pytest.raises(ValueError, match="pallas"):
        fft2(x, backend="jnp", algo="fused_stockham")


def test_split_table_reconstruction_accuracy():
    """The split hi/lo pair recovers the float64 table to ~bf16-eps^2: two
    orders of magnitude tighter than the straight bf16 cast."""
    rng = np.random.default_rng(0)
    t = rng.uniform(-1.0, 1.0, size=(64, 64))
    pair = np.asarray(split_table_np(t, jnp.bfloat16), np.float64)
    recon = pair[0] + pair[1]
    plain = np.asarray(jnp.asarray(t, jnp.bfloat16), np.float64)
    assert np.abs(recon - t).max() < 1e-4
    assert np.abs(recon - t).max() < 0.01 * np.abs(plain - t).max()


def test_gemm_tables_operand_count_and_shapes():
    plain = gemm_tables(64, 128, False, jnp.float32, "plain")
    comp = gemm_tables(64, 128, False, jnp.bfloat16, "compensated")
    assert len(plain) == len(comp) == 12
    for p, c in zip(plain, comp):
        assert c.shape == (2,) + p.shape       # stacked (hi, lo)
        assert c.dtype == jnp.bfloat16


@pytest.mark.parametrize("hw", [(256, 256), (512, 512)])
def test_bf16_compensated_error_bound(hw):
    """The acceptance bound: compensated bf16 stays within 5e-3 relative of
    the fp64 reference, and beats the plain bf16 cast — the split-twiddle
    correction is what buys the margin at these sizes."""
    rng = np.random.default_rng(sum(hw))
    zr = rng.standard_normal(hw)
    zi = rng.standard_normal(hw)
    ref = np.fft.fft2(zr + 1j * zi)            # float64 reference
    x = SplitComplex(jnp.asarray(zr[None], jnp.bfloat16),
                     jnp.asarray(zi[None], jnp.bfloat16))
    errs = {}
    for variant in ("plain", "compensated"):
        out = ops.fft2d_gemm(x, variant=variant)
        got = (np.asarray(out.re, np.float64)
               + 1j * np.asarray(out.im, np.float64))[0]
        errs[variant] = np.linalg.norm(got - ref) / np.linalg.norm(ref)
    assert errs["compensated"] <= 5e-3, errs
    assert errs["compensated"] < errs["plain"], errs


def test_bf16_compensated_roundtrip():
    z = _rand2d((2, 128, 128), seed=6)
    x = SplitComplex(jnp.asarray(z.real, jnp.bfloat16),
                     jnp.asarray(z.imag, jnp.bfloat16))
    back = ops.fft2d_gemm(ops.fft2d_gemm(x, variant="compensated"),
                          inverse=True, variant="compensated")
    got = (np.asarray(back.re, np.float64)
           + 1j * np.asarray(back.im, np.float64))
    assert np.linalg.norm(got - z) / np.linalg.norm(z) < 1e-2
    assert back.re.dtype == jnp.bfloat16


def test_registry_variant_resolution_and_execution():
    """auto-variant: fp32 GEMM plans stay plain, bf16 ones resolve to
    compensated — and the compensated plan executes to the 5e-3 bound."""
    f32 = P.get_plan((128, 128), backend="pallas")
    assert (f32.algo, f32.variant) == ("fused", "plain")
    bf16 = P.get_plan((128, 128), backend="pallas", dtype=jnp.bfloat16)
    assert (bf16.algo, bf16.variant) == ("fused", "compensated")
    # explicit variants intern separately and never displace the auto plan
    explicit = P.get_plan((128, 128), backend="pallas", dtype=jnp.bfloat16,
                          variant="plain")
    assert explicit.variant == "plain"
    assert P.get_plan((128, 128), backend="pallas",
                      dtype=jnp.bfloat16) is bf16
    rng = np.random.default_rng(1)
    zr, zi = rng.standard_normal((128, 128)), rng.standard_normal((128, 128))
    x = SplitComplex(jnp.asarray(zr, jnp.bfloat16),
                     jnp.asarray(zi, jnp.bfloat16))
    y = bf16(x)
    got = (np.asarray(y.re, np.float64) + 1j * np.asarray(y.im, np.float64))
    ref = np.fft.fft2(zr + 1j * zi)
    assert np.linalg.norm(got - ref) / np.linalg.norm(ref) <= 5e-3


def test_autotune_grid_includes_variant_and_oracle():
    """The bf16 2-D pallas candidate grid measures both precision variants
    plus the Stockham oracle and the row-column baseline."""
    plan = P.FFTPlan(shape=(32, 32), dtype="bfloat16", algo="fused",
                     backend="pallas", block_batch=1, variant="compensated")
    labels = [lbl for lbl, _ in P._candidates(plan)]
    assert "fused/plain/bb1" in labels
    assert "fused_stockham/bb1" in labels
    assert "row_col" in labels
    cfgs = {(c.algo, c.block_batch, c.variant)
            for _, c in P._candidates(plan)}
    assert ("fused", 1, "plain") in cfgs
    # fixed_variant (an explicit variant= request) drops the other variant
    fixed = [lbl for lbl, _ in P._candidates(plan, fixed_variant=True)]
    assert "fused/plain/bb1" not in fixed
    # 3-D grids have no Stockham oracle
    plan3 = dataclasses.replace(plan, shape=(16, 16, 16))
    labels3 = [lbl for lbl, _ in P._candidates(plan3)]
    assert "fused_stockham/bb1" not in labels3
    # the plan's own config (fused/bb1) is the "default" candidate
    assert "default" in labels3 and "row_col" in labels3
    assert "fused/bb2" in labels3
