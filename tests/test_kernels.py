"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
ref.py jnp.fft oracle (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.complexmath import SplitComplex, from_complex, to_complex
from repro.kernels import ops, ref
from repro.kernels.fft_stockham import packed_twiddles_np


def _rand(batch, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    z = rng.standard_normal((batch, n)) + 1j * rng.standard_normal((batch, n))
    zc = z.astype(np.complex64)
    return SplitComplex(jnp.asarray(zc.real, dtype), jnp.asarray(zc.imag, dtype))


TOL = {jnp.float32: 3e-4, jnp.bfloat16: 6e-2}


@pytest.mark.parametrize("n", [2, 8, 64, 512, 4096, 16384])
@pytest.mark.parametrize("batch", [1, 5, 8])
def test_stockham_kernel_shapes(n, batch):
    x = _rand(batch, n, jnp.float32)
    got = ops.fft_stockham(x)
    want = ref.fft_ref(x)
    r = np.asarray(to_complex(want))
    scale = max(np.abs(r).max(), 1.0)
    np.testing.assert_allclose(np.asarray(to_complex(got)), r,
                               atol=3e-4 * scale)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stockham_kernel_dtypes(dtype):
    x = _rand(4, 256, dtype)
    got = ops.fft_stockham(x)
    x32 = SplitComplex(x.re.astype(jnp.float32), x.im.astype(jnp.float32))
    r = np.asarray(to_complex(ref.fft_ref(x32)))
    scale = max(np.abs(r).max(), 1.0)
    np.testing.assert_allclose(
        np.asarray(to_complex(got)).astype(np.complex64), r,
        atol=TOL[dtype] * scale)


@pytest.mark.parametrize("n", [64, 1024, 4096, 16384])
def test_fourstep_kernel(n):
    x = _rand(4, n, jnp.float32, seed=n)
    got = ops.fft_fourstep(x)
    r = np.asarray(to_complex(ref.fft_ref(x)))
    scale = max(np.abs(r).max(), 1.0)
    np.testing.assert_allclose(np.asarray(to_complex(got)), r,
                               atol=5e-4 * scale)


@pytest.mark.parametrize("n", [16, 256, 2048])
def test_staged_kernel_paper_baseline(n):
    x = _rand(4, n, jnp.float32, seed=n)
    got = ops.fft_staged(x)
    r = np.asarray(to_complex(ref.fft_ref(x)))
    scale = max(np.abs(r).max(), 1.0)
    np.testing.assert_allclose(np.asarray(to_complex(got)), r,
                               atol=3e-4 * scale)


@pytest.mark.parametrize("n", [256, 4096])
def test_inverse_kernels(n):
    x = _rand(4, n, jnp.float32, seed=n + 1)
    fwd = ref.fft_ref(x)
    for fn in (ops.fft_stockham, ops.fft_fourstep):
        back = fn(fwd, inverse=True)
        np.testing.assert_allclose(np.asarray(to_complex(back)),
                                   np.asarray(to_complex(x)), atol=2e-3)


def test_batch_padding_path():
    """Non-multiple batch exercises the pad/unpad logic in ops."""
    x = _rand(3, 128, jnp.float32)
    got = ops.fft_stockham(x, block_batch=8)
    r = np.asarray(to_complex(ref.fft_ref(x)))
    np.testing.assert_allclose(np.asarray(to_complex(got)), r,
                               atol=3e-4 * max(np.abs(r).max(), 1.0))


@pytest.mark.parametrize("batch,bb", [
    (1, 8),    # batch < block_batch: tile shrinks to the batch
    (5, 8),    # batch < block_batch, not a divisor of it
    (10, 4),   # batch > block_batch but not a multiple: pad 10 -> 12
    (9, 8),    # one full tile plus a ragged remainder
])
def test_batch_padding_edges(batch, bb):
    """Pad/unpad against the jnp reference for every ragged-batch shape."""
    x = _rand(batch, 64, jnp.float32, seed=batch * 31 + bb)
    r = np.asarray(to_complex(ref.fft_ref(x)))
    tol = 3e-4 * max(np.abs(r).max(), 1.0)
    for fn in (ops.fft_stockham, ops.fft_fourstep):
        got = np.asarray(to_complex(fn(x, block_batch=bb)))
        assert got.shape == r.shape
        np.testing.assert_allclose(got, r, atol=tol)


def test_leading_dims_flatten():
    rng = np.random.default_rng(0)
    z = (rng.standard_normal((2, 3, 64)) + 1j * rng.standard_normal((2, 3, 64))
         ).astype(np.complex64)
    x = from_complex(jnp.asarray(z))
    got = np.asarray(to_complex(ops.fft_stockham(x)))
    ref_v = np.fft.fft(z)
    np.testing.assert_allclose(got, ref_v, atol=3e-4 * np.abs(ref_v).max())


def test_packed_twiddles_consistency():
    wr, wi = packed_twiddles_np(64, False)
    assert wr.shape == (6, 32)
    # stage 0: stride 1, w_p = exp(-2pi i p/64)
    p = np.arange(32)
    np.testing.assert_allclose(wr[0], np.cos(-2 * np.pi * p / 64), atol=1e-12)
    # last stage: all ones (n_cur=2)
    np.testing.assert_allclose(wr[-1], np.ones(32), atol=1e-12)
    np.testing.assert_allclose(wi[-1], np.zeros(32), atol=1e-12)
