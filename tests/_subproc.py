"""Run a snippet under a fake multi-device JAX runtime (subprocess so the
parent's 1-device jax is untouched)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-3000:]}"
    return proc.stdout
