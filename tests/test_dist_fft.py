"""Distributed pencil FFT == single-device FFT (8 fake devices, subprocess)."""
from _subproc import run_with_devices

# Mesh construction goes through repro.launch.mesh.make_mesh and shard_map
# through repro.dist._compat — never raw jax.make_mesh(axis_types=...) /
# jax.shard_map, which only exist on some jax versions.
CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.complexmath import from_complex, to_complex, SplitComplex
from repro.core import fft2d
from repro.dist import pencil
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(0)
mesh = make_mesh((8,), ("data",))
H = W = 128
x = (rng.standard_normal((H, W)) + 1j*rng.standard_normal((H, W))).astype(np.complex64)
sh = NamedSharding(mesh, P("data", None))
xs = from_complex(jnp.asarray(x))
xs = SplitComplex(jax.device_put(xs.re, sh), jax.device_put(xs.im, sh))
ref = np.fft.fft2(x)

for chunks in (1, 4):
    got = np.asarray(to_complex(pencil.pfft2(xs, mesh, "data", chunks=chunks))).T
    assert np.abs(got - ref).max()/np.abs(ref).max() < 1e-4, chunks
got = np.asarray(to_complex(pencil.pfft2(xs, mesh, "data", transposed_output=False)))
assert np.abs(got - ref).max()/np.abs(ref).max() < 1e-4
back = pencil.pfft2(pencil.pfft2(xs, mesh, "data", transposed_output=False),
                    mesh, "data", inverse=True, transposed_output=False)
assert np.abs(np.asarray(to_complex(back)) - x).max() < 1e-3

# distributed path is pinned to the single-chip plan-registry path too, not
# just to numpy: pfft2 == core.fft2 on the same split-complex input
loc = np.asarray(to_complex(fft2d.fft2(from_complex(jnp.asarray(x)))))
assert np.abs(got - loc).max()/np.abs(loc).max() < 1e-5

# hierarchical two-hop (2 pods x 4)
mesh2 = make_mesh((2, 4), ("pod", "data"))
shp = NamedSharding(mesh2, P(("pod", "data"), None))
xs2 = SplitComplex(jax.device_put(jnp.real(jnp.asarray(x)), shp),
                   jax.device_put(jnp.imag(jnp.asarray(x)), shp))
got = np.asarray(to_complex(pencil.pfft2_hierarchical(xs2, mesh2))).T
assert np.abs(got - ref).max()/np.abs(ref).max() < 1e-4

# 3-D pencil FFT over a 2-D process grid (the paper's future-work case)
mesh3 = make_mesh((2, 4), ("data", "model"))
X = Y = 16; Z = 32
x3 = (rng.standard_normal((X, Y, Z)) + 1j*rng.standard_normal((X, Y, Z))).astype(np.complex64)
sh3 = NamedSharding(mesh3, P("data", "model", None))
z3 = from_complex(jnp.asarray(x3))
z3 = SplitComplex(jax.device_put(z3.re, sh3), jax.device_put(z3.im, sh3))
out3 = pencil.pfft3(z3, mesh3)
got3 = np.asarray(to_complex(out3)).transpose(2, 1, 0)   # (Z,Y,X) -> (X,Y,Z)
ref3 = np.fft.fftn(x3)
assert np.abs(got3 - ref3).max()/np.abs(ref3).max() < 1e-4

# backend= plumbs through pfft3's local passes to the plan registry (the
# same routing the single-chip fft3 has).  Z=32 resolves to "naive" and
# demotes (interned under the jnp key); Z=512 has a four_step kernel path
# and must intern a live pallas plan.
from repro.core import plan as plan_lib
out3p = pencil.pfft3(z3, mesh3, backend="pallas")
got3p = np.asarray(to_complex(out3p)).transpose(2, 1, 0)
assert np.abs(got3p - ref3).max()/np.abs(ref3).max() < 1e-4
pk = plan_lib._plan_key((Z,), jnp.float32, False, "jnp", "c2c")
assert pk in plan_lib._PLAN_CACHE, "pfft3 demoted Z pass missing from registry"

Zk = 512
xk = (rng.standard_normal((X, Y, Zk)) + 1j*rng.standard_normal((X, Y, Zk))).astype(np.complex64)
zk = from_complex(jnp.asarray(xk))
zk = SplitComplex(jax.device_put(zk.re, sh3), jax.device_put(zk.im, sh3))
outk = pencil.pfft3(zk, mesh3, backend="pallas")
gotk = np.asarray(to_complex(outk)).transpose(2, 1, 0)
refk = np.fft.fftn(xk)
assert np.abs(gotk - refk).max()/np.abs(refk).max() < 1e-4
pk = plan_lib._plan_key((Zk,), jnp.float32, False, "pallas", "c2c")
assert pk in plan_lib._PLAN_CACHE, "pfft3 local Z pass never hit the registry"
assert plan_lib._PLAN_CACHE[pk].backend == "pallas"

# distributed 1-D four-step, forward + inverse roundtrip
mesh = make_mesh((8,), ("data",))
n = 1 << 14
v = (rng.standard_normal(n) + 1j*rng.standard_normal(n)).astype(np.complex64)
sh1 = NamedSharding(mesh, P("data"))
vs = from_complex(jnp.asarray(v))
vs = SplitComplex(jax.device_put(vs.re, sh1), jax.device_put(vs.im, sh1))
out = pencil.pfft1d(vs, mesh, "data")
p, h, w = 8, 8, n // 8
while (w > 2*h) and (w % 2 == 0) and ((w//2) % p == 0): h, w = h*2, w//2
assert (h, w) == pencil.fourstep_split(n, p)
got = np.asarray(to_complex(out)).reshape(h, w).T.reshape(-1)
ref1 = np.fft.fft(v)
assert np.abs(got - ref1).max()/np.abs(ref1).max() < 1e-4
back = pencil.pfft1d(out, mesh, "data", inverse=True)
assert np.abs(np.asarray(to_complex(back)) - v).max() < 1e-3
print("DIST_FFT_OK")
"""


def test_pencil_fft_8dev():
    out = run_with_devices(CODE, 8)
    assert "DIST_FFT_OK" in out
