"""Checkpoint manager: atomic save/restore, latest-k GC, async overlap,
data-iterator state, elastic restore onto a different device layout."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_with_devices
from repro.train.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                       "step": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(7, tree, extra={"data_step": 8})
    got, extra = mgr.restore(7, jax.tree.map(jnp.zeros_like, tree))
    assert extra["data_step"] == 8
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_k_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save_overlaps(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save_async(5, tree, extra={"data_step": 6})
    mgr.wait()
    got, extra = mgr.restore(5, jax.tree.map(jnp.zeros_like, tree))
    assert extra["data_step"] == 6
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_no_partial_checkpoint_on_crash(tmp_path):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp0"))
    assert mgr.all_steps() == []


ELASTIC = r"""
import jax, jax.numpy as jnp, numpy as np, sys
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import CheckpointManager

d = sys.argv[1] if len(sys.argv) > 1 else None
import os
tmp = os.environ["CKPT_DIR"]
mgr = CheckpointManager(tmp, keep=2)
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("data",))
sh = NamedSharding(mesh, P("data", None))
w = jax.device_put(jnp.arange(32.0).reshape(8, 4), sh)
mgr.save(1, {"w": w})
# elastic restore onto a DIFFERENT layout (2-way on the other dim)
mesh2 = make_mesh((2, 2), ("a", "b"))
sh2 = NamedSharding(mesh2, P(None, "a"))
got, _ = mgr.restore(1, {"w": jnp.zeros((8, 4))}, shardings={"w": sh2})
np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(32.0).reshape(8, 4))
assert got["w"].sharding.spec == P(None, "a")
print("ELASTIC_OK")
"""


def test_elastic_restore_4dev(tmp_path):
    import os
    os.environ["CKPT_DIR"] = str(tmp_path)
    try:
        out = run_with_devices(ELASTIC, 4)
    finally:
        os.environ.pop("CKPT_DIR")
    assert "ELASTIC_OK" in out


def test_data_pipeline_determinism_and_sharding():
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64,
                      block_pattern=("attn_mlp",), repeat=1,
                      vocab_pad_multiple=32)
    data = SyntheticLM(DataConfig(seq_len=16, global_batch=8, seed=5), cfg)
    b1 = data.batch_at(3)
    b2 = data.batch_at(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # host sharding: two hosts see disjoint slices, deterministic each
    h0 = data.batch_at(3, host_id=0, num_hosts=2)
    h1 = data.batch_at(3, host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))
    # restart-safety: checkpoint state is just the step
    st = data.checkpoint_state(17)
    assert SyntheticLM.restore_step(st) == 17
