"""repro.tt: arch tables, Tensix pipeline, NoC model, plan traces, and the
paper's §6 Wormhole-vs-Xeon table."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import FFTPlan, _time_candidates
from repro.core.complexmath import SplitComplex
from repro.tt import arch as ttarch
from repro.tt import noc as ttnoc
from repro.tt import report as ttreport
from repro.tt import tensix as tt
from repro.tt import trace as tttrace

MIB = 1024 * 1024


# ---------------------------------------------------------------------------
# arch
# ---------------------------------------------------------------------------

def test_arch_lookup_and_aliases():
    assert ttarch.get_arch("wormhole").name == "wormhole_n300"
    assert ttarch.get_arch("n300") is ttarch.get_arch("wormhole_n300")
    assert ttarch.get_arch("xeon").kind == "cpu"
    assert ttarch.get_arch(ttarch.TPU_V5E) is ttarch.TPU_V5E
    with pytest.raises(KeyError, match="unknown arch"):
        ttarch.get_arch("a100")


def test_hw_table_matches_legacy_roofline_dict():
    """The roofline's HW dict must keep its historical v5e numbers now that
    it delegates here."""
    from repro.analysis.roofline import HW
    assert HW == ttarch.hw_table("tpu_v5e")
    assert HW["peak_flops_bf16"] == 197e12
    assert HW["hbm_bw"] == 819e9
    assert HW["ici_bw"] == 50e9
    assert HW["chip_power_w"] == 215.0


def test_register_custom_arch():
    custom = dataclasses.replace(ttarch.WORMHOLE_N300, name="wormhole_n150",
                                 cores=64, dram_bw=288e9)
    try:
        ttarch.register_arch(custom, "n150")
        assert ttarch.get_arch("n150").cores == 64
        t = tttrace.trace_plan(
            FFTPlan(shape=(256, 256), algo="fused", backend="pallas",
                    block_batch=1), arch="n150")
        # half the DRAM bandwidth of the n300 -> strictly slower prediction
        t300 = tttrace.trace_plan(
            FFTPlan(shape=(256, 256), algo="fused", backend="pallas",
                    block_batch=1), arch="wormhole_n300")
        assert t.seconds > t300.seconds
    finally:
        ttarch.ARCHS.pop("wormhole_n150", None)
        ttarch._ALIASES.pop("n150", None)


# ---------------------------------------------------------------------------
# tensix pipeline
# ---------------------------------------------------------------------------

def test_pipeline_double_buffering_timeline():
    per_tile = {"reader": 1e-6, "unpacker": 2e-6, "math": 1e-6,
                "packer": 2e-6, "writer": 1e-6}
    tl = tt.pipeline_timeline(per_tile, 100)
    # fill = one traversal, then one tile per slowest-unit interval
    assert tl.fill_s == pytest.approx(7e-6)
    assert tl.steady_tile_s == pytest.approx(2e-6)
    assert tl.total_s == pytest.approx(7e-6 + 99 * 2e-6)
    assert tl.bottleneck == "unpacker" and tl.movement_bound
    # the bottleneck unit is ~saturated, others idle part-time
    assert tl.occupancy["unpacker"] == pytest.approx(1.0, abs=0.05)
    assert tl.occupancy["math"] < 0.6


def test_pipeline_without_double_buffering_serialises():
    per_tile = {"reader": 1e-6, "unpacker": 2e-6, "math": 1e-6,
                "packer": 2e-6, "writer": 1e-6}
    serial = tt.pipeline_timeline(per_tile, 100, cb_depth=1)
    overlapped = tt.pipeline_timeline(per_tile, 100, cb_depth=2)
    assert serial.total_s == pytest.approx(100 * 7e-6)
    assert overlapped.total_s < serial.total_s / 3


def test_fft_kernel_on_tensix_is_movement_bound():
    """The paper's core observation: the FFT's Tensix pipeline is limited
    by data movement (unpack/pack), not by the math unit."""
    a = ttarch.get_arch("wormhole_n300")
    plane = 1024 * 1024 * 8.0
    tl = tt.kernel_timeline(flops=5 * 1024 * 1024 * 20, dram_in=plane,
                            dram_out=plane, sram_read=11 * plane,
                            sram_write=11 * plane, arch=a)
    assert tl.movement_bound


# ---------------------------------------------------------------------------
# noc
# ---------------------------------------------------------------------------

def test_global_transpose_crosses_most_of_the_plane():
    x = ttnoc.global_transpose(1024, 1024, arch="wormhole_n300")
    plane = 1024 * 1024 * 8
    p = ttarch.get_arch("wormhole_n300").cores
    assert x["noc_bytes"] == pytest.approx(plane * (p - 1) / p)
    assert x["tiles"] == (1024 // 32) ** 2
    small = ttnoc.global_transpose(256, 256, arch="wormhole_n300")
    assert small["seconds"] < x["seconds"]


def test_all_to_all_prices_compressed_wire_format():
    tree = {"g": np.zeros((1024, 1024), np.float32)}
    full = ttnoc.all_to_all_s(tree, 8, "wormhole_n300")
    bf16 = ttnoc.all_to_all_s(tree, 8, "wormhole_n300", method="bf16")
    int8 = ttnoc.all_to_all_s(tree, 8, "wormhole_n300", method="int8")
    assert bf16["wire_bytes"] == pytest.approx(full["wire_bytes"] / 2)
    assert int8["wire_bytes"] == pytest.approx(full["wire_bytes"] / 4)
    assert full["wire_bytes"] == pytest.approx(4 * 1024 * 1024 * 7 / 8)
    assert int8["seconds"] < bf16["seconds"] < full["seconds"]


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------

def _fused(size, bb=1):
    return FFTPlan(shape=(size, size), algo="fused", backend="pallas",
                   block_batch=bb)


def _row_col(size):
    return FFTPlan(shape=(size, size), algo="row_col", backend="pallas",
                   block_batch=8)


def test_trace_stage_structure_fused_vs_transpose():
    """Fusion collapses the stage list to one kernel; the transpose path
    keeps four stages and 4x the DRAM traffic (the roofline's 8-vs-2
    plane-traversal model)."""
    from repro.analysis.roofline import fft2d_traffic_bytes
    f = tttrace.trace_plan(_fused(512), arch="wormhole_n300")
    r = tttrace.trace_plan(_row_col(512), arch="wormhole_n300")
    assert len(f.stages) == 1 and f.stages[0].name == "fused_fft2d"
    assert [s.name for s in r.stages] == [
        "row_fft", "global_transpose", "col_fft", "output_transpose"]
    plane = 512 * 512 * 8
    assert f.dram_bytes == pytest.approx(
        fft2d_traffic_bytes(512, 512, fused=True), rel=0.05)
    assert r.dram_bytes == pytest.approx(
        fft2d_traffic_bytes(512, 512, fused=False), rel=0.05)
    assert f.stages[0].noc_bytes == 0
    assert r.stages[1].noc_bytes > 0.9 * plane   # the §5 NoC all-to-all
    assert f.energy_j > 0 and r.energy_j > f.energy_j


@pytest.mark.parametrize("size", [256, 512])
@pytest.mark.parametrize("arch", ["wormhole_n300", "tpu_v5e"])
def test_predicted_ordering_fused_beats_transpose(size, arch):
    cands = [_fused(size), _row_col(size)]
    costs = [tttrace.predict_cost(p, arch=arch) for p in cands]
    assert costs[0] < costs[1]


@pytest.mark.parametrize("size,batch", [(256, 4), (512, 1)])
def test_ranking_consistency_predicted_vs_measured(size, batch):
    """The model is useful iff its ordering of real candidate plans matches
    what the measuring autotuner finds: fused-vs-transpose at 256^2/512^2.
    The 256^2 case measures a batch of 4 — one image is only tens of ms in
    interpret mode, inside this shared box's noise floor."""
    cands = [_fused(size), _row_col(size)]
    rng = np.random.default_rng(0)
    shp = (batch, size, size)
    x = SplitComplex(jnp.asarray(rng.standard_normal(shp), jnp.float32),
                     jnp.asarray(rng.standard_normal(shp), jnp.float32))
    measured, _ = _time_candidates(cands, x, iters=3)
    measured_order = np.argsort(measured).tolist()
    for arch in ("wormhole_n300", "tpu_v5e"):
        predicted = [tttrace.predict_cost(p, arch=arch, batch=batch)
                     for p in cands]
        assert np.argsort(predicted).tolist() == measured_order, \
            (arch, predicted, measured)


def test_vmem_high_water_regression_1024_fused():
    """Pin the GEMM fused kernel's 1024x1024 VMEM footprint (ROADMAP): the
    tile is 8 MiB of split-complex f32, the pass ping-pong doubles it, and
    the four-step operand tables add 2 x 24 KiB — just over the 16 MiB v5e
    VMEM budget, so the model must flag it instead of assuming it fits."""
    t = tttrace.trace_plan(_fused(1024), arch="tpu_v5e")
    tile = 1024 * 1024 * 8                  # re+im f32 plane
    tables = 2 * tttrace.fourstep_table_bytes(1024)   # both axes
    assert tile == 8 * MIB and tables == 49152
    assert t.sram_high_water == 2 * tile + tables == 16826368
    assert t.sram_budget == 16 * MIB
    assert not t.fits
    assert tttrace.predict_cost(_fused(1024), arch="tpu_v5e") == float("inf")
    # the Stockham-stage oracle (algo="fused_stockham") keeps its own pin:
    # packed per-stage twiddles instead of the dense four-step tables
    o = tttrace.trace_plan(
        FFTPlan(shape=(1024, 1024), algo="fused_stockham", backend="pallas",
                block_batch=1), arch="tpu_v5e")
    assert [s.name for s in o.stages] == ["fused_fft2d_stockham"]
    twiddles = 2 * (2 * 5 * 3 * (1024 // 4) * 4)
    assert o.sram_high_water == 2 * tile + twiddles == 16838656
    assert not o.fits
    # ...while 512x512 fits comfortably, and block_batch=4 (on a batch that
    # actually sustains it — block_batch clamps to the batch) busts it again
    assert tttrace.trace_plan(_fused(512), arch="tpu_v5e").fits
    assert not tttrace.trace_plan(_fused(512, bb=4), arch="tpu_v5e",
                                  batch=4).fits
    # the Wormhole budget is per-core L1 aggregated over the mesh: fits
    assert tttrace.trace_plan(_fused(1024), arch="wormhole_n300").fits


def test_trace_bf16_plans_halve_movement_golden():
    """Golden pin (ROADMAP: teach the tracer about bf16 plans): a bfloat16
    GEMM fused 1024^2 plan traces at exactly half the fp32 DRAM/SRAM
    bytes, its VMEM high-water drops from the pinned 16826368 B to
    8413184 B, and the PR 3 "does 1024x1024 fit in 16 MiB v5e VMEM?"
    answer flips to True."""
    f32 = tttrace.trace_plan(_fused(1024), arch="tpu_v5e")
    bf16 = tttrace.trace_plan(
        FFTPlan(shape=(1024, 1024), dtype="bfloat16", algo="fused",
                backend="pallas", block_batch=1), arch="tpu_v5e")
    assert tttrace.plan_elem_bytes(_fused(1024)) == 8
    assert tttrace.plan_elem_bytes(
        FFTPlan(shape=(1024, 1024), dtype="bfloat16", algo="fused",
                backend="pallas")) == 4
    assert f32.sram_high_water == 16826368 and not f32.fits
    assert bf16.sram_high_water == 16826368 // 2 == 8413184
    assert bf16.fits and bf16.sram_budget == 16 * MIB
    assert bf16.dram_bytes == f32.dram_bytes / 2
    s32, s16 = f32.stages[0], bf16.stages[0]
    assert s16.sram_bytes == s32.sram_bytes / 2
    assert s16.noc_bytes == s32.noc_bytes / 2
    assert bf16.energy_j < f32.energy_j
    assert bf16.flops == f32.flops          # same math, narrower planes
    # ...and the model query flips: the bf16 plan is now rankable
    bf16_plan = FFTPlan(shape=(1024, 1024), dtype="bfloat16", algo="fused",
                        backend="pallas", block_batch=1)
    assert tttrace.predict_cost(bf16_plan, arch="tpu_v5e") < float("inf")
    # the halving also reaches the NoC transpose path (row_col, tensix)
    r32 = tttrace.trace_plan(_row_col(512), arch="wormhole_n300")
    r16 = tttrace.trace_plan(
        FFTPlan(shape=(512, 512), dtype="bfloat16", algo="row_col",
                backend="pallas", block_batch=8), arch="wormhole_n300")
    assert r16.noc_bytes == r32.noc_bytes / 2


def test_vmem_bf16_compensated_1024_fits():
    """THE acceptance pin of the GEMM-first core: the precision-compensated
    bf16 1024x1024 plan — split hi/lo operand tables (2x table bytes, 2x
    table flops) but a bf16 resident tile — fits the 16 MiB v5e VMEM
    budget the fp32 plan busts, at exactly the plain-bf16 tile footprint
    plus one extra copy of the tables."""
    plain = FFTPlan(shape=(1024, 1024), dtype="bfloat16", algo="fused",
                    backend="pallas", block_batch=1, variant="plain")
    comp = dataclasses.replace(plain, variant="compensated")
    tp = tttrace.trace_plan(plain, arch="tpu_v5e")
    tc = tttrace.trace_plan(comp, arch="tpu_v5e")
    tables = 2 * tttrace.fourstep_table_bytes(1024, elem_bytes=4)
    assert tp.sram_high_water == 2 * 1024 * 1024 * 4 + tables == 8413184
    assert tc.sram_high_water == tp.sram_high_water + tables == 8437760
    assert tc.fits and tc.variant == "compensated"
    assert tc.flops == 2 * tp.flops          # split-pair reconstruction
    assert tc.dram_bytes == tp.dram_bytes + tables
    assert tttrace.predict_cost(comp, arch="tpu_v5e") < float("inf")
    d = tc.to_dict()
    assert d["variant"] == "compensated" and d["fits"]


def test_trace_fused3d_single_stage_vs_row_col():
    """The fused 3-D kernel traces to ONE stage with 2 DRAM volume
    traversals + tables; the row-column schedule pays three passes and
    four relayout round-trips, and the model must rank fused ahead on
    both archs."""
    f = FFTPlan(shape=(64, 64, 64), algo="fused", backend="pallas",
                block_batch=1)
    r = FFTPlan(shape=(64, 64, 64), algo="row_col", backend="pallas",
                block_batch=8)
    tf = tttrace.trace_plan(f, arch="tpu_v5e", batch=2)
    tr = tttrace.trace_plan(r, arch="tpu_v5e", batch=2)
    assert [s.name for s in tf.stages] == ["fused_fft3d"]
    assert [s.name for s in tr.stages] == [
        "w_fft", "transpose_wh_in", "h_fft", "transpose_wh_out",
        "transpose_wd_in", "d_fft", "transpose_wd_out"]
    vol = 2 * 64 ** 3 * 8                      # batch x split-complex f32
    tables = 3 * tttrace.fourstep_table_bytes(64)
    assert tf.dram_bytes == 2 * vol + tables
    assert tf.dram_bytes < tr.dram_bytes       # four round-trips vs none
    assert tf.sram_high_water == 64 ** 3 * 8 * 2 + tables
    assert tf.fits
    for arch in ("wormhole_n300", "tpu_v5e"):
        assert tttrace.predict_cost(f, arch=arch, batch=2) < \
            tttrace.predict_cost(r, arch=arch, batch=2)


def test_trace_dist_pencil_schedule_golden():
    """Golden regression for the extended tracer: the multi-chip pencil
    schedules walk per-shard plan stages + exchange legs, and the rfft2
    schedule's exchange is exactly half the complex one's."""
    from repro.core import clear_plan_cache
    clear_plan_cache()
    tc = tttrace.trace_dist((512, 512), devices=8, arch="wormhole_n300")
    tr = tttrace.trace_dist((512, 512), devices=8, arch="wormhole_n300",
                            real=True)
    assert [s.name for s in tc.stages] == [
        "rows/fft1d_four_step", "exchange_a2a", "cols/fft1d_four_step"]
    assert [s.name for s in tr.stages] == [
        "rows/rfft_inner_naive", "rows/rfft_untangle", "exchange_a2a",
        "cols/fft1d_four_step", "unpack_nyquist"]
    # per-device payload 64x512 (vs 64x256 packed) split-complex f32,
    # (p-1)/p of it crossing chips
    assert tc.exchange_wire_bytes == 64 * 512 * 8 * 7 / 8 == 229376.0
    assert tr.exchange_wire_bytes == 114688.0
    assert tr.kind == "prfft2" and tr.devices == 8 and tr.elem_bytes == 8
    assert tr.seconds > 0 and tr.energy_j > 0 and tr.fits
    d = tr.to_dict()
    assert d["exchange_wire_bytes"] == 114688.0
    assert len(d["stages"]) == 5
    # a second (still packed) exchange restores natural order
    tn = tttrace.trace_dist((512, 512), devices=8, arch="wormhole_n300",
                            real=True, transposed_output=False)
    assert tn.exchange_wire_bytes == 2 * tr.exchange_wire_bytes
    # the multi-chip hop table prices the legs: more chips, more hops
    assert ttnoc.eth_hops(8) == pytest.approx(1.5)      # 2x4 chip mesh
    assert ttnoc.eth_hops(2) < ttnoc.eth_hops(8) < ttnoc.eth_hops(32)
    x8 = ttnoc.all_to_all_s(1 << 20, 8, "wormhole_n300", multichip=True)
    assert x8["grid"] == (2, 4) and x8["hops"] == pytest.approx(1.5)
    # 16 ethernet links at 12.5 GB/s serialise the wire + 1.5 us of hops
    wire = (1 << 20) * 7 / 8
    assert x8["seconds"] == pytest.approx(wire / 200e9 + 1.5e-6)
    clear_plan_cache()


def test_dist_model_bench_predicted_rows_golden():
    """Pin the predicted side of BENCH_dist_model.json (the measured side
    is corroborated by tests/test_dist_rfft.py on emulated devices): at
    512^2 and 1024^2 the model ranks prfft2's exchange at exactly half of
    pfft2's on every arch, inside the (N/2+1)/N Hermitian bound."""
    import math
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import table6_dist_model as t6
    from repro.core import clear_plan_cache
    clear_plan_cache()
    rows = t6.predicted_rows((512, 1024), methods=("none", "bf16"))
    for n in (512, 1024):
        row = rows[f"{n}x{n}"]
        for arch in t6.MODEL_ARCHS:
            for method in ("none", "bf16"):
                a = row[f"pfft2/{method}/{arch}"]
                b = row[f"prfft2/{method}/{arch}"]
                assert row[f"wire_ratio/{method}/{arch}"] == 0.5
                assert b["exchange_wire_bytes"] <= math.ceil(
                    (n // 2 + 1) / n * a["exchange_wire_bytes"])
                assert a["us"] > 0 and b["energy_j"] > 0
                assert "exchange_a2a" in b["stages"]
    clear_plan_cache()


def test_dist_model_bench_ranking_artifact_agrees():
    """The committed BENCH_dist_model.json must carry all-True
    predicted-vs-measured wire agreement rows (regenerate with
    ``python -m benchmarks.table6_dist_model`` if the model changes)."""
    import json
    import os
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_dist_model.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_dist_model.json not generated yet")
    with open(path) as fh:
        data = json.load(fh)
    ranking = data["ranking"]
    assert ranking, "empty ranking section"
    for size, row in ranking.items():
        for key, val in row.items():
            if key.startswith(("wire_ratio_agrees", "wire_order_agrees",
                               "halved_bound_holds")):
                assert val is True, (size, key)
            if key.startswith("measured_wire_ratio"):
                assert val == pytest.approx(0.5), (size, key, val)


def test_trace_1d_plans_and_energy_scaling():
    small = tttrace.trace_plan(FFTPlan(shape=(4096,), algo="stockham"),
                               arch="wormhole_n300", batch=8)
    big = tttrace.trace_plan(FFTPlan(shape=(4096,), algo="stockham"),
                             arch="wormhole_n300", batch=64)
    assert big.seconds > small.seconds
    assert big.energy_j > small.energy_j
    assert big.dram_bytes == pytest.approx(8 * small.dram_bytes, rel=0.3)
    r2 = tttrace.trace_plan(FFTPlan(shape=(4096,), algo="stockham", radix=2),
                            arch="wormhole_n300", batch=8)
    # radix-2 runs twice the stages -> more SRAM traffic than mixed 4/2
    assert r2.stages[0].sram_bytes > small.stages[0].sram_bytes


def test_vmem_high_water_fused_rfft_1024_fits():
    """The tentpole's model pin, next to the 16838656 B complex golden:
    the fused rfft kernel's 1024x1024 fp32 working set is the half-width
    column tile ping-pong (2 x 1024 x 513 split-complex) plus the
    four-step tables — 8454144 B, UNDER the 16 MiB v5e budget the complex
    fused kernel busts.  Real-input specialisation flips the verdict."""
    rfused = FFTPlan(shape=(1024, 1024), algo="fused", backend="pallas",
                     block_batch=1, kind="rfft")
    t = tttrace.trace_plan(rfused, arch="tpu_v5e")
    assert [s.name for s in t.stages] == ["fused_rfft2d"]   # ONE stage
    tables = 2 * 3 * 32 * 32 * 8                 # (n1^2+n2^2+n1*n2) x 2 axes
    assert tttrace.fourstep_table_bytes(1024) == tables // 2 == 24576
    assert t.sram_high_water == 2 * 1024 * 513 * 8 + tables == 8454144
    assert t.fits and t.sram_budget == 16 * MIB
    assert tttrace.predict_cost(rfused, arch="tpu_v5e") < float("inf")
    # the complex golden next door stays pinned (and busted)
    c = tttrace.trace_plan(_fused(1024), arch="tpu_v5e")
    assert c.sram_high_water == 16826368 and not c.fits
    # HBM bytes: one real plane + one half spectrum ~ half the complex
    # kernel's two full planes
    ratio = t.dram_bytes / c.dram_bytes
    assert 0.49 < ratio < 0.52, ratio
    # the inverse twin mirrors the footprint
    ti = tttrace.trace_plan(
        FFTPlan(shape=(1024, 1024), algo="fused", backend="pallas",
                block_batch=1, kind="rfft", inverse=True), arch="tpu_v5e")
    assert ti.stages[0].name == "fused_irfft2d"
    assert ti.sram_high_water == 8454144 and ti.fits
    assert ti.dram_bytes == t.dram_bytes
    # NoC: the fused kernel never crosses the mesh; the jnp rfft schedule
    # pays the (halved) global transpose on a Tensix mesh
    tw = tttrace.trace_plan(rfused, arch="wormhole_n300")
    assert tw.noc_bytes == 0
    from repro.core import clear_plan_cache, get_plan
    clear_plan_cache()
    jn = tttrace.trace_plan(get_plan((1024, 1024), kind="rfft"),
                            arch="wormhole_n300")
    assert jn.noc_bytes > 0
    clear_plan_cache()


def test_vmem_high_water_fused_conv_pinned():
    """PR 10's model pin: the fused spectral-convolution stage's working
    set is the packed complex-row ping-pong (2 x rows x m/2 split-complex)
    plus the packed filter pair E/F (same bytes again) plus both
    half-length four-step table sets.  At the benchmark's 1024x64 shape
    that is 1077248 B (fits 16 MiB); at the SSM training shape
    (m=8192, 160 conv channels) it is 21168128 B — an honest bust that
    says the channel bank must split across grid steps on real silicon."""
    conv = FFTPlan(shape=(1024,), algo="fused", backend="pallas",
                   block_batch=1, kind="conv_causal")
    t = tttrace.trace_plan(conv, arch="tpu_v5e", batch=64)
    assert [s.name for s in t.stages] == ["fused_fftconv"]   # ONE stage
    assert tttrace.fourstep_table_bytes(512) == 14336        # (16, 32) split
    ping = 2 * 64 * 512 * 8
    assert t.sram_high_water == 2 * ping + 2 * 14336 == 1077248
    assert t.fits and t.sram_budget == 16 * MIB
    # the SSM-shaped trace: busts VMEM, and the model says so
    big = tttrace.trace_plan(
        FFTPlan(shape=(8192,), algo="fused", backend="pallas",
                block_batch=1, kind="conv_causal"),
        arch="tpu_v5e", batch=160)
    assert big.sram_high_water == 21168128 and not big.fits
    # the fused stage deletes the unfused path's six-plane traffic: > 3x
    # fewer HBM bytes at the SSM shape
    unf = tttrace.trace_plan(
        FFTPlan(shape=(8192,), algo="unfused", backend="jnp",
                block_batch=8, kind="conv_causal"),
        arch="tpu_v5e", batch=160)
    assert len(unf.stages) == 5                              # six-plane path
    assert unf.dram_bytes / big.dram_bytes > 3.0


def test_predicted_ordering_fused_rfft_beats_jnp_schedule():
    """prune="model" support for rfft keys: the fused kernel must outrank
    the jnp schedule wherever it fits."""
    for size in (256, 512):
        fused = FFTPlan(shape=(size, size), algo="fused", backend="pallas",
                        block_batch=1, kind="rfft")
        jnp_plan = FFTPlan(shape=(size, size), algo="naive", backend="jnp",
                           block_batch=8, kind="rfft")
        for arch in ("wormhole_n300", "tpu_v5e"):
            assert tttrace.predict_cost(fused, arch=arch) < \
                tttrace.predict_cost(jnp_plan, arch=arch), (size, arch)


def test_trace_rfft_plans_price_the_real_schedule():
    """rfft-kind plans must trace their actual schedule: inner half-length
    pass + untangle in 1-D; half-width spectrum transpose + column pass in
    2-D.  The half-spectrum saving shows up as fewer bytes than the c2c
    plan of the same shape, not as a crash or a full-length mischarge."""
    from repro.core import clear_plan_cache, get_plan
    clear_plan_cache()
    r1 = tttrace.trace_plan(get_plan((512,), kind="rfft"),
                            arch="wormhole_n300", batch=4)
    c1 = tttrace.trace_plan(get_plan((512,)), arch="wormhole_n300", batch=4)
    assert [s.name for s in r1.stages] == ["rfft_inner_naive",
                                           "rfft_untangle"]
    # inner naive pass runs at n/2: far below the full-length charge
    assert r1.stages[0].flops < 0.3 * 8.0 * 4 * 512 ** 2
    assert r1.seconds > 0 and r1.energy_j > 0
    # 2-D: forward and inverse both trace, with the half-width transpose
    r2 = tttrace.trace_plan(get_plan((64, 128), kind="rfft"),
                            arch="wormhole_n300")
    assert [s.name for s in r2.stages] == [
        "rfft_rows_naive", "rfft_untangle", "global_transpose", "col_fft"]
    c2 = tttrace.trace_plan(
        FFTPlan(shape=(64, 128), algo="row_col", backend="jnp",
                block_batch=8), arch="wormhole_n300")
    assert r2.noc_bytes < 0.6 * c2.noc_bytes      # halved transpose bytes
    ri = tttrace.trace_plan(get_plan((64, 128), kind="rfft", inverse=True),
                            arch="wormhole_n300")
    assert ri.stages[0].name == "col_ifft"
    assert ri.stages[-1].name == "irfft_extend"
    assert tttrace.predict_cost(get_plan((64, 128), kind="rfft"),
                                arch="tpu_v5e") < float("inf")
    clear_plan_cache()


# ---------------------------------------------------------------------------
# report — the paper's §6 table
# ---------------------------------------------------------------------------

def test_paper_table_reproduces_power_and_energy_ratios():
    """Acceptance: the Wormhole-vs-Xeon table shows ~8x less power and
    ~2.8x less energy for the Wormhole while being slower (paper abstract
    + §6), at every published size."""
    rows = ttreport.compare("wormhole_n300", "xeon_8160", source="paper")
    assert {r["size"] for r in rows} >= {256, 512, 1024}
    for r in rows:
        assert r["time_ratio"] > 1.0, "Wormhole is slower in the paper"
        assert 7.0 < r["power_ratio"] < 9.0, r
        assert 2.5 < r["energy_ratio"] < 3.1, r
    md = ttreport.markdown_table(rows)
    assert "wormhole_n300" in md and "xeon_8160" in md
    assert "1024x1024" in md
    import json
    parsed = json.loads(ttreport.to_json(rows))
    assert len(parsed["wormhole_vs_xeon"]) == len(rows)


def test_model_mode_table_runs():
    rows = ttreport.compare(source="model", sizes=(256,))
    assert rows[0]["time_a_ms"] > 0 and rows[0]["energy_b_j"] > 0


def test_rfft2_row_in_wormhole_vs_xeon_table():
    """The §6 comparison covers the real-input transform the distributed
    path ships: rfft2 model rows exist, run faster than the complex fft2
    rows on both archs (half the movement), and render in the table."""
    sizes = (256, 1024)
    c_rows = ttreport.compare(source="model", sizes=sizes)
    r_rows = ttreport.compare(source="model", sizes=sizes,
                              transform="rfft2")
    for cr, rr in zip(c_rows, r_rows):
        assert rr["transform"] == "rfft2" and cr["transform"] == "fft2"
        assert rr["size"] == cr["size"]
        assert rr["time_a_ms"] > 0 and rr["energy_a_j"] > 0
        # the Xeon baseline's rfft2 schedule halves the row-column
        # movement and FLOPs: strictly faster than its c2c fft2
        assert 0 < rr["time_b_ms"] < cr["time_b_ms"]
    md = ttreport.markdown_table(r_rows)
    assert "rfft2 1024x1024" in md
    # no published real-input anchors: paper source must refuse
    with pytest.raises(ValueError, match="anchors"):
        ttreport.compare(source="paper", transform="rfft2")
