"""Flash (streaming-softmax custom-VJP) attention vs a dense reference:
forward, gradients, GQA grouping, sliding windows, non-causal, odd chunking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention


def ref_attn(q, k, v, qp, kp, window, causal):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d) / np.sqrt(d)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k)
    m = kp[:, None, :] >= 0
    if causal:
        m &= kp[:, None, :] <= qp[:, :, None]
    if window is not None:
        m &= kp[:, None, :] > qp[:, :, None] - window
    s = jnp.where(m[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqkgc,bckd->bqkgd", p, v).reshape(b, sq, h, d)


def _setup(B=2, S=40, H=4, KV=2, D=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    return q, k, v, pos


@pytest.mark.parametrize("window,causal", [(None, True), (16, True),
                                           (None, False)])
@pytest.mark.parametrize("chunk", [8, 16, 40, 64])
def test_forward_matches_dense(window, causal, chunk):
    q, k, v, pos = _setup()
    f = flash_attention(q, k, v, pos, pos, chunk, window, causal)
    r = ref_attn(q, k, v, pos, pos, window, causal)
    assert float(jnp.abs(f - r).max()) < 1e-5


@pytest.mark.parametrize("window,causal", [(None, True), (12, True),
                                           (None, False)])
def test_gradients_match_dense(window, causal):
    q, k, v, pos = _setup(seed=3)

    def loss_f(q, k, v):
        return jnp.sum(jnp.sin(
            flash_attention(q, k, v, pos, pos, 16, window, causal)))

    def loss_r(q, k, v):
        return jnp.sum(jnp.sin(ref_attn(q, k, v, pos, pos, window, causal)))

    gf = jax.grad(loss_f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        assert float(jnp.abs(a - b).max()) < 2e-5


def test_mha_no_grouping():
    q, k, v, pos = _setup(H=4, KV=4, seed=5)
    f = flash_attention(q, k, v, pos, pos, 16, None, True)
    r = ref_attn(q, k, v, pos, pos, None, True)
    assert float(jnp.abs(f - r).max()) < 1e-5


def test_padding_positions_masked():
    q, k, v, pos = _setup(seed=7)
    kp = pos.at[:, -8:].set(-1)          # pad tail KV positions
    f = flash_attention(q, k, v, pos, kp, 16, None, False)
    r = ref_attn(q, k, v, pos, kp, None, False)
    assert float(jnp.abs(f - r).max()) < 1e-5


def test_jit_and_remat_compose():
    q, k, v, pos = _setup(seed=9)
    fn = jax.jit(jax.checkpoint(
        lambda q, k, v: flash_attention(q, k, v, pos, pos, 16, None, True)))
    out = fn(q, k, v)
    g = jax.jit(jax.grad(lambda q: jnp.sum(jax.checkpoint(
        lambda q: flash_attention(q, k, v, pos, pos, 16, None, True))(q))))(q)
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(g).all())
