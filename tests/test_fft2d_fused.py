"""Fused transpose-free 2-D FFT kernel, radix-4 Stockham, and rfft2 edges."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (fft2, rfft2, irfft2, from_complex, to_complex,
                        fft_stockham, fft_stockham_radix2)
from repro.core.complexmath import SplitComplex
from repro.kernels import ops


def _rand2d(shape, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape)
            + 1j * rng.standard_normal(shape)).astype(np.complex64)


@pytest.mark.parametrize("hw", [(8, 8), (32, 8), (8, 32), (64, 64),
                                (128, 64), (256, 256)])
def test_fused_kernel_matches_numpy(hw):
    z = _rand2d(hw, seed=sum(hw))
    got = np.asarray(to_complex(ops.fft2d_fused(from_complex(jnp.asarray(z)))))
    ref = np.fft.fft2(z)
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4


def test_fused_kernel_leading_batch_dims():
    z = _rand2d((2, 3, 16, 32), seed=7)
    got = np.asarray(to_complex(ops.fft2d_fused(from_complex(jnp.asarray(z)))))
    ref = np.fft.fft2(z)
    assert got.shape == ref.shape
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4


def test_fused_kernel_batch_padding():
    """batch=3 with block_batch=2 exercises the pad/unpad path."""
    z = _rand2d((3, 32, 32), seed=9)
    got = np.asarray(to_complex(
        ops.fft2d_fused(from_complex(jnp.asarray(z)), block_batch=2)))
    ref = np.fft.fft2(z)
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4


def test_fused_inverse_roundtrip():
    z = _rand2d((2, 64, 64), seed=3)
    x = from_complex(jnp.asarray(z))
    back = ops.fft2d_fused(ops.fft2d_fused(x), inverse=True)
    assert np.abs(np.asarray(to_complex(back)) - z).max() < 1e-3


def test_fft2_pallas_backend_routes_to_fused():
    z = _rand2d((64, 64), seed=4)
    x = from_complex(jnp.asarray(z))
    got = np.asarray(to_complex(fft2(x, backend="pallas")))
    ref = np.fft.fft2(z)
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4


def test_fft2_pallas_transpose_baseline_agrees():
    z = _rand2d((64, 64), seed=5)
    x = from_complex(jnp.asarray(z))
    fused = np.asarray(to_complex(fft2(x, backend="pallas", algo="fused")))
    rowcol = np.asarray(to_complex(fft2(x, backend="pallas",
                                        algo="row_col")))
    assert np.abs(fused - rowcol).max() / np.abs(rowcol).max() < 1e-4


def test_fft2_rejects_1d_input():
    x = from_complex(jnp.asarray(np.arange(8.0) + 0j, jnp.complex64))
    with pytest.raises(ValueError, match="at least 2 axes"):
        fft2(x)


# -- radix-4 Stockham vs the radix-2 oracle ---------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 64, 128, 1024, 2048])
def test_radix4_matches_radix2_oracle(n):
    """Same shape bit-for-bit, values within 1e-4 of the radix-2 path."""
    z = _rand2d((3, n), seed=n)
    x = from_complex(jnp.asarray(z))
    r4 = to_complex(fft_stockham(x))
    r2 = to_complex(fft_stockham_radix2(x))
    assert r4.shape == r2.shape and r4.dtype == r2.dtype
    scale = np.abs(np.asarray(r2)).max()
    assert np.abs(np.asarray(r4) - np.asarray(r2)).max() / scale < 1e-4
    ref = np.fft.fft(z)
    assert np.abs(np.asarray(r4) - ref).max() / np.abs(ref).max() < 1e-4


@pytest.mark.parametrize("radix", [2, 4])
def test_kernel_radix_variants(radix):
    z = _rand2d((4, 512), seed=radix)
    x = from_complex(jnp.asarray(z))
    got = np.asarray(to_complex(ops.fft_stockham(x, radix=radix)))
    ref = np.fft.fft(z)
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4


# -- rfft2 / irfft2 round-trips ---------------------------------------------

@pytest.mark.parametrize("hw", [(16, 16), (32, 64), (64, 32)])
def test_rfft2_matches_numpy(hw):
    rng = np.random.default_rng(11)
    x = rng.standard_normal(hw).astype(np.float32)
    got = np.asarray(to_complex(rfft2(jnp.asarray(x))))
    ref = np.fft.rfft2(x)
    assert got.shape == ref.shape
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-4


@pytest.mark.parametrize("hw", [(16, 16), (32, 64)])
def test_irfft2_roundtrip(hw):
    rng = np.random.default_rng(12)
    x = rng.standard_normal((2,) + hw).astype(np.float32)
    back = np.asarray(irfft2(rfft2(jnp.asarray(x))))
    assert back.shape == x.shape
    assert np.abs(back - x).max() < 1e-4
