"""Compressed gradient collectives (4 fake devices, subprocess) and the
error-feedback residual in the train step."""
import jax
import jax.numpy as jnp
import numpy as np

from _subproc import run_with_devices
from repro.dist.compression import wire_bytes

# shard_map comes from repro.dist._compat (three homes across jax versions)
# and the mesh from repro.launch.mesh.make_mesh (axis_types-tolerant).
CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist._compat import shard_map
from repro.dist.compression import psum_compressed
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 3.0
want = np.asarray(x.sum(0))
for method, tol in (("none", 1e-6), ("bf16", 0.1), ("int8", 0.3)):
    fn = shard_map(lambda v: psum_compressed(v[0], "data", method),
                   mesh=mesh, in_specs=(P("data", None),), out_specs=P())
    got = np.asarray(fn(x))
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < tol, (method, err)
print("COMPRESS_OK")
"""


def test_psum_compressed_4dev():
    assert "COMPRESS_OK" in run_with_devices(CODE, 4)


def test_wire_bytes():
    tree = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((16,))}
    assert wire_bytes(tree, "none") == 48 * 4
    assert wire_bytes(tree, "bf16") == 48 * 2
    assert wire_bytes(tree, "int8") == 48


def test_wire_bytes_mixed_dtypes():
    """bf16 compression never inflates an already-narrow leaf."""
    tree = {"w": jnp.zeros((4, 8), jnp.float32),        # 32 elems x 4 B
            "b": jnp.zeros((16,), jnp.bfloat16)}        # 16 elems x 2 B
    assert wire_bytes(tree, "none") == 32 * 4 + 16 * 2
    assert wire_bytes(tree, "bf16") == 32 * 2 + 16 * 2  # bf16 leaf unchanged
    assert wire_bytes(tree, "int8") == 48


def test_error_feedback_residual_carries():
    """bf16 compression keeps the quantisation error and replays it."""
    from repro.models.config import ModelConfig
    from repro.models import model as M
    from repro.train import optimizer as opt_lib
    from repro.train.train_step import make_train_step, init_opt_state

    cfg = ModelConfig(name="t", family="dense", d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64,
                      block_pattern=("attn_mlp",), repeat=1, head_dim=16,
                      attn_chunk=8, vocab_pad_multiple=32)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(cfg, ocfg, compress="bf16")
    state = init_opt_state(cfg, ocfg, params, compress="bf16")
    assert "ef_residual" in state
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    p2, s2, m = jax.jit(step)(params, state, batch)
    assert jnp.isfinite(m["loss"])
    resid_norm = sum(float(jnp.abs(r.astype(jnp.float32)).sum())
                     for r in jax.tree.leaves(s2["ef_residual"]))
    assert resid_norm > 0.0          # quantisation error was captured
