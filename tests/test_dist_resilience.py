"""Distributed exchange integrity: energy-checksummed pencil exchanges
detect injected wire faults, recover via one retry, and raise (never
silently corrupt) when the fault persists.  One 8-device subprocess covers
the whole matrix — process startup, not the checks, dominates the cost."""
from tests._subproc import run_with_devices

CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.core.complexmath import SplitComplex
from repro.dist import pencil
from repro.dist._compat import make_mesh
from repro.resilience import faults

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = SplitComplex(jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
                 jnp.asarray(rng.standard_normal((64, 64)), jnp.float32))
ref = pencil.pfft2(x, mesh)

def same(a, b):
    return (np.array_equal(np.asarray(a.re), np.asarray(b.re))
            and np.array_equal(np.asarray(a.im), np.asarray(b.im)))

# clean run: one attempt, delta exactly 0 (a2a is a pure permutation)
pencil.reset_exchange_log()
out = pencil.pfft2(x, mesh, verify=True)
log = pencil.exchange_log()
print("CLEAN", same(out, ref), len(log), log[0]["delta"] == 0.0)

# each wire-fault kind: detected (attempt 0 not ok), recovered on the
# retry, and the recovered result is bit-identical to the fault-free run
for kind in ("drop", "corrupt", "nan"):
    pencil.reset_exchange_log()
    with faults.inject("dist.exchange", kind) as fp:
        out = pencil.pfft2(x, mesh, verify=True)
    oks = [e["ok"] for e in pencil.exchange_log()]
    print("FAULT", kind, fp.fired(), oks == [False, True], same(out, ref))

# without verify the same fault passes through silently — the checksum is
# what stands between a dropped payload and a wrong answer
with faults.inject("dist.exchange", "drop"):
    bad = pencil.pfft2(x, mesh)
print("UNVERIFIED_DIFFERS", not same(bad, ref))

# persistent fault: retry also fails -> loud ExchangeIntegrityError
try:
    with faults.inject("dist.exchange", "drop", times=None):
        pencil.pfft2(x, mesh, verify=True)
    print("PERSISTENT raised=False")
except pencil.ExchangeIntegrityError as e:
    print("PERSISTENT raised=True tagged=" + str(e.tag == "pfft2"))

# rfft pair under verify: corrupt-exchange recovery + packed roundtrip
xr = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
rref = pencil.prfft2(xr, mesh)
with faults.inject("dist.exchange", "corrupt"):
    rout = pencil.prfft2(xr, mesh, verify=True)
with faults.inject("dist.exchange", "nan"):
    back = pencil.pirfft2(rout, mesh, verify=True)
print("RFFT", same(rout, rref),
      float(np.abs(np.asarray(back) - np.asarray(xr)).max()) < 1e-5)

# lossy wire format: quantisation noise stays inside the bf16 tolerance
pencil.reset_exchange_log()
pencil.pfft2(x, mesh, compress="bf16", verify=True)
print("BF16", [e["ok"] for e in pencil.exchange_log()] == [True])
"""


def test_exchange_checksum_detects_and_recovers():
    out = run_with_devices(CODE, 8)
    assert "CLEAN True 1 True" in out
    for kind in ("drop", "corrupt", "nan"):
        assert f"FAULT {kind} 1 True True" in out
    assert "UNVERIFIED_DIFFERS True" in out
    assert "PERSISTENT raised=True tagged=True" in out
    assert "RFFT True True" in out
    assert "BF16 True" in out
