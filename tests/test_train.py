"""Training behaviour: loss decreases, microbatch-accumulation equivalence,
optimizer/schedule sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train.train_step import init_opt_state, make_train_step

CFG = ModelConfig(name="t", family="dense", d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128,
                  block_pattern=("attn_mlp",), repeat=2, head_dim=16,
                  attn_chunk=16, vocab_pad_multiple=32)


def test_loss_decreases():
    dcfg = DataConfig(seq_len=32, global_batch=8, seed=3)
    data = SyntheticLM(dcfg, CFG)
    ocfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    state = init_opt_state(CFG, ocfg, params)
    step = jax.jit(make_train_step(CFG, ocfg))
    first = last = None
    for i in range(60):
        params, state, metrics = step(params, state, data.batch_at(i))
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert np.isfinite(last)
    assert last < first - 0.3, (first, last)


def test_microbatch_equivalence():
    """accumulated grads over 4 microbatches == single big batch update."""
    dcfg = DataConfig(seq_len=32, global_batch=8, seed=1)
    data = SyntheticLM(dcfg, CFG)
    batch = data.batch_at(0)
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                               clip_norm=None)
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    s1 = init_opt_state(CFG, ocfg, params)
    s4 = init_opt_state(CFG, ocfg, params)
    p1, _, m1 = jax.jit(make_train_step(CFG, ocfg, microbatches=1))(
        params, s1, batch)
    p4, _, m4 = jax.jit(make_train_step(CFG, ocfg, microbatches=4))(
        params, s4, batch)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-5, max(jax.tree.leaves(diffs))


def test_warmup_cosine_schedule():
    ocfg = opt_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                               min_lr_ratio=0.1)
    lr0 = float(opt_lib.warmup_cosine(ocfg, jnp.asarray(1)))
    lr_w = float(opt_lib.warmup_cosine(ocfg, jnp.asarray(10)))
    lr_end = float(opt_lib.warmup_cosine(ocfg, jnp.asarray(100)))
    assert lr0 < 0.2 and abs(lr_w - 1.0) < 1e-5 and abs(lr_end - 0.1) < 1e-3


def test_grad_clipping():
    ocfg = opt_lib.AdamWConfig(clip_norm=1e-6)
    params = {"w": jnp.ones((4, 4))}
    state = opt_lib.adamw_init(ocfg, params)
    grads = {"w": jnp.full((4, 4), 100.0)}
    newp, _, metrics = opt_lib.adamw_update(ocfg, grads, state, params)
    assert float(metrics["grad_norm"]) > 100.0       # reported pre-clip
    # post-clip update is tiny (clipped to 1e-6 total norm * lr scale)
    assert float(jnp.abs(newp["w"] - params["w"]).max()) < ocfg.lr * 2


def test_bf16_moments_halve_memory():
    ocfg = opt_lib.AdamWConfig(moments_dtype="bfloat16")
    params = {"w": jnp.ones((128, 128), jnp.float32)}
    st = opt_lib.adamw_init(ocfg, params)
    assert st["m"]["w"].dtype == jnp.bfloat16
    newp, st2, _ = opt_lib.adamw_update(ocfg, {"w": jnp.ones((128, 128))},
                                        st, params)
    assert st2["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(newp["w"]).all())
