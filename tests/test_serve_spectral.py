"""Spectral serving: shape-bucket scheduling, pipelined execution,
pre-warm/degrade, deadlines, drain-on-shutdown, and the load generator."""
import threading
import time

import numpy as np
import pytest

from repro.core import plan as plan_lib
from repro.core.complexmath import SplitComplex
from repro.data.pipeline import Prefetcher
from repro.resilience import faults
from repro.serve.spectral import (BucketConfig, MixItem, NoBucketError,
                                  Request, ShapeBucketScheduler,
                                  SpectralServer, closed_loop, open_loop)
from repro.serve.spectral.metrics import LatencyHistogram, Metrics


def _c2c_payload(rng, shape):
    return SplitComplex(rng.standard_normal(shape).astype(np.float32),
                        rng.standard_normal(shape).astype(np.float32))


def _to_complex(sc):
    return np.asarray(sc.re) + 1j * np.asarray(sc.im)


class FakeClock:
    """Settable clock for deterministic deadline/aging tests."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# -- plan.warm (bulk pre-warm API) -------------------------------------------


def test_warm_bulk_resolves_in_order():
    res = plan_lib.warm([(64, 64), {"shape": (64, 64), "kind": "rfft"},
                         {"shape": (64, 64), "inverse": True}])
    assert [r.plan.shape for r in res] == [(64, 64)] * 3
    assert [r.plan.kind for r in res] == ["c2c", "rfft", "c2c"]
    assert res[2].plan.inverse
    assert not any(r.degraded for r in res)


def test_warm_degrades_on_injected_fault():
    with faults.inject("serve.prewarm", "error", tag="c2c/64x64"):
        res = plan_lib.warm([(64, 64), {"shape": (64, 64), "kind": "rfft"}])
    assert res[0].degraded and "FaultInjected" in res[0].reason
    assert res[0].plan.backend == "jnp"
    assert res[0].requested_backend == "pallas"
    assert not res[1].degraded        # the fault never takes others down


def test_warm_on_error_raise_propagates():
    with faults.inject("serve.prewarm", "error"):
        with pytest.raises(faults.FaultInjected):
            plan_lib.warm([(64, 64)], on_error="raise")


# -- scheduler ---------------------------------------------------------------


def _sched(clock=None, **kw):
    buckets = [BucketConfig((64, 64), max_batch=4),
               BucketConfig((128, 128), max_batch=4)]
    return ShapeBucketScheduler(buckets, clock=clock or time.monotonic,
                                **kw)


def test_scheduler_reject_unmatched():
    s = _sched()
    with pytest.raises(NoBucketError):
        s.admit(Request(rid=0, payload=None, shape=(48, 48)))
    assert s.pending() == 0


def test_scheduler_pad_up_picks_smallest_fitting():
    s = _sched(unmatched="pad_up")
    b, padded = s.match("c2c", (48, 48))
    assert padded and b.shape == (64, 64)
    b, padded = s.match("c2c", (100, 20))
    assert padded and b.shape == (128, 128)
    # inverse transforms never pad up (no spectral-interpolation reading)
    b, padded = s.match("c2c", (48, 48), inverse=True)
    assert b is None
    # too big for every bucket
    b, padded = s.match("c2c", (256, 256))
    assert b is None


def test_scheduler_backpressure_bounded_queue():
    s = _sched(max_queue=2)
    assert s.admit(Request(rid=0, payload=None, shape=(64, 64)))
    assert s.admit(Request(rid=1, payload=None, shape=(64, 64)))
    r = Request(rid=2, payload=None, shape=(64, 64))
    assert not s.admit(r)
    assert r.bucket_label == "c2c/f/64x64"   # label known even on rejection
    assert s.pending() == 2


def test_scheduler_priority_aging_no_starvation():
    clk = FakeClock()
    s = _sched(clock=clk, aging_rate=1.0)
    s.admit(Request(rid="old-low", payload=None, shape=(64, 64),
                    priority=0.0))
    clk.t = 5.0
    s.admit(Request(rid="new-high", payload=None, shape=(128, 128),
                    priority=2.0))
    # old-low has aged 5s * 1.0 = 5.0 effective > 2.0: it dispatches first
    bucket, reqs = s.next_batch()
    assert [r.rid for r in reqs] == ["old-low"]
    bucket, reqs = s.next_batch()
    assert [r.rid for r in reqs] == ["new-high"]


def test_scheduler_deadline_sweep_retires_queued(recwarn):
    clk = FakeClock()
    retired = []
    s = _sched(clock=clk, on_timeout=retired.append)
    r = Request(rid="dies", payload=None, shape=(64, 64), deadline=1.0)
    live = Request(rid="lives", payload=None, shape=(64, 64))
    s.admit(r)
    s.admit(live)
    clk.t = 2.0
    bucket, reqs = s.next_batch()
    assert [x.rid for x in reqs] == ["lives"]
    assert [x.rid for x in retired] == ["dies"]
    assert s.pending() == 0


def test_scheduler_on_timeout_fires_outside_lock():
    """The timeout callback may re-enter the scheduler (the server's
    completion path reads queue depths): it must run with the internal
    lock released, or a non-reentrant lock deadlocks here."""
    clk = FakeClock()
    seen = []
    s = ShapeBucketScheduler(
        [BucketConfig((64, 64), max_batch=4)], clock=clk,
        on_timeout=lambda r: seen.append((r.rid, s.pending(),
                                          s.queue_depths())))
    s.admit(Request(rid="t", payload=None, shape=(64, 64), deadline=1.0))
    clk.t = 2.0
    assert s.next_batch() is None
    assert seen == [("t", 0, {"c2c/f/64x64": 0})]


def test_scheduler_threaded_admit_vs_sweep_loses_nothing():
    """Client threads hammer admit() while a consumer thread sweeps and
    dequeues: every admitted request comes out exactly once (dispatched
    or timed out) — the expiry sweep's queue rebuild must not discard a
    concurrently pushed request, and _pending must not drift."""
    timed_out = []
    s = ShapeBucketScheduler([BucketConfig((64, 64), max_batch=4)],
                             max_queue=100_000,
                             on_timeout=timed_out.append)
    n_threads, n_req = 4, 250
    admitted = [0] * n_threads

    def producer(t):
        for i in range(n_req):
            # half pre-expired: every sweep rebuilds the heap, so the
            # push-vs-rebuild window is exercised constantly
            dl = time.monotonic() if i % 2 else None
            if s.admit(Request(rid=(t, i), payload=None, shape=(64, 64),
                               deadline=dl)):
                admitted[t] += 1

    dispatched = []
    stop = threading.Event()

    def consumer():
        while not stop.is_set() or s.pending():
            sel = s.next_batch()
            if sel is not None:
                dispatched.extend(sel[1])

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(n_threads)]
    c = threading.Thread(target=consumer)
    c.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    c.join(timeout=30)
    assert not c.is_alive()
    total = sum(admitted)
    assert len(dispatched) + len(timed_out) == total
    rids = [r.rid for r in dispatched] + [r.rid for r in timed_out]
    assert len(set(rids)) == total        # exactly once, no duplicates
    assert s.pending() == 0


# -- metrics -----------------------------------------------------------------


def test_latency_histogram_percentiles_bracket_samples():
    h = LatencyHistogram()
    for ms in [1, 1, 1, 1, 1, 1, 1, 1, 1, 100]:
        h.record(ms / 1e3)
    snap = h.snapshot()
    assert snap["count"] == 10
    # p50 lands in the 1ms bucket (log-spaced edge <= ~1.26ms)
    assert 0.9 <= snap["p50_ms"] <= 1.3
    # p99 is the tail sample's bucket, capped at the true max
    assert 90 <= snap["p99_ms"] <= 100.0
    assert snap["max_ms"] == pytest.approx(100.0)


def test_metrics_snapshot_totals_roll_up():
    m = Metrics()
    m.inc("a", "admitted", 3)
    m.inc("b", "admitted", 2)
    m.observe("a", "e2e", 0.01)
    m.annotate("a", plan_backend="pallas")
    snap = m.snapshot()
    assert snap["totals"]["admitted"] == 5
    assert snap["buckets"]["a"]["counters"]["admitted"] == 3
    assert snap["buckets"]["a"]["plan_backend"] == "pallas"
    assert snap["buckets"]["a"]["latency"]["e2e"]["count"] == 1


# -- data.pipeline.Prefetcher ------------------------------------------------


def test_prefetcher_preserves_order_and_exhausts():
    with Prefetcher(iter(range(100)), depth=4) as p:
        assert list(p) == list(range(100))


def test_prefetcher_propagates_producer_error():
    def gen():
        yield 1
        raise RuntimeError("boom")

    p = Prefetcher(gen(), depth=2)
    it = iter(p)
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="boom"):
        next(it)


def test_prefetcher_inline_mode_is_passthrough():
    p = Prefetcher(iter([1, 2, 3]), depth=2, threaded=False)
    assert list(p) == [1, 2, 3]


def test_prefetcher_bounded_depth_backpressures_producer():
    produced = []

    def gen():
        for i in range(50):
            produced.append(i)
            yield i

    p = Prefetcher(gen(), depth=2)
    it = iter(p)
    assert next(it) == 0
    time.sleep(0.05)                      # let the producer run ahead
    assert len(produced) <= 2 + 2 + 1     # queue + sentinel slack, not 50
    p.close()


# -- server: correctness through the full pipeline ---------------------------


def test_server_inline_serves_correct_spectra():
    rng = np.random.default_rng(0)
    buckets = [BucketConfig((64, 64)), BucketConfig((64, 64), kind="rfft")]
    with SpectralServer(buckets, threaded=False) as srv:
        x = _c2c_payload(rng, (64, 64))
        r = rng.standard_normal((64, 64)).astype(np.float32)
        srv.submit("a", x)
        srv.submit("b", r, kind="rfft")
        assert srv.drain()
        got = _to_complex(srv.result("a").value)
        ref = np.fft.fft2(_to_complex(x))
        assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
        gotb = _to_complex(srv.result("b").value)
        refb = np.fft.rfft2(r)
        assert gotb.shape == (64, 33)
        assert np.max(np.abs(gotb - refb)) / np.max(np.abs(refb)) < 1e-4


def test_server_pad_up_matches_zero_padded_fft():
    rng = np.random.default_rng(1)
    with SpectralServer([BucketConfig((64, 64))], threaded=False,
                        unmatched="pad_up") as srv:
        x = _c2c_payload(rng, (48, 40))
        srv.submit("p", x)
        assert srv.drain()
        rec = srv.result("p")
        assert rec.status == "completed" and rec.padded
        padded = np.zeros((64, 64), np.complex128)
        padded[:48, :40] = _to_complex(x)
        ref = np.fft.fft2(padded)
        got = _to_complex(rec.value)
        assert got.shape == (64, 64)
        assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
        assert srv.metrics.counter("c2c/f/64x64", "padded_up") == 1


def test_server_rejects_unmatched_and_counts_it():
    rng = np.random.default_rng(2)
    with SpectralServer([BucketConfig((64, 64))], threaded=False) as srv:
        with pytest.raises(NoBucketError):
            srv.submit("nope", _c2c_payload(rng, (48, 48)))
        assert srv.metrics.counter("_unmatched", "rejected_nobucket") == 1
        with pytest.raises(KeyError):
            srv.result("nope")            # nothing was recorded


def test_server_prime_size_rides_demoted_jnp_plan():
    """A bucket whose shape the pallas kernels can't take (prime dims)
    resolves to a demoted jnp plan; requests are served correctly and the
    demotion is visible in fallback metrics + the bucket annotation."""
    rng = np.random.default_rng(3)
    with SpectralServer([BucketConfig((61, 61))], threaded=False) as srv:
        st = srv.states["c2c/f/61x61"]
        assert st.requested_backend == "pallas"
        assert st.plan.backend == "jnp" and st.plan.demote_reason
        x = _c2c_payload(rng, (61, 61))
        srv.submit("prime", x)
        assert srv.drain()
        rec = srv.result("prime")
        assert rec.status == "completed"
        ref = np.fft.fft2(_to_complex(x))
        got = _to_complex(rec.value)
        assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 1e-4
        assert srv.metrics.counter("c2c/f/61x61", "fallback_served") == 1
        snap = srv.snapshot()
        assert snap["buckets"]["c2c/f/61x61"]["demote_reason"]


def test_server_backpressure_and_duplicate_rid():
    rng = np.random.default_rng(4)
    with SpectralServer([BucketConfig((64, 64))], threaded=False,
                        max_queue=1) as srv:
        assert srv.submit("a", _c2c_payload(rng, (64, 64)))
        assert not srv.submit("b", _c2c_payload(rng, (64, 64)))
        assert srv.metrics.counter("c2c/f/64x64",
                                   "rejected_backpressure") == 1
        with pytest.raises(ValueError, match="duplicate"):
            srv.submit("a", _c2c_payload(rng, (64, 64)))
        assert srv.drain()
        assert srv.result("a").status == "completed"


def test_server_rejects_batched_payloads():
    rng = np.random.default_rng(5)
    with SpectralServer([BucketConfig((64, 64))], threaded=False) as srv:
        with pytest.raises(ValueError, match="batch"):
            srv.submit("x", rng.standard_normal((3, 64, 64)), kind="rfft")


# -- deadlines: queued vs in-flight ------------------------------------------


def test_deadline_expires_queued_deterministic_clock():
    clk = FakeClock()
    rng = np.random.default_rng(6)
    with SpectralServer([BucketConfig((64, 64))], threaded=False,
                        clock=clk) as srv:
        srv.submit("dies", _c2c_payload(rng, (64, 64)), deadline_s=1.0)
        srv.submit("lives", _c2c_payload(rng, (64, 64)))
        clk.t = 2.0                       # past the queued deadline
        assert srv.drain()
        assert srv.result("dies").status == "timed_out_queued"
        assert srv.result("lives").status == "completed"
        assert srv.metrics.counter("c2c/f/64x64", "timed_out_queued") == 1
        assert srv.metrics.counter("c2c/f/64x64", "completed") == 1


def test_deadline_expires_inflight_under_step_hang():
    """The deadline passes while the batch is already dispatched (a
    ``serve.step`` hang): the request terminates ``timed_out_inflight``,
    never ``timed_out_queued``, and never blocks forever."""
    rng = np.random.default_rng(7)
    with SpectralServer([BucketConfig((64, 64))], threaded=False) as srv:
        with faults.inject("serve.step", "hang", duration=0.25):
            srv.submit("late", _c2c_payload(rng, (64, 64)), deadline_s=0.05)
            assert srv.drain()
        rec = srv.result("late")
        assert rec.status == "timed_out_inflight"
        assert rec.value is None
        assert srv.metrics.counter("c2c/f/64x64", "timed_out_inflight") == 1
        assert srv.metrics.counter("c2c/f/64x64", "timed_out_queued") == 0


# -- prewarm + resilience ----------------------------------------------------


def test_prewarm_fault_degrades_with_identical_outputs():
    """An injected pre-warm fault demotes the bucket to jnp with no crash;
    the degraded server's spectra match a healthy server's bit-for-bit at
    fp32 tolerance (acceptance: degrade changes the path, not the math)."""
    rng = np.random.default_rng(8)
    x = _c2c_payload(rng, (64, 64))
    with SpectralServer([BucketConfig((64, 64))], threaded=False) as ok:
        ok.submit("r", x)
        ok.drain()
        want = _to_complex(ok.result("r").value)
    with faults.inject("serve.prewarm", "error"):
        srv = SpectralServer([BucketConfig((64, 64))], threaded=False)
    with srv:
        assert srv.degraded_buckets == ["c2c/f/64x64"]
        st = srv.states["c2c/f/64x64"]
        assert st.plan.backend == "jnp" and "FaultInjected" in st.reason
        assert srv.prewarm_report.degraded == ["c2c/f/64x64"]
        srv.submit("r", x)
        srv.drain()
        got = _to_complex(srv.result("r").value)
    assert np.max(np.abs(got - want)) <= 1e-6 * max(1.0, np.abs(want).max())


def test_prewarm_report_entries():
    with SpectralServer([BucketConfig((64, 64)),
                         BucketConfig((64, 64), kind="rfft")],
                        threaded=False) as srv:
        rep = srv.prewarm_report
        assert sorted(e.label for e in rep.entries) == \
            ["c2c/f/64x64", "rfft/f/64x64"]
        assert all(e.compile_s > 0 for e in rep.entries)
        assert rep.total_s >= max(e.compile_s for e in rep.entries)
        assert not rep.degraded


# -- threaded pipeline: drain-on-shutdown, zero orphans ----------------------


def test_threaded_drain_on_shutdown_zero_orphans():
    rng = np.random.default_rng(9)
    buckets = [BucketConfig((64, 64)), BucketConfig((64, 64), kind="rfft")]
    srv = SpectralServer(buckets, threaded=True)
    rids = []
    for i in range(30):
        rid = f"r{i}"
        if i % 2:
            ok = srv.submit(rid, rng.standard_normal((64, 64))
                            .astype(np.float32), kind="rfft")
        else:
            ok = srv.submit(rid, _c2c_payload(rng, (64, 64)))
        if ok:
            rids.append(rid)
    assert srv.close(timeout_s=60)        # stop admission + drain + join
    for rid in rids:                      # every admitted rid terminated
        rec = srv.result(rid, timeout=0)
        assert rec is not None and rec.status == "completed"
    assert not srv.submit("late", _c2c_payload(rng, (64, 64)))
    snap = srv.snapshot()
    assert snap["pending"] == 0
    assert snap["totals"]["completed"] == len(rids)
    assert not any(t.is_alive() for t in srv.executor._threads)


def test_result_consumes_record_and_frees_rid():
    """result() evicts the terminal record + event (no per-request leak
    in a long-lived server) and the rid becomes reusable."""
    rng = np.random.default_rng(14)
    with SpectralServer([BucketConfig((64, 64))], threaded=False) as srv:
        x = _c2c_payload(rng, (64, 64))
        srv.submit("r", x)
        assert srv.drain()
        assert srv.result("r").status == "completed"
        assert srv._records == {} and srv._done == {}
        with pytest.raises(KeyError):
            srv.result("r")                   # consumed
        srv.submit("r", x)                    # reuse: no duplicate error
        assert srv.drain()
        assert srv.result("r").status == "completed"


def test_prewarm_jnp_twin_failure_never_crashes(monkeypatch):
    """Both the bucket's plan AND its jnp twin fail to compile at
    pre-warm: startup still succeeds (degrade, never crash), the report
    records the double failure, and the runtime degrade path serves the
    first request anyway."""
    from repro.serve.spectral import prewarm as prewarm_mod

    def broken(state):
        raise RuntimeError("no compile for you")

    # only pre-warm sees the broken compiler; the executor's runtime
    # make_fn is untouched, so first dispatch recovers
    monkeypatch.setattr(prewarm_mod, "make_fn", broken)
    rng = np.random.default_rng(15)
    with SpectralServer([BucketConfig((64, 64))], threaded=False) as srv:
        (entry,) = srv.prewarm_report.entries
        assert entry.degraded
        assert "jnp twin failed" in entry.reason
        st = srv.states["c2c/f/64x64"]
        assert st.fn is None and st.plan.backend == "jnp"
        srv.submit("r", _c2c_payload(rng, (64, 64)))
        assert srv.drain()
        assert srv.result("r").status == "completed"


def test_threaded_step_error_terminates_requests():
    """A dispatch error that survives the degrade path still terminates
    every request in the batch (status "error"), never orphans them."""
    rng = np.random.default_rng(10)
    srv = SpectralServer([BucketConfig((64, 64))], threaded=True)
    try:
        # error fires on the jnp twin too: degrade re-raise path
        with faults.inject("serve.step", "error", times=None):
            srv.submit("e", _c2c_payload(rng, (64, 64)))
            rec = srv.result("e", timeout=30)
        assert rec is not None and rec.status == "error"
        assert isinstance(rec.error, faults.FaultInjected)
    finally:
        srv.close()


def test_threaded_staging_crash_still_releases_pipeline():
    """A staging-side crash (next_batch itself raising) kills the staging
    generator; the Prefetcher re-raises at the dispatch loop's next().
    The drain sentinel must still flow — shutdown() joins promptly and no
    pipeline thread is left alive."""
    srv = SpectralServer([BucketConfig((64, 64))], threaded=True)
    threads = list(srv.executor._threads)

    def boom():
        raise RuntimeError("staging boom")

    srv.scheduler.next_batch = boom
    srv.executor.poke()
    time.sleep(0.2)                   # let staging hit the crash
    t0 = time.monotonic()
    srv.executor.shutdown()
    assert time.monotonic() - t0 < 5.0
    assert not any(t.is_alive() for t in threads)
    snap = srv.metrics.snapshot()
    assert "staging boom" in snap["buckets"]["_pipeline"]["staging_error"]


def test_threaded_assembly_error_terminates_requests_not_pipeline():
    """Batch assembly failing after the requests left the scheduler still
    gives each an "error" terminal record, and staging survives to serve
    later requests."""
    rng = np.random.default_rng(16)
    srv = SpectralServer([BucketConfig((64, 64))], threaded=True)
    try:
        orig = srv.executor._assemble
        calls = {"n": 0}

        def flaky(bucket, reqs):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("assembly boom")
            return orig(bucket, reqs)

        srv.executor._assemble = flaky
        srv.submit("a", _c2c_payload(rng, (64, 64)))
        rec = srv.result("a", timeout=30)
        assert rec is not None and rec.status == "error"
        assert "assembly boom" in str(rec.error)
        srv.submit("b", _c2c_payload(rng, (64, 64)))
        rec = srv.result("b", timeout=30)
        assert rec is not None and rec.status == "completed"
    finally:
        srv.close()


# -- loadgen + metrics endpoint ----------------------------------------------


def test_closed_loop_completes_all():
    buckets = [BucketConfig((64, 64)), BucketConfig((128,))]
    mix = [MixItem((64, 64)), MixItem((128,), weight=0.5)]
    with SpectralServer(buckets, threaded=True) as srv:
        res = closed_loop(srv, mix, requests=24, concurrency=6, seed=0)
        assert res["completed"] == 24
        assert res["timed_out"] == 0
        assert res["achieved_qps"] > 0
        assert res["p99_ms"] >= res["p50_ms"] > 0


def test_open_loop_reports_offered_vs_achieved():
    with SpectralServer([BucketConfig((64, 64))], threaded=True) as srv:
        res = open_loop(srv, [MixItem((64, 64))], qps=100.0,
                        duration_s=0.3, seed=1)
        assert res["offered_qps"] == 100.0
        assert res["completed"] + res["timed_out"] + res["rejected"] > 0
        assert res["completed"] > 0


def test_metrics_http_endpoint_serves_snapshot():
    import json
    import urllib.request
    rng = np.random.default_rng(11)
    with SpectralServer([BucketConfig((64, 64))], threaded=False) as srv:
        port = srv.serve_metrics_http()
        srv.submit("m", _c2c_payload(rng, (64, 64)))
        srv.drain()
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read()
        snap = json.loads(body)
        assert snap["buckets"]["c2c/f/64x64"]["counters"]["admitted"] == 1
