"""FFT invariants: a seeded, hypothesis-free round-trip sweep over every
plan-registry kind (always runs), mirrored by hypothesis property tests
(when the dev dependency is installed — CI asserts it is)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (circular_conv, clear_plan_cache, fft, fft2, fft_conv,
                        from_complex, get_plan, ifft, irfft, irfft2, rfft,
                        rfft2, to_complex)
from repro.core import complexmath as cm
from repro.core.complexmath import SplitComplex

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                      # dev dependency (requirements-dev)
    HAVE_HYPOTHESIS = False

ALGOS = ["naive", "cooley_tukey", "cooley_tukey_fused", "stockham",
         "four_step"]

BACKENDS = ["jnp", "pallas"]


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)) \
        .astype(np.complex64)


# ---------------------------------------------------------------------------
# Seeded plan-registry sweep (no hypothesis needed)
# ---------------------------------------------------------------------------
# Every registry kind x both backends x awkward shapes: odd, prime,
# even-non-pow2, pow2 (the only shapes the kernels accept — everything else
# must demote to jnp, not crash), under scalar and ragged batch dims.

C2C_SIZES = (27, 31, 54, 64, 512)        # odd, prime, 2xodd, pow2, pow2-big
RFFT_SIZES = (54, 62, 64, 512, 1024)     # rfft needs even lengths; 1024's
                                         # inner 512 is the 1-D kernel path
BATCHES = ((), (3,), (2, 3))             # scalar batch and ragged leading dims
C2C_2D = ((9, 31), (12, 54), (16, 16))
RFFT_2D = ((10, 22), (9, 54), (16, 32), (64, 32))   # pow2 pairs hit the
                                                    # fused rfft kernel


def _assert_close(got, ref, tol=5e-4):
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(got, ref, atol=tol * scale, rtol=0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_plan_sweep_c2c_roundtrip_matches_numpy(backend):
    clear_plan_cache()
    for batch in BATCHES:
        for seed, n in enumerate(C2C_SIZES):
            x = _rand(batch + (n,), seed)
            z = from_complex(jnp.asarray(x))
            fwd = get_plan((n,), backend=backend)
            inv = get_plan((n,), backend=backend, inverse=True)
            _assert_close(np.asarray(to_complex(fwd(z))), np.fft.fft(x))
            _assert_close(np.asarray(to_complex(inv(fwd(z)))), x, 2e-3)
    clear_plan_cache()


@pytest.mark.parametrize("backend", BACKENDS)
def test_plan_sweep_rfft_roundtrip_matches_numpy(backend):
    clear_plan_cache()
    for batch in BATCHES:
        for seed, n in enumerate(RFFT_SIZES):
            rng = np.random.default_rng(100 + seed)
            x = rng.standard_normal(batch + (n,)).astype(np.float32)
            fwd = get_plan((n,), backend=backend, kind="rfft")
            inv = get_plan((n,), backend=backend, kind="rfft", inverse=True)
            _assert_close(np.asarray(to_complex(fwd(jnp.asarray(x)))),
                          np.fft.rfft(x))
            _assert_close(np.asarray(inv(fwd(jnp.asarray(x)))), x, 2e-3)
    clear_plan_cache()


@pytest.mark.parametrize("backend", BACKENDS)
def test_plan_sweep_2d_roundtrip_matches_numpy(backend):
    clear_plan_cache()
    for batch in BATCHES:
        for seed, hw in enumerate(C2C_2D):
            x = _rand(batch + hw, 200 + seed)
            z = from_complex(jnp.asarray(x))
            fwd = get_plan(hw, backend=backend)
            inv = get_plan(hw, backend=backend, inverse=True)
            _assert_close(np.asarray(to_complex(fwd(z))), np.fft.fft2(x))
            _assert_close(np.asarray(to_complex(inv(fwd(z)))), x, 2e-3)
        for seed, hw in enumerate(RFFT_2D):
            rng = np.random.default_rng(300 + seed)
            x = rng.standard_normal(batch + hw).astype(np.float32)
            fwd = get_plan(hw, backend=backend, kind="rfft")
            inv = get_plan(hw, backend=backend, kind="rfft", inverse=True)
            _assert_close(np.asarray(to_complex(fwd(jnp.asarray(x)))),
                          np.fft.rfft2(x))
            _assert_close(np.asarray(inv(fwd(jnp.asarray(x)))), x, 2e-3)
    clear_plan_cache()


def test_irfft2_explicit_shape_matches_numpy():
    """irfft2 honours s= with numpy truncate/pad semantics on both algo
    paths (the registry rfft-kind key and an explicit algo)."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((2, 24, 32)).astype(np.float32)
    spec = np.fft.rfft2(x)
    xf = from_complex(jnp.asarray(spec.astype(np.complex64)))
    for s in (None, (24, 16), (24, 64), (12, 32), (48, 32), (12, 48),
              (36, 20)):
        ref = np.fft.irfft2(spec, s=s) if s else np.fft.irfft2(spec)
        for kw in ({}, {"algo": "naive"}):
            got = np.asarray(irfft2(xf, s=s, **kw))
            assert got.shape == ref.shape, (s, kw, got.shape)
            _assert_close(got, ref, 2e-4)
    with pytest.raises(ValueError, match="positive"):
        irfft2(xf, s=(24, 0))


def test_irfft2_odd_widths_match_numpy():
    """Odd output widths follow numpy's odd-s semantics on the direct
    path (the registry's rfft keys cover even widths only)."""
    rng = np.random.default_rng(6)
    x = rng.standard_normal((24, 32)).astype(np.float32)
    spec = np.fft.rfft2(x)
    xf = from_complex(jnp.asarray(spec.astype(np.complex64)))
    for s in ((24, 31), (24, 33), (11, 27), (36, 63)):
        ref = np.fft.irfft2(spec, s=s)
        got = np.asarray(irfft2(xf, s=s))
        assert got.shape == ref.shape, (s, got.shape)
        _assert_close(got, ref, 2e-4)
    # 1-D twin: odd n routes through the direct Hermitian extension
    sp1 = np.fft.rfft(x[0])
    xf1 = from_complex(jnp.asarray(sp1.astype(np.complex64)))
    for n in (15, 17, 31):
        ref1 = np.fft.irfft(sp1, n=n)
        got1 = np.asarray(irfft(xf1, n=n))
        assert got1.shape == ref1.shape, n
        _assert_close(got1, ref1, 2e-4)


def test_rfft_pallas_demotes_with_registry_visible_reason():
    """Shapes with no kernel path must fall back to jnp cleanly — and the
    interned plan says why (not a crash, not a silent demotion)."""
    clear_plan_cache()
    for shape in ((54,), (62,), (10, 22), (9, 54)):
        p = get_plan(shape, kind="rfft", backend="pallas")
        assert p.backend == "jnp", shape
        assert p.demote_reason, shape
    # ...while kernel-capable shapes stay on pallas with no reason
    p1 = get_plan((1024,), kind="rfft", backend="pallas")
    assert p1.backend == "pallas" and p1.demote_reason is None
    p2 = get_plan((16, 32), kind="rfft", backend="pallas")
    assert p2.backend == "pallas" and p2.algo == "fused"
    assert p2.demote_reason is None
    clear_plan_cache()


CONV_BATCHES = ((), (3,), (2, 3))        # scalar and ragged leading dims
CONV_SIGLENS = (37, 100, 256, 1000)      # odd / even / pow2 / non-pow2
CONV_KERLENS = (1, 3, 33, 65)            # odd kernel lengths (SSM-style)


@pytest.mark.parametrize("backend", BACKENDS)
def test_conv_sweep_causal_matches_np_convolve(backend):
    """Seeded fft_conv sweep vs np.convolve: odd kernel lengths, causal
    truncation to the signal length, ragged batch dims, both backends
    (padded non-pow2 lengths route pallas onto the fused conv kernel;
    the truncation semantics must not depend on the backend)."""
    clear_plan_cache()
    for batch in CONV_BATCHES:
        for seed, (L, K) in enumerate(zip(CONV_SIGLENS, CONV_KERLENS)):
            rng = np.random.default_rng(seed + 10 * len(batch))
            sig = rng.standard_normal(batch + (L,)).astype(np.float32)
            ker = rng.standard_normal(batch + (K,)).astype(np.float32)
            got = np.asarray(fft_conv(jnp.asarray(sig), jnp.asarray(ker),
                                      backend=backend))
            flat_s = sig.reshape(-1, L)
            flat_k = ker.reshape(-1, K)
            ref = np.stack([np.convolve(s, kk)[:L]
                            for s, kk in zip(flat_s, flat_k)])
            _assert_close(got.reshape(-1, L), ref, 2e-4)
    clear_plan_cache()


@pytest.mark.parametrize("backend", BACKENDS)
def test_conv_sweep_circular_matches_fft_reference(backend):
    """Seeded circular_conv sweep vs the float64 FFT reference: pow2
    lengths hit the fused kernel on pallas, non-pow2 lengths demote (the
    values must stay correct either way)."""
    clear_plan_cache()
    for batch in CONV_BATCHES:
        for seed, n in enumerate((54, 64, 256, 300)):
            rng = np.random.default_rng(seed + 100 * len(batch))
            sig = rng.standard_normal(batch + (n,)).astype(np.float32)
            ker = rng.standard_normal(batch + (n,)).astype(np.float32)
            got = np.asarray(circular_conv(jnp.asarray(sig),
                                           jnp.asarray(ker),
                                           backend=backend))
            ref = np.real(np.fft.ifft(
                np.fft.fft(sig.astype(np.float64))
                * np.fft.fft(ker.astype(np.float64))))
            _assert_close(got, ref, 2e-4)
    clear_plan_cache()


def test_conv_pallas_demotes_with_registry_visible_reason():
    """Circular lengths with no kernel path (non-pow2) demote to the
    unfused jnp schedule with the reason interned on the plan."""
    clear_plan_cache()
    p = get_plan((300,), kind="conv_circular", backend="pallas")
    assert p.backend == "jnp" and p.algo == "unfused"
    assert "power-of-two" in p.demote_reason
    # ...while the causal kind always pads to pow2 and stays fused
    p2 = get_plan((256,), kind="conv_causal", backend="pallas")
    assert p2.algo == "fused" and p2.demote_reason is None
    clear_plan_cache()


# ---------------------------------------------------------------------------
# Hypothesis mirrors (deep randomised variants of the sweep above)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(logn=st.integers(1, 10), seed=st.integers(0, 2**20),
           algo=st.sampled_from(ALGOS))
    def test_matches_numpy(logn, seed, algo):
        n = 1 << logn
        x = _rand((2, n), seed)
        got = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)),
                                        algo=algo)))
        ref = np.fft.fft(x)
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(got, ref, atol=5e-4 * scale, rtol=0)

    @settings(max_examples=15, deadline=None)
    @given(logn=st.integers(1, 11), seed=st.integers(0, 2**20))
    def test_roundtrip(logn, seed):
        n = 1 << logn
        x = _rand((n,), seed)
        z = from_complex(jnp.asarray(x))
        back = np.asarray(to_complex(ifft(fft(z))))
        np.testing.assert_allclose(back, x, atol=2e-3)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 200), seed=st.integers(0, 2**20),
           batch=st.integers(1, 4), backend=st.sampled_from(BACKENDS))
    def test_plan_registry_roundtrip_property(n, seed, batch, backend):
        """The hypothesis mirror of the c2c sweep: any length, any batch,
        either backend — the registry must roundtrip through whatever
        algo/demotion it resolves."""
        x = _rand((batch, n), seed)
        z = from_complex(jnp.asarray(x))
        fwd = get_plan((n,), backend=backend)
        inv = get_plan((n,), backend=backend, inverse=True)
        got = np.asarray(to_complex(fwd(z)))
        ref = np.fft.fft(x)
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(got, ref, atol=2e-3 * scale, rtol=0)
        np.testing.assert_allclose(np.asarray(to_complex(inv(fwd(z)))), x,
                                   atol=2e-3)

    @settings(max_examples=15, deadline=None)
    @given(half=st.integers(1, 100), seed=st.integers(0, 2**20),
           backend=st.sampled_from(BACKENDS))
    def test_plan_registry_rfft_roundtrip_property(half, seed, backend):
        """The hypothesis mirror of the rfft sweep: any even length."""
        n = 2 * half
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((3, n)).astype(np.float32)
        fwd = get_plan((n,), backend=backend, kind="rfft")
        inv = get_plan((n,), backend=backend, kind="rfft", inverse=True)
        got = np.asarray(to_complex(fwd(jnp.asarray(x))))
        ref = np.fft.rfft(x)
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(got, ref, atol=2e-3 * scale, rtol=0)
        np.testing.assert_allclose(np.asarray(inv(fwd(jnp.asarray(x)))), x,
                                   atol=2e-3)

    @settings(max_examples=15, deadline=None)
    @given(logn=st.integers(2, 10), seed=st.integers(0, 2**20),
           a=st.floats(-3, 3), b=st.floats(-3, 3))
    def test_linearity(logn, seed, a, b):
        n = 1 << logn
        x, y = _rand((n,), seed), _rand((n,), seed + 1)
        fx = to_complex(fft(from_complex(jnp.asarray(x))))
        fy = to_complex(fft(from_complex(jnp.asarray(y))))
        fxy = to_complex(fft(from_complex(jnp.asarray(a * x + b * y))))
        np.testing.assert_allclose(np.asarray(fxy), a * np.asarray(fx)
                                   + b * np.asarray(fy), atol=1e-2)

    @settings(max_examples=15, deadline=None)
    @given(logn=st.integers(1, 11), seed=st.integers(0, 2**20))
    def test_parseval(logn, seed):
        n = 1 << logn
        x = _rand((n,), seed)
        fx = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)))))
        e_time = np.sum(np.abs(x) ** 2)
        e_freq = np.sum(np.abs(fx) ** 2) / n
        np.testing.assert_allclose(e_freq, e_time, rtol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(logn=st.integers(3, 9), shift=st.integers(0, 63),
           seed=st.integers(0, 2**20))
    def test_shift_theorem(logn, shift, seed):
        n = 1 << logn
        shift = shift % n
        x = _rand((n,), seed)
        fx = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)))))
        fxs = np.asarray(to_complex(fft(from_complex(
            jnp.asarray(np.roll(x, -shift))))))
        phase = np.exp(2j * np.pi * shift * np.arange(n) / n)
        np.testing.assert_allclose(fxs, fx * phase, atol=5e-3 * max(
            np.abs(fx).max(), 1.0))

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 600), seed=st.integers(0, 2**20))
    def test_arbitrary_length_bluestein(n, seed):
        x = _rand((n,), seed)
        got = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)))))
        ref = np.fft.fft(x)
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(got, ref, atol=2e-3 * scale)

    @settings(max_examples=15, deadline=None)
    @given(logn=st.integers(1, 10), seed=st.integers(0, 2**20))
    def test_rfft_hermitian_and_matches(logn, seed):
        n = 1 << logn
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((3, n)).astype(np.float32)
        got = np.asarray(to_complex(rfft(jnp.asarray(x))))
        ref = np.fft.rfft(x)
        scale = max(np.abs(ref).max(), 1.0)
        np.testing.assert_allclose(got, ref, atol=5e-4 * scale)
        back = np.asarray(irfft(rfft(jnp.asarray(x))))
        np.testing.assert_allclose(back, x, atol=2e-3)

    @settings(max_examples=10, deadline=None)
    @given(logl=st.integers(3, 8), k=st.integers(1, 16),
           seed=st.integers(0, 2**18))
    def test_fftconv_matches_direct(logl, k, seed):
        L = 1 << logl
        rng = np.random.default_rng(seed)
        sig = rng.standard_normal((2, L)).astype(np.float32)
        ker = rng.standard_normal((2, k)).astype(np.float32)
        got = np.asarray(fft_conv(jnp.asarray(sig), jnp.asarray(ker)))
        ref = np.stack([np.convolve(s, kk)[:L] for s, kk in zip(sig, ker)])
        np.testing.assert_allclose(got, ref,
                                   atol=2e-3 * max(1.0, np.abs(ref).max()))


def test_fft2_matches_numpy():
    x = _rand((64, 128), 7)
    got = np.asarray(to_complex(fft2(from_complex(jnp.asarray(x)))))
    ref = np.fft.fft2(x)
    np.testing.assert_allclose(got, ref, atol=1e-3 * np.abs(ref).max())


def test_karatsuba_mul_matches():
    a = from_complex(jnp.asarray(_rand((128,), 1)))
    b = from_complex(jnp.asarray(_rand((128,), 2)))
    m4 = cm.mul(a, b)
    m3 = cm.mul3(a, b)
    np.testing.assert_allclose(np.asarray(m3.re), np.asarray(m4.re),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(m3.im), np.asarray(m4.im),
                               atol=1e-4)
