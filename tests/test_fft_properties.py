"""Property-based tests (hypothesis) for the FFT core's invariants."""
import pytest

pytest.importorskip("hypothesis", reason="dev dependency (requirements-dev)")

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import (fft, ifft, rfft, irfft, fft2, from_complex,
                        to_complex, fft_conv)
from repro.core import complexmath as cm

ALGOS = ["naive", "cooley_tukey", "cooley_tukey_fused", "stockham",
         "four_step"]


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)) \
        .astype(np.complex64)


@settings(max_examples=20, deadline=None)
@given(logn=st.integers(1, 10), seed=st.integers(0, 2**20),
       algo=st.sampled_from(ALGOS))
def test_matches_numpy(logn, seed, algo):
    n = 1 << logn
    x = _rand((2, n), seed)
    got = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)), algo=algo)))
    ref = np.fft.fft(x)
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(got, ref, atol=5e-4 * scale, rtol=0)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(1, 11), seed=st.integers(0, 2**20))
def test_roundtrip(logn, seed):
    n = 1 << logn
    x = _rand((n,), seed)
    z = from_complex(jnp.asarray(x))
    back = np.asarray(to_complex(ifft(fft(z))))
    np.testing.assert_allclose(back, x, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(2, 10), seed=st.integers(0, 2**20),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_linearity(logn, seed, a, b):
    n = 1 << logn
    x, y = _rand((n,), seed), _rand((n,), seed + 1)
    fx = to_complex(fft(from_complex(jnp.asarray(x))))
    fy = to_complex(fft(from_complex(jnp.asarray(y))))
    fxy = to_complex(fft(from_complex(jnp.asarray(a * x + b * y))))
    np.testing.assert_allclose(np.asarray(fxy), a * np.asarray(fx)
                               + b * np.asarray(fy), atol=1e-2)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(1, 11), seed=st.integers(0, 2**20))
def test_parseval(logn, seed):
    n = 1 << logn
    x = _rand((n,), seed)
    fx = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)))))
    e_time = np.sum(np.abs(x) ** 2)
    e_freq = np.sum(np.abs(fx) ** 2) / n
    np.testing.assert_allclose(e_freq, e_time, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(3, 9), shift=st.integers(0, 63),
       seed=st.integers(0, 2**20))
def test_shift_theorem(logn, shift, seed):
    n = 1 << logn
    shift = shift % n
    x = _rand((n,), seed)
    fx = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)))))
    fxs = np.asarray(to_complex(fft(from_complex(
        jnp.asarray(np.roll(x, -shift))))))
    phase = np.exp(2j * np.pi * shift * np.arange(n) / n)
    np.testing.assert_allclose(fxs, fx * phase, atol=5e-3 * max(
        np.abs(fx).max(), 1.0))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 600), seed=st.integers(0, 2**20))
def test_arbitrary_length_bluestein(n, seed):
    x = _rand((n,), seed)
    got = np.asarray(to_complex(fft(from_complex(jnp.asarray(x)))))
    ref = np.fft.fft(x)
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(got, ref, atol=2e-3 * scale)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(1, 10), seed=st.integers(0, 2**20))
def test_rfft_hermitian_and_matches(logn, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((3, n)).astype(np.float32)
    got = np.asarray(to_complex(rfft(jnp.asarray(x))))
    ref = np.fft.rfft(x)
    scale = max(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(got, ref, atol=5e-4 * scale)
    back = np.asarray(irfft(rfft(jnp.asarray(x))))
    np.testing.assert_allclose(back, x, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(logl=st.integers(3, 8), k=st.integers(1, 16), seed=st.integers(0, 2**18))
def test_fftconv_matches_direct(logl, k, seed):
    L = 1 << logl
    rng = np.random.default_rng(seed)
    sig = rng.standard_normal((2, L)).astype(np.float32)
    ker = rng.standard_normal((2, k)).astype(np.float32)
    got = np.asarray(fft_conv(jnp.asarray(sig), jnp.asarray(ker)))
    ref = np.stack([np.convolve(s, kk)[:L] for s, kk in zip(sig, ker)])
    np.testing.assert_allclose(got, ref, atol=2e-3 * max(1.0, np.abs(ref).max()))


def test_fft2_matches_numpy():
    x = _rand((64, 128), 7)
    got = np.asarray(to_complex(fft2(from_complex(jnp.asarray(x)))))
    ref = np.fft.fft2(x)
    np.testing.assert_allclose(got, ref, atol=1e-3 * np.abs(ref).max())


def test_karatsuba_mul_matches():
    a = from_complex(jnp.asarray(_rand((128,), 1)))
    b = from_complex(jnp.asarray(_rand((128,), 2)))
    m4 = cm.mul(a, b)
    m3 = cm.mul3(a, b)
    np.testing.assert_allclose(np.asarray(m3.re), np.asarray(m4.re), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m3.im), np.asarray(m4.im), atol=1e-4)
