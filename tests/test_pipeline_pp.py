"""GPipe pipeline parallelism: forward + autodiff backward == sequential
(4 fake devices, subprocess)."""
from _subproc import run_with_devices

# Mesh construction goes through repro.launch.mesh.make_mesh, which is
# tolerant of jax versions without jax.sharding.AxisType.
CODE = r"""
import jax, jax.numpy as jnp
from repro.dist.pipeline import pipelined_apply
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("pod",))
ws = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.3
def stage_fn(w, x): return jnp.tanh(x @ w)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
out = pipelined_apply(mesh, "pod", stage_fn, ws, x, n_microbatches=4)
ref = x
for i in range(4): ref = jnp.tanh(ref @ ws[i])
assert float(jnp.abs(out - ref).max()) < 1e-5

g = jax.grad(lambda ws: jnp.sum(pipelined_apply(mesh, "pod", stage_fn, ws, x, 4) ** 2))(ws)
gr = jax.grad(lambda ws: (lambda r: jnp.sum(r**2))(
    jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(x @ ws[0]) @ ws[1]) @ ws[2]) @ ws[3])))(ws)
rel = float(jnp.abs(g - gr).max() / (jnp.abs(gr).max() + 1e-9))
assert rel < 1e-4, rel
print("PP_OK")
"""


def test_gpipe_pipeline_4dev():
    assert "PP_OK" in run_with_devices(CODE, 4)
