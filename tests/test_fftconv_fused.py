"""The fused spectral-convolution kernel (PR 10): correctness of the
VMEM-resident rfft -> pointwise multiply -> irfft pass against float64
numpy, the conv plan registry keys and their demotions, the per-plan
filter-spectrum cache (the kernel-side rfft runs ONCE per plan key for
static filters), the packed-domain filter cache, and gradient parity of
the custom-VJP pallas path against the jnp twin."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clear_plan_cache, fft_conv, circular_conv, get_plan
from repro.core import fftconv as fftconv_mod
from repro.core.complexmath import SplitComplex
from repro.kernels import fftconv_fused as fconv
from repro.kernels import ops


def _kf64(k, m):
    pad = np.zeros(k.shape[:-1] + (m,), np.float64)
    pad[..., : k.shape[-1]] = k
    return np.fft.rfft(pad)


def _split(c):
    return SplitComplex(jnp.asarray(c.real, jnp.float32),
                        jnp.asarray(c.imag, jnp.float32))


# ---------------------------------------------------------------------------
# Raw kernel wrapper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [4, 8, 64, 1024])
@pytest.mark.parametrize("rows", [1, 3, 64])
def test_fused_kernel_matches_numpy_shared_bank(m, rows):
    """Shared filter bank (rows, m/2+1) against (batch, rows, m) — the SSM
    channel-bank layout — including odd row counts (no pairing
    constraint) and the tiny-length edge m=4."""
    rng = np.random.default_rng(m + rows)
    x = rng.standard_normal((2, rows, m)).astype(np.float32)
    kf = _kf64(rng.standard_normal((rows, m)), m)
    ref = np.fft.irfft(np.fft.rfft(x.astype(np.float64)) * kf[None], m)
    out = np.asarray(ops.fftconv_fused(jnp.asarray(x), _split(kf)),
                     np.float64)
    err = np.linalg.norm(out - ref) / np.linalg.norm(ref)
    assert err < 2e-6, err


def test_fused_kernel_per_batch_banks():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, 5, 512)).astype(np.float32)
    kf = _kf64(rng.standard_normal((3, 5, 512)), 512)
    ref = np.fft.irfft(np.fft.rfft(x.astype(np.float64)) * kf, 512)
    out = np.asarray(ops.fftconv_fused(jnp.asarray(x), _split(kf)),
                     np.float64)
    assert np.linalg.norm(out - ref) / np.linalg.norm(ref) < 2e-6


def test_fused_kernel_rejects_bad_lengths():
    for m in (3, 6, 768):
        with pytest.raises(ValueError, match="power-of-two"):
            fconv._check_len(m)


# ---------------------------------------------------------------------------
# Packed-domain filter operands
# ---------------------------------------------------------------------------

def test_pack_filter_concrete_matches_traced():
    """The float64-numpy pack (concrete filters) and the in-graph jnp pack
    (traced training parameters) build the same E/F operands."""
    m = 256
    rng = np.random.default_rng(1)
    kf = _split(_kf64(rng.standard_normal((4, m)), m))
    e_np, f_np = fconv.pack_filter(kf, m, jnp.float32)
    e_tr, f_tr = jax.jit(
        lambda k: fconv.pack_filter(k, m, jnp.float32))(kf)
    for a, b in ((e_np, e_tr), (f_np, f_tr)):
        scale = float(np.abs(np.asarray(a.re)).max())
        np.testing.assert_allclose(np.asarray(a.re), np.asarray(b.re),
                                   atol=1e-6 * scale)
        np.testing.assert_allclose(np.asarray(a.im), np.asarray(b.im),
                                   atol=1e-6 * scale)


def test_pack_filter_identity_cache():
    """One filter object across calls -> one pack; a fresh filter array
    recomputes and replaces the entry (never stale)."""
    fconv.clear_pack_cache()
    m = 128
    rng = np.random.default_rng(2)
    kf = _split(_kf64(rng.standard_normal((3, m)), m))
    ef1 = fconv.pack_filter(kf, m, jnp.float32)
    ef2 = fconv.pack_filter(kf, m, jnp.float32)
    assert ef1 is ef2                      # identity hit, no recompute
    kf3 = _split(_kf64(rng.standard_normal((3, m)), m))
    ef3 = fconv.pack_filter(kf3, m, jnp.float32)
    assert ef3 is not ef1
    assert len(fconv._PACK_CACHE) == 1     # one entry per shape/length key
    fconv.clear_pack_cache()
    assert not fconv._PACK_CACHE


# ---------------------------------------------------------------------------
# Conv plans: keys, demotions, the filter-spectrum cache
# ---------------------------------------------------------------------------

def test_conv_plan_keys_and_demotions():
    clear_plan_cache()
    pf = get_plan((1024,), kind="conv_causal", backend="pallas")
    assert (pf.algo, pf.backend, pf.demote_reason) == \
        ("fused", "pallas", None)
    pu = get_plan((1024,), kind="conv_causal", backend="jnp")
    assert (pu.algo, pu.backend) == ("unfused", "jnp")
    assert pf is not pu                    # backend is part of the key
    # non-power-of-two circular length: demote with a visible reason
    pd = get_plan((768,), kind="conv_circular", backend="pallas")
    assert pd.algo == "unfused" and pd.backend == "jnp"
    assert "power-of-two" in pd.demote_reason
    # conv plans are 1-D forward-only
    with pytest.raises(ValueError, match="1-D"):
        get_plan((8, 8), kind="conv_causal")
    with pytest.raises(ValueError, match="inverse"):
        get_plan((1024,), kind="conv_causal", inverse=True)


def test_filter_spectrum_cached_once_per_plan_key():
    """The satellite guarantee: with a static filter, the kernel-side rfft
    of the filter runs ONCE per conv plan key across repeated calls."""
    clear_plan_cache()
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 4, 200)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((4, 33)).astype(np.float32))
    for _ in range(4):
        fft_conv(x, k, backend="pallas")
    (key, stats), = fftconv_mod.SPECTRUM_STATS.items()
    assert key[2:] == ("conv_causal", "pallas", "fused")
    assert stats == {"computes": 1, "hits": 3}
    # a fresh filter array recomputes (the cache is never stale)
    k2 = jnp.asarray(rng.standard_normal((4, 33)).astype(np.float32))
    fft_conv(x, k2, backend="pallas")
    assert fftconv_mod.SPECTRUM_STATS[key] == {"computes": 2, "hits": 3}
    # traced filters bypass the cache entirely (recomputed in-graph)
    jax.jit(lambda a, b: fft_conv(a, b, backend="pallas"))(x, k2)
    assert fftconv_mod.SPECTRUM_STATS[key] == {"computes": 2, "hits": 3}
    clear_plan_cache()
    assert not fftconv_mod.SPECTRUM_STATS


# ---------------------------------------------------------------------------
# End-to-end conv entry points and gradients
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fft_conv_causal_matches_direct(backend):
    clear_plan_cache()
    rng = np.random.default_rng(4)
    x = rng.standard_normal((4, 16, 1000)).astype(np.float32)
    k = rng.standard_normal((16, 65)).astype(np.float32)
    ref = np.stack([[np.convolve(x[b, c], k[c])[:1000] for c in range(16)]
                    for b in range(4)])
    out = np.asarray(fft_conv(jnp.asarray(x), jnp.asarray(k),
                              backend=backend), np.float64)
    assert np.linalg.norm(out - ref) / np.linalg.norm(ref) < 2e-6


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_circular_conv_matches_fft_reference(backend):
    clear_plan_cache()
    rng = np.random.default_rng(5)
    x = rng.standard_normal((2, 8, 256)).astype(np.float32)
    k = rng.standard_normal((8, 256)).astype(np.float32)
    ref = np.real(np.fft.ifft(np.fft.fft(x.astype(np.float64))
                              * np.fft.fft(k.astype(np.float64))[None]))
    out = np.asarray(circular_conv(jnp.asarray(x), jnp.asarray(k),
                                   backend=backend), np.float64)
    assert np.linalg.norm(out - ref) / np.linalg.norm(ref) < 2e-6


def test_fused_gradients_match_jnp_backend():
    """The pallas conv path trains: its custom VJP (the bilinear jnp twin)
    produces the same gradients as the unfused jnp backend."""
    clear_plan_cache()
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 4, 300)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((4, 33)).astype(np.float32))

    def loss(backend):
        return lambda xx, kk: jnp.sum(
            fft_conv(xx, kk, backend=backend) ** 2)

    gx_p, gk_p = jax.grad(loss("pallas"), argnums=(0, 1))(x, k)
    gx_j, gk_j = jax.grad(loss("jnp"), argnums=(0, 1))(x, k)
    rx = float(jnp.abs(gx_p - gx_j).max() / jnp.abs(gx_j).max())
    rk = float(jnp.abs(gk_p - gk_j).max() / jnp.abs(gk_j).max())
    assert rx < 1e-4 and rk < 1e-4, (rx, rk)


def test_fused_conv_under_jit_traced_filter():
    """The training pattern end-to-end: x AND filter traced (jit-time
    parameters), the filter packs in-graph, values match the eager path."""
    clear_plan_cache()
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 3, 500)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((3, 17)).astype(np.float32))
    eager = fft_conv(x, k, backend="pallas")
    jitted = jax.jit(lambda a, b: fft_conv(a, b, backend="pallas"))(x, k)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               atol=1e-5)
