"""Straggler mitigation logic: deterministic rebalancing + ejection."""
import numpy as np
import pytest

from repro.dist.straggler import rebalance, should_eject

# Only the property-based sweep needs hypothesis (a dev dependency); the
# deterministic tests below must run even where it is absent.
try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def test_rebalance_shifts_work_away_from_slow_host():
    times = [1.0, 1.0, 1.0, 3.0]          # host 3 is 3x slower
    a = rebalance(times, 16)
    assert sum(a) == 16
    assert a[3] < a[0]
    assert a[3] >= 1


def test_rebalance_uniform_when_equal():
    a = rebalance([2.0] * 8, 32)
    assert a == [4] * 8


def test_rebalance_deterministic():
    times = [1.1, 0.9, 2.0, 1.0, 1.3]
    assert rebalance(times, 23) == rebalance(times, 23)


def test_rebalance_smoothing_uses_previous():
    times = [1.0, 1.0, 1.0, 10.0]
    prev = [4, 4, 4, 4]
    a_smooth = rebalance(times, 16, smoothing=0.1, prev_assignment=prev)
    a_sharp = rebalance(times, 16, smoothing=1.0)
    assert a_smooth[3] >= a_sharp[3]       # smoothing damps the swing


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(n=st.integers(2, 16), seed=st.integers(0, 1000),
           mult=st.integers(2, 8))
    def test_rebalance_invariants(n, seed, mult):
        rng = np.random.default_rng(seed)
        times = (0.5 + rng.random(n) * 3).tolist()
        total = n * mult
        a = rebalance(times, total)
        assert sum(a) == total
        assert min(a) >= 1
        # slowest host never gets more than the fastest
        assert a[int(np.argmax(times))] <= a[int(np.argmin(times))]
else:
    @pytest.mark.skip(reason="dev dependency (requirements-dev)")
    def test_rebalance_invariants():
        pass


def test_rebalance_invariants_seeded():
    """hypothesis-free slice of the invariant sweep (always runs)."""
    rng = np.random.default_rng(7)
    for n, mult in ((2, 2), (5, 3), (9, 8), (16, 4)):
        times = (0.5 + rng.random(n) * 3).tolist()
        a = rebalance(times, n * mult)
        assert sum(a) == n * mult
        assert min(a) >= 1
        assert a[int(np.argmax(times))] <= a[int(np.argmin(times))]


def test_should_eject():
    idx, med = should_eject([1.0, 1.1, 0.9, 5.0], eject_threshold=3.0)
    assert idx == [3]
    idx, _ = should_eject([1.0, 1.1, 0.9, 1.2], eject_threshold=3.0)
    assert idx == []


# ---------------------------------------------------------------------------
# Edge cases under seeded fault injection (repro.resilience.faults)
# ---------------------------------------------------------------------------

def test_single_shard_mesh():
    """One host: it gets everything, and can never be ejected (it IS the
    median)."""
    assert rebalance([1.7], 5) == [5]
    assert rebalance([1.7], 1) == [1]
    idx, med = should_eject([1.7], eject_threshold=3.0)
    assert idx == [] and med == 1.7


def test_all_equal_timings_with_injected_straggler():
    """All-equal gossip splits uniformly; a fault-injected slowdown on the
    last host deterministically shifts its work to the others."""
    from repro.resilience import faults

    base = [2.0, 2.0, 2.0, 2.0]
    assert rebalance(base, 17)[:3] == [5, 4, 4]      # remainder by index

    def round_assign():
        times = list(base)
        times[-1] = faults.scaled("straggler.times", times[-1])
        return rebalance(times, 16), should_eject(times)[0]

    with faults.inject("straggler.times", "slow", scale=8.0, times=None):
        a, ejected = round_assign()
    assert a[-1] == 1 and sum(a) == 16               # starved, never zero
    assert all(v > a[-1] for v in a[:-1])
    assert ejected == [3]                            # 8x > 3x median


def test_empty_smoothing_history_defaults_to_uniform_prior():
    """smoothing < 1 with no prev_assignment must blend against the
    uniform prior, not crash or bias toward any host."""
    times = [1.0, 1.0, 1.0, 4.0]
    a = rebalance(times, 16, smoothing=0.5, prev_assignment=None)
    assert sum(a) == 16 and min(a) >= 1
    sharp = rebalance(times, 16, smoothing=1.0)
    assert a[3] >= sharp[3]          # uniform prior damps the swing
    # smoothing -> 0 degenerates to (almost) the uniform prior itself
    near_uniform = rebalance(times, 16, smoothing=1e-6,
                             prev_assignment=None)
    assert max(near_uniform) - min(near_uniform) <= 1


def test_ejection_flapping_is_deterministic_and_bounded():
    """A host oscillating around the threshold (seeded prob < 1 fault)
    produces an identical ejection sequence on identical runs, and is
    only ever flagged in rounds where the fault actually fired."""
    from repro.resilience.faults import FaultPlan

    def run():
        decisions, fired = [], []
        fp = FaultPlan(seed=11).add("straggler.times", "slow",
                                    prob=0.5, times=None, scale=6.0)
        with fp:
            from repro.resilience import faults
            for _ in range(12):
                t3 = faults.scaled("straggler.times", 1.2)
                fired.append(t3 > 1.2)
                idx, _ = should_eject([1.0, 1.1, 0.9, t3],
                                      eject_threshold=3.0)
                decisions.append(tuple(idx))
        return decisions, fired

    d1, f1 = run()
    d2, f2 = run()
    assert (d1, f1) == (d2, f2)      # seeded: no flaky ejection flapping
    assert set(d1) == {(), (3,)}     # flaps, but only host 3, never others
    assert 0 < sum(f1) < 12          # both states actually occur
    # ejected exactly when (and only when) the fault fired that round
    assert all(d == ((3,) if f else ()) for d, f in zip(d1, f1))
