"""Straggler mitigation logic: deterministic rebalancing + ejection."""
import numpy as np
import pytest

from repro.dist.straggler import rebalance, should_eject

# Only the property-based sweep needs hypothesis (a dev dependency); the
# deterministic tests below must run even where it is absent.
try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def test_rebalance_shifts_work_away_from_slow_host():
    times = [1.0, 1.0, 1.0, 3.0]          # host 3 is 3x slower
    a = rebalance(times, 16)
    assert sum(a) == 16
    assert a[3] < a[0]
    assert a[3] >= 1


def test_rebalance_uniform_when_equal():
    a = rebalance([2.0] * 8, 32)
    assert a == [4] * 8


def test_rebalance_deterministic():
    times = [1.1, 0.9, 2.0, 1.0, 1.3]
    assert rebalance(times, 23) == rebalance(times, 23)


def test_rebalance_smoothing_uses_previous():
    times = [1.0, 1.0, 1.0, 10.0]
    prev = [4, 4, 4, 4]
    a_smooth = rebalance(times, 16, smoothing=0.1, prev_assignment=prev)
    a_sharp = rebalance(times, 16, smoothing=1.0)
    assert a_smooth[3] >= a_sharp[3]       # smoothing damps the swing


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(n=st.integers(2, 16), seed=st.integers(0, 1000),
           mult=st.integers(2, 8))
    def test_rebalance_invariants(n, seed, mult):
        rng = np.random.default_rng(seed)
        times = (0.5 + rng.random(n) * 3).tolist()
        total = n * mult
        a = rebalance(times, total)
        assert sum(a) == total
        assert min(a) >= 1
        # slowest host never gets more than the fastest
        assert a[int(np.argmax(times))] <= a[int(np.argmin(times))]
else:
    @pytest.mark.skip(reason="dev dependency (requirements-dev)")
    def test_rebalance_invariants():
        pass


def test_rebalance_invariants_seeded():
    """hypothesis-free slice of the invariant sweep (always runs)."""
    rng = np.random.default_rng(7)
    for n, mult in ((2, 2), (5, 3), (9, 8), (16, 4)):
        times = (0.5 + rng.random(n) * 3).tolist()
        a = rebalance(times, n * mult)
        assert sum(a) == n * mult
        assert min(a) >= 1
        assert a[int(np.argmax(times))] <= a[int(np.argmin(times))]


def test_should_eject():
    idx, med = should_eject([1.0, 1.1, 0.9, 5.0], eject_threshold=3.0)
    assert idx == [3]
    idx, _ = should_eject([1.0, 1.1, 0.9, 1.2], eject_threshold=3.0)
    assert idx == []
