"""Fused real-input 2-D FFT kernel: correctness vs numpy, the inverse
twin, registry routing (kind="rfft" x backend="pallas"), cross-backend
autotuning, and the wisdom stale-entry guard."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (clear_plan_cache, get_plan, irfft2, rfft2,
                        from_complex, to_complex, save_wisdom, load_wisdom)
from repro.core.complexmath import SplitComplex
from repro.kernels import ops


def _real(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize("hw", [(2, 2), (8, 8), (2, 32), (32, 2), (16, 64),
                                (64, 16), (128, 128)])
def test_rfft2d_kernel_matches_numpy(hw):
    x = _real((3,) + hw, seed=sum(hw))
    got = np.asarray(to_complex(ops.rfft2d_fused(jnp.asarray(x))))
    ref = np.fft.rfft2(x)
    assert got.shape == ref.shape
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-5


def test_rfft2d_kernel_leading_batch_and_padding():
    x = _real((2, 3, 16, 32), seed=7)
    got = np.asarray(to_complex(
        ops.rfft2d_fused(jnp.asarray(x), block_batch=4)))
    ref = np.fft.rfft2(x)
    assert got.shape == ref.shape
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-5
    # scalar batch too
    got1 = np.asarray(to_complex(ops.rfft2d_fused(jnp.asarray(x[0, 0]))))
    assert np.abs(got1 - ref[0, 0]).max() / np.abs(ref).max() < 1e-5


@pytest.mark.parametrize("hw", [(2, 2), (16, 16), (32, 64), (64, 32)])
def test_irfft2d_kernel_roundtrip_and_matches_numpy(hw):
    x = _real((2,) + hw, seed=sum(hw) + 1)
    spec = np.fft.rfft2(x)
    xf = from_complex(jnp.asarray(spec.astype(np.complex64)))
    got = np.asarray(ops.irfft2d_fused(xf))
    ref = np.fft.irfft2(spec)
    assert got.shape == ref.shape == x.shape
    assert np.abs(got - ref).max() < 1e-5
    back = np.asarray(ops.irfft2d_fused(ops.rfft2d_fused(jnp.asarray(x))))
    assert np.abs(back - x).max() < 1e-5


def test_rfft2_registry_routes_to_fused_kernel():
    clear_plan_cache()
    p = get_plan((32, 64), kind="rfft", backend="pallas")
    assert p.algo == "fused" and p.backend == "pallas"
    assert p.block_batch == 1 and p.demote_reason is None
    x = _real((32, 64), seed=3)
    got = np.asarray(to_complex(rfft2(jnp.asarray(x), backend="pallas")))
    ref = np.fft.rfft2(x)
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-5
    back = np.asarray(irfft2(rfft2(jnp.asarray(x), backend="pallas"),
                             backend="pallas"))
    assert np.abs(back - x).max() < 1e-5
    clear_plan_cache()


def test_irfft2_pallas_honours_s_fits():
    """The s= truncate/pad happens upstream of the kernel, so the pallas
    path follows numpy semantics for every even-width fit."""
    clear_plan_cache()
    x = _real((32, 64), seed=4)
    spec = np.fft.rfft2(x)
    xf = from_complex(jnp.asarray(spec.astype(np.complex64)))
    for s in (None, (32, 32), (32, 128), (16, 64), (64, 64), (16, 32)):
        ref = np.fft.irfft2(spec, s=s) if s else np.fft.irfft2(spec)
        got = np.asarray(irfft2(xf, s=s, backend="pallas"))
        assert got.shape == ref.shape, s
        assert np.abs(got - ref).max() < 1e-4, s
    clear_plan_cache()


def test_rfft2_explicit_fused_algo():
    x = _real((16, 16), seed=5)
    got = np.asarray(to_complex(rfft2(jnp.asarray(x), algo="fused",
                                      backend="pallas")))
    assert np.abs(got - np.fft.rfft2(x)).max() < 1e-4
    with pytest.raises(ValueError, match="fused"):
        rfft2(jnp.asarray(x), algo="fused")
    with pytest.raises(ValueError, match="fused"):
        irfft2(from_complex(jnp.asarray(np.fft.rfft2(x).astype(
            np.complex64))), algo="fused")
    # an odd s= width can never reach the even-only kernel: explicit error
    # instead of silently returning the wrong width
    with pytest.raises(ValueError, match="even"):
        irfft2(from_complex(jnp.asarray(np.fft.rfft2(x).astype(
            np.complex64))), s=(16, 17), algo="fused", backend="pallas")


def test_registry_explicit_algo_matches_direct_path():
    """A registry plan for an explicit non-fused algo on backend="pallas"
    must execute the same kernel-pass schedule as the direct
    rfft2(algo=..., backend="pallas") call — not silently demote to jnp."""
    clear_plan_cache()
    p = get_plan((8, 1024), kind="rfft", backend="pallas", algo="stockham")
    assert p.backend == "pallas" and p.algo == "stockham"
    assert p.demote_reason is None
    x = _real((8, 1024), seed=11)
    ref = np.fft.rfft2(x)
    got = np.asarray(to_complex(p(jnp.asarray(x))))
    direct = np.asarray(to_complex(rfft2(jnp.asarray(x), algo="stockham",
                                         backend="pallas")))
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-5
    np.testing.assert_array_equal(got, direct)
    # ...while an algo outside the kernel set demotes with a reason
    q = get_plan((8, 1024), kind="rfft", backend="pallas",
                 algo="cooley_tukey")
    assert q.backend == "jnp" and q.demote_reason
    clear_plan_cache()


def test_rfft2_explicit_algo_keeps_pallas_backend():
    """An explicit non-fused algo with backend="pallas" must still run the
    1-D kernel passes (not silently fall back to jnp) and match numpy."""
    x = _real((8, 1024), seed=8)
    ref = np.fft.rfft2(x)
    got = np.asarray(to_complex(rfft2(jnp.asarray(x), algo="stockham",
                                      backend="pallas")))
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-5
    xf = from_complex(jnp.asarray(ref.astype(np.complex64)))
    back = np.asarray(irfft2(xf, algo="stockham", backend="pallas"))
    assert np.abs(back - x).max() < 1e-4
    # ...including the odd-width direct path
    back_odd = np.asarray(irfft2(xf, s=(8, 1023), backend="pallas"))
    assert back_odd.shape == (8, 1023)
    assert np.abs(back_odd - np.fft.irfft2(ref, s=(8, 1023))).max() < 1e-3


def test_rfft_1d_pallas_inner_kernel():
    """1-D rfft plans on backend="pallas" run their inner complex
    transform on the 1-D kernels (inner 512 -> four_step kernel)."""
    clear_plan_cache()
    p = get_plan((1024,), kind="rfft", backend="pallas")
    assert p.backend == "pallas" and p.algo == "four_step"
    x = _real((4, 1024), seed=6)
    got = np.asarray(to_complex(p(jnp.asarray(x))))
    ref = np.fft.rfft(x)
    assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-5
    pi = get_plan((1024,), kind="rfft", backend="pallas", inverse=True)
    assert pi.backend == "pallas"
    back = np.asarray(pi(from_complex(jnp.asarray(ref.astype(
        np.complex64)))))
    assert np.abs(back - x).max() < 1e-4
    clear_plan_cache()


def test_rfft_kind_autotunes_across_backends():
    """rfft pallas keys measure the (algo, backend, block_batch) grid:
    the jnp schedule is always a candidate, and prune="model" measures
    strictly fewer with the default always kept."""
    clear_plan_cache()
    full = get_plan((64, 64), kind="rfft", backend="pallas", tune=True,
                    tune_batch=2)
    assert full.tuned
    labels = set(full.tune_report) - {"winner", "n_candidates",
                                      "n_measured", "model_pruned"}
    assert "jnp" in labels and any(l.startswith("fused") for l in labels)
    assert full.tune_report["n_measured"] == \
        full.tune_report["n_candidates"] == 3
    clear_plan_cache()
    pruned = get_plan((64, 64), kind="rfft", backend="pallas", tune=True,
                      tune_batch=2, prune="model")
    assert pruned.tuned
    assert pruned.tune_report["n_measured"] < \
        pruned.tune_report["n_candidates"]
    assert "default" in pruned.tune_report
    # the cross-backend jnp schedule is never model-pruned: the model
    # cannot see interpret-mode overhead vs XLA amortisation, and jnp
    # measurably wins at small sizes
    assert "jnp" in pruned.tune_report
    clear_plan_cache()


def test_wisdom_records_cross_backend_winner(tmp_path):
    """A tuned rfft pallas key whose winner is the jnp schedule must
    round-trip through wisdom with backend="jnp" intact (v2 format)."""
    import dataclasses
    from repro.core import plan as plan_mod
    clear_plan_cache()
    p = get_plan((64, 64), kind="rfft", backend="pallas", tune=True,
                 tune_batch=2)
    # force a cross-backend winner into the registry entry to pin the
    # round-trip (measurement noise decides the real winner)
    key = plan_mod._plan_key((64, 64), jnp.float32, False, "pallas", "rfft")
    forced = dataclasses.replace(p, backend="jnp", algo="naive",
                                 block_batch=8)
    plan_mod._PLAN_CACHE[key] = forced
    path = str(tmp_path / "w.json")
    assert save_wisdom(path) == 1
    clear_plan_cache()
    assert load_wisdom(path) == 1
    again = get_plan((64, 64), kind="rfft", backend="pallas", tune=True)
    assert again.backend == "jnp" and again.algo == "naive"
    assert again.tune_report["source"] == "wisdom"
    clear_plan_cache()


def test_wisdom_v1_files_are_rejected(tmp_path):
    """The stale-entry guard: a v1 wisdom file — written when rfft keys
    were hard-pinned to backend="jnp" — must not resurrect jnp as the
    tuned winner; the version guard rejects the whole file."""
    import hashlib
    ks = "shape=16x32;dtype=float32;inverse=0;backend=jnp;kind=rfft"
    # the exact v1 hash recipe (no backend field in the payload)
    v1_hash = hashlib.sha256(
        f"v1:{ks}:naive:4:8".encode()).hexdigest()[:16]
    path = tmp_path / "v1.json"
    path.write_text(json.dumps({"version": 1, "entries": [{
        "key": ks, "key_hash": v1_hash, "algo": "naive", "radix": 4,
        "block_batch": 8, "tune_report": {"winner": "default"}}]}))
    clear_plan_cache()
    assert load_wisdom(str(path)) == 0
    with pytest.raises(ValueError, match="version"):
        load_wisdom(str(path), strict=True)
    # the registry stays clean: the key resolves to the kernel path
    p = get_plan((16, 32), kind="rfft", backend="pallas")
    assert p.backend == "pallas" and p.algo == "fused" and not p.tuned
    clear_plan_cache()


def test_wisdom_v1_autoload_subprocess(tmp_path):
    """$REPRO_FFT_WISDOM pointing at a v-old wisdom file is a harmless
    no-op at import: nothing loads, the rfft key tunes fresh on the
    kernel path."""
    import hashlib
    import os
    import subprocess
    import sys
    ks = "shape=16x32;dtype=float32;inverse=0;backend=jnp;kind=rfft"
    v1_hash = hashlib.sha256(
        f"v1:{ks}:naive:4:8".encode()).hexdigest()[:16]
    path = tmp_path / "v1.json"
    path.write_text(json.dumps({"version": 1, "entries": [{
        "key": ks, "key_hash": v1_hash, "algo": "naive", "radix": 4,
        "block_batch": 8, "tune_report": {"winner": "default"}}]}))
    code = (
        "from repro.core import plan as P\n"
        "pl = P.get_plan((16, 32), kind='rfft', backend='pallas')\n"
        "print('V1GUARD', P.WISDOM_AUTOLOADED, pl.backend, pl.algo)\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["REPRO_FFT_WISDOM"] = str(path)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("V1GUARD")][0]
    assert line.split() == ["V1GUARD", "0", "pallas", "fused"]


def test_kernel_rejects_non_pow2():
    from repro.kernels import rfft2d_fused as rk
    with pytest.raises(ValueError, match="power-of-two"):
        rk.rfft2d_fused_pallas(jnp.zeros((1, 12, 20), jnp.float32))
    with pytest.raises(ValueError, match="power-of-two"):
        rk.irfft2d_fused_pallas(SplitComplex(
            jnp.zeros((1, 12, 11), jnp.float32),
            jnp.zeros((1, 12, 11), jnp.float32)))


def test_empty_batch_returns_empty():
    """A zero-size leading batch must not reach the kernel (grid of 0 /
    division by zero) — every wrapper returns the right empty shape."""
    x = jnp.zeros((0, 16, 32), jnp.float32)
    out = ops.rfft2d_fused(x)
    assert out.re.shape == (0, 16, 17)
    xf = SplitComplex(jnp.zeros((0, 16, 17), jnp.float32),
                      jnp.zeros((0, 16, 17), jnp.float32))
    assert ops.irfft2d_fused(xf).shape == (0, 16, 32)
    zc = SplitComplex(jnp.zeros((0, 16, 32), jnp.float32),
                      jnp.zeros((0, 16, 32), jnp.float32))
    assert ops.fft2d_fused(zc).shape == (0, 16, 32)


def test_explicit_cooley_tukey_demotes_with_reason():
    """The demote whitelist mirrors _fft_inner's kernel dispatch set: an
    explicit algo with no kernel must not report backend="pallas"."""
    clear_plan_cache()
    p = get_plan((1024,), kind="rfft", backend="pallas",
                 algo="cooley_tukey")
    assert p.backend == "jnp" and p.demote_reason
    clear_plan_cache()
