"""Distributed real-input pencil FFT: prfft2/pirfft2 correctness, the
Hermitian invariants of the exchanged pencils, and the halved exchange
bytes — measured (wire log) and predicted (trace_dist) — per wire format.
(8 fake devices, subprocess; the model-side assertions run in-process.)"""
import math

import pytest

from _subproc import run_with_devices

CODE = r"""
import math
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.complexmath import from_complex, to_complex, SplitComplex
from repro.core import fft2d, rfft
from repro.dist import pencil
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(0)
mesh = make_mesh((8,), ("data",))
P8 = 8


def gathered(sc):
    return SplitComplex(jnp.asarray(np.asarray(sc.re)),
                        jnp.asarray(np.asarray(sc.im)))


def rel(got, ref):
    return np.abs(got - ref).max() / np.abs(ref).max()


for H, W in ((128, 128), (64, 256), (512, 512), (1024, 1024)):
    x = rng.standard_normal((H, W)).astype(np.float32)
    sh = NamedSharding(mesh, P("data", None))
    xr = jax.device_put(jnp.asarray(x), sh)
    ref = np.fft.rfft2(x)

    # acceptance: prfft2 == numpy.fft.rfft2 at rel err <= 1e-6 (fp32)
    out = pencil.prfft2(xr, mesh, "data")
    spec = pencil.unpack_half_spectrum(gathered(out))
    got = np.asarray(to_complex(spec)).T
    assert rel(got, ref) <= 1e-6, (H, W, rel(got, ref))

    # ...and == the single-chip plan-registry rfft2 (not just numpy)
    loc = np.asarray(to_complex(fft2d.rfft2(jnp.asarray(x))))
    assert rel(got, loc) < 1e-5, (H, W)

    # ...and == pfft2 of the zero-imag complex input on the unique bins
    xc = SplitComplex(xr, jnp.zeros_like(xr))
    full = np.asarray(to_complex(pencil.pfft2(xc, mesh, "data"))).T
    assert rel(got, full[:, : W // 2 + 1]) < 1e-5, (H, W)

    # roundtrip through the packed layout
    back = np.asarray(pencil.pirfft2(out, mesh, "data"))
    assert np.abs(back - x).max() < 1e-4, (H, W)

# Hermitian invariants of the exchanged pencils (H, W from the last loop
# iteration): the row rfft's DC and Nyquist bins are *exactly* real — that
# is what makes the pack information-tight...
y = to_complex(rfft(jnp.asarray(x)))
assert np.abs(np.imag(np.asarray(y)[:, 0])).max() == 0.0
assert np.abs(np.imag(np.asarray(y)[:, W // 2])).max() == 0.0
# ...and the unpacked DC/Nyquist columns are conjugate-symmetric along H
spec = np.asarray(to_complex(pencil.unpack_half_spectrum(
    gathered(pencil.prfft2(xr, mesh, "data"))))).T
for col in (0, W // 2):
    c = spec[:, col]
    sym = np.conj(c[(-np.arange(H)) % H])
    assert np.abs(c - sym).max() / np.abs(c).max() < 1e-5, col

# halved exchange bytes, measured by the wire log, per compression dtype
H = W = 512
x = rng.standard_normal((H, W)).astype(np.float32)
xr = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data", None)))
xc = SplitComplex(xr, jnp.zeros_like(xr))
ref = np.fft.rfft2(x)
for method, tol in (("none", 1e-5), ("bf16", 5e-2), ("int8", 0.35)):
    pencil.reset_wire_log()
    o_r = pencil.prfft2(xr, mesh, "data", compress=method)
    wire_r = pencil.logged_exchange_bytes()
    pencil.reset_wire_log()
    pencil.pfft2(xc, mesh, "data", compress=method)
    wire_c = pencil.logged_exchange_bytes()
    assert wire_r <= math.ceil((W // 2 + 1) / W * wire_c), (method, wire_r)
    assert wire_r * 2 == wire_c, (method, wire_r, wire_c)
    assert wire_r == pencil.exchange_bytes(H, W, P8, real=True,
                                           method=method), method
    g = np.asarray(to_complex(pencil.unpack_half_spectrum(gathered(o_r)))).T
    assert rel(g, ref) < tol, (method, rel(g, ref))

# pirfft2 honours s= with numpy truncate/pad semantics (all fits local)
spec_t = from_complex(jnp.asarray(ref.T.astype(np.complex64)))
packed = pencil.pack_half_spectrum(spec_t)
shp = NamedSharding(mesh, P("data", None))
packed = SplitComplex(jax.device_put(packed.re, shp),
                      jax.device_put(packed.im, shp))
for s in (None, (512, 256), (512, 1024), (256, 512), (256, 384)):
    got_i = np.asarray(pencil.pirfft2(packed, mesh, "data", s=s))
    ref_i = np.fft.irfft2(ref, s=s) if s else np.fft.irfft2(ref)
    assert got_i.shape == ref_i.shape, (s, got_i.shape)
    assert rel(got_i, ref_i) < 1e-4, (s, rel(got_i, ref_i))

# natural (non-transposed) output spends a second packed all_to_all
pencil.reset_wire_log()
o_n = pencil.prfft2(xr, mesh, "data", transposed_output=False)
assert pencil.logged_exchange_bytes() == \
    pencil.exchange_bytes(H, W, P8, real=True, transposed_output=False)
g_n = np.asarray(to_complex(pencil.unpack_half_spectrum(
    pencil._swap_last2(gathered(o_n))))).T
assert rel(g_n, ref) <= 1e-6
print("DIST_RFFT_OK")
"""


def test_prfft2_8dev():
    out = run_with_devices(CODE, 8)
    assert "DIST_RFFT_OK" in out


PALLAS_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.complexmath import from_complex, to_complex, SplitComplex
from repro.core import plan as plan_lib
from repro.dist import pencil
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(1)
mesh = make_mesh((8,), ("data",))
H, W = 256, 1024                       # W's inner 512 rides the 1-D kernel

x = rng.standard_normal((H, W)).astype(np.float32)
xr = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("data", None)))
ref = np.fft.rfft2(x)

# the row plan the shards execute really is the pallas kernel path
row_plan = plan_lib.get_plan((W,), kind="rfft", backend="pallas")
assert row_plan.backend == "pallas", row_plan
assert row_plan.demote_reason is None

pencil.reset_wire_log()
out = pencil.prfft2(xr, mesh, "data", backend="pallas")
wire_pal = pencil.logged_exchange_bytes()
spec = pencil.unpack_half_spectrum(SplitComplex(
    jnp.asarray(np.asarray(out.re)), jnp.asarray(np.asarray(out.im))))
got = np.asarray(to_complex(spec)).T
rel = np.abs(got - ref).max() / np.abs(ref).max()
assert rel <= 1e-6, rel

# same halved wire bytes as the jnp path (backend changes compute only)
assert wire_pal == pencil.exchange_bytes(H, W, 8, real=True)
pencil.reset_wire_log()
pencil.prfft2(xr, mesh, "data", backend="jnp")
assert pencil.logged_exchange_bytes() == wire_pal

# roundtrip through pirfft2 on the pallas backend
back = np.asarray(pencil.pirfft2(out, mesh, "data", backend="pallas"))
assert np.abs(back - x).max() < 1e-4
print("DIST_RFFT_PALLAS_OK")
"""


def test_prfft2_pallas_backend_8dev():
    """CI acceptance: prfft2(backend="pallas") end-to-end on 8 emulated
    devices — the per-shard row pass runs the registry's pallas rfft
    plans, ships the same halved wire bytes, and roundtrips."""
    out = run_with_devices(PALLAS_CODE, 8)
    assert "DIST_RFFT_PALLAS_OK" in out


# ---------------------------------------------------------------------------
# Model-side assertions (no devices needed)
# ---------------------------------------------------------------------------

def test_exchange_bytes_helper_halves_per_method():
    import jax.numpy as jnp
    from repro.dist import pencil
    for n in (512, 1024):
        for method, factor in (("none", 1), ("bf16", 2), ("int8", 4)):
            full = pencil.exchange_bytes(n, n, 8, method=method)
            half = pencil.exchange_bytes(n, n, 8, real=True, method=method)
            assert full == n * (n // 8) * 4 * 2 // factor  # re+im planes
            assert half * 2 == full
            assert half <= math.ceil((n // 2 + 1) / n * full)
    # a bf16 *plan* (compute dtype) halves the wire before any compression
    assert pencil.exchange_bytes(512, 512, 8, dtype=jnp.bfloat16) \
        == pencil.exchange_bytes(512, 512, 8) // 2


def test_trace_dist_predicts_halved_exchange():
    """The tentpole acceptance, model side: predicted exchange wire bytes
    of prfft2 are ~(N/2+1)/N ~ half of pfft2's at 512^2 and 1024^2, on
    every arch and wire format, and they agree exactly with what the
    pencil wire log measures (same wire_bytes pricing x (p-1)/p)."""
    from repro.dist import pencil
    from repro.tt import trace as tttrace
    for n in (512, 1024):
        for arch in ("wormhole_n300", "tpu_v5e"):
            for method in ("none", "bf16", "int8"):
                tc = tttrace.trace_dist((n, n), devices=8, arch=arch,
                                        method=method)
                tr = tttrace.trace_dist((n, n), devices=8, arch=arch,
                                        method=method, real=True)
                assert tr.exchange_wire_bytes * 2 == tc.exchange_wire_bytes
                assert tr.exchange_wire_bytes <= math.ceil(
                    (n // 2 + 1) / n * tc.exchange_wire_bytes)
                assert tr.exchange_seconds < tc.exchange_seconds
                # the model's wire == the log's payload x the (p-1)/p
                # fraction that actually leaves the chip
                assert tr.exchange_wire_bytes == pytest.approx(
                    pencil.exchange_bytes(n, n, 8, real=True,
                                          method=method) * 7 / 8)
