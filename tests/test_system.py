"""End-to-end behaviour: train a small FNet-style model (the paper's
technique inside a transformer) until the loss drops, checkpoint mid-run,
kill, resume, and verify bitwise-identical continuation."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as M
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import init_opt_state, make_train_step


def test_fnet_technique_end_to_end(tmp_path):
    cfg = C.get_config("fnet_demo").reduced()
    assert cfg.block_pattern == ("fourier_mlp",)       # FFT token mixing
    data = SyntheticLM(DataConfig(seq_len=32, global_batch=8, seed=0), cfg)
    ocfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=80)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    state = init_opt_state(cfg, ocfg, params)
    step = jax.jit(make_train_step(cfg, ocfg))
    mgr = CheckpointManager(str(tmp_path), keep=2)

    losses = []
    for i in range(40):
        params, state, metrics = step(params, state, data.batch_at(i))
        losses.append(float(metrics["loss"]))
        if i == 19:
            mgr.save(19, (params, state), extra={"data_step": 20})
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    final_direct = jax.tree.leaves(params)

    # crash + resume from step 19: continuation must be identical
    params2 = M.init_params(jax.random.PRNGKey(0), cfg)
    state2 = init_opt_state(cfg, ocfg, params2)
    (params2, state2), extra = mgr.restore(19, (params2, state2))
    for i in range(int(extra["data_step"]), 40):
        params2, state2, _ = step(params2, state2, data.batch_at(i))
    for a, b in zip(final_direct, jax.tree.leaves(params2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
