"""Serving: prefill==decode consistency, ring cache wraparound, engine
scheduler behaviour, MoE dropless decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serve.engine import Engine, ServeConfig

BASE = dict(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
            head_dim=16, attn_chunk=16, vocab_pad_multiple=32)


def _dense(**kw):
    return ModelConfig(name="t", family="dense",
                       block_pattern=("attn_mlp",), repeat=2, **BASE, **kw)


def test_prefill_matches_stepwise_decode():
    cfg = _dense()
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 256)
    lg_pre, cache_pre = M.prefill(p, cfg, tokens=toks,
                                  cache=M.init_cache(cfg, B, 64))
    cache = M.init_cache(cfg, B, 64)
    for t in range(S):
        lg, cache = M.decode_step(p, cfg, toks[:, t], cache,
                                  jnp.full((B,), t, jnp.int32))
    assert float(jnp.abs(lg_pre[:, -1] - lg).max()) < 2e-3
    # caches agree -> continuing generation from prefill is consistent
    errs = [float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(cache_pre),
                            jax.tree.leaves(cache))]
    assert max(errs) < 2e-3


def test_sliding_window_ring_wraparound():
    """decode far past the window: ring cache must stay correct."""
    cfg = _dense(sliding_window=8)
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 256)
    # reference: full forward (mask enforces the window)
    ref, _ = M.forward(p, cfg, tokens=toks)
    cache = M.init_cache(cfg, B, S)       # ring: min(S, window)=8 slots
    assert cache["b0"]["k"].shape[2] == 8
    for t in range(S):
        lg, cache = M.decode_step(p, cfg, toks[:, t], cache,
                                  jnp.full((B,), t, jnp.int32))
    assert float(jnp.abs(ref[:, -1] - lg).max()) < 2e-3


def test_engine_serves_all_requests():
    cfg = _dense()
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, ServeConfig(batch_size=2, max_len=64), p)
    rng = np.random.default_rng(0)
    reqs = [(i, rng.integers(0, 256, size=5).astype(np.int32))
            for i in range(5)]
    out = eng.run(reqs, max_new=4)
    assert sorted(out) == [0, 1, 2, 3, 4]
    assert all(len(v) == 5 for v in out.values())     # 1 prompt tail + 4 new


def test_engine_greedy_deterministic():
    cfg = _dense()
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([5, 6, 7], np.int32)
    outs = []
    for _ in range(2):
        eng = Engine(cfg, ServeConfig(batch_size=2, max_len=64), p)
        outs.append(eng.run([(0, prompt)], max_new=6)[0])
    assert outs[0] == outs[1]


def test_moe_dropless_decode_exact():
    from repro.models import moe
    cfg = ModelConfig(name="m", family="moe", block_pattern=("attn_moe",),
                      repeat=1, n_experts=8, n_experts_active=2, moe_d_ff=32,
                      **BASE)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 64))
    got, _ = moe.moe_apply(p, x, cfg, dropless=True)
    # dense reference: route every token through its top-k experts exactly
    xf = x.reshape(4, 64)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, 2)
    topw = topw / topw.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(4):
        acc = jnp.zeros((64,))
        for j in range(2):
            e = int(topi[t, j])
            h = jax.nn.silu(xf[t] @ p["wg"][e]) * (xf[t] @ p["wi"][e])
            acc += topw[t, j] * (h @ p["wo"][e])
        ref = ref.at[t].set(acc)
    assert float(jnp.abs(got.reshape(4, 64) - ref).max()) < 1e-4
